//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E):
//! trains the ~0.5M-parameter `transformer-med` language model on the
//! synthetic Markov corpus with 8 workers for several hundred steps,
//! three ways — uncompressed, ScaleCom (47x), and naive local top-k —
//! logging the loss curves and the communication ledger. This exercises
//! every layer: L2/L1 artifacts under PJRT, the L3 coordinator's
//! compressed collectives, the optimizer and LR schedule.
//!
//! Run: `make artifacts && cargo run --release --example train_transformer`
//! (about 10-15 minutes; pass --quick for a 60-step smoke run)

use scalecom::config::train::{CompressConfig, OptimizerKind, TrainConfig};
use scalecom::metrics::Table;
use scalecom::trainer::{LrSchedule, Trainer};

fn cfg(scheme: &str, steps: usize) -> TrainConfig {
    let zoo = scalecom::models::zoo_model("transformer-med").unwrap();
    TrainConfig {
        model: "transformer-med".into(),
        workers: 8,
        steps,
        batch_per_worker: zoo.batch_per_worker,
        lr: 0.01,
        optimizer: OptimizerKind::Adam,
        eval_every: (steps / 10).max(1),
        compress: CompressConfig {
            scheme: scheme.to_string(),
            rate: zoo.default_rate, // 47x, the paper's transformer rate
            beta: 1.0,
            warmup_steps: if scheme == "none" { 0 } else { steps / 20 },
            use_flops_rule: false,
        },
        ..TrainConfig::default()
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 60 } else { 600 };
    println!(
        "E2E driver: transformer-med (~547k params, vocab 64, seq 32), 8 workers,\n\
         global batch {} sequences/step, {} steps, Adam + warmup-invsqrt\n",
        8 * 16,
        steps
    );

    let mut rows = Vec::new();
    for scheme in ["none", "scalecom", "local-topk"] {
        let c = cfg(scheme, steps);
        let mut trainer = Trainer::from_config(c)?;
        trainer.schedule = LrSchedule::warmup_invsqrt(0.01, steps / 10);
        let mut log = trainer.run()?;
        log.name = format!("e2e_transformer_{}", scheme.replace('-', ""));
        let path = log.save_csv(std::path::Path::new("results"))?;
        let (eval_loss, eval_acc) = trainer.evaluate()?;
        println!(
            "[{scheme:<11}] final train loss {:.4} | eval loss {eval_loss:.4} | \
             eval acc {:.1}% | comm up {:.2} MB/worker total | wall {:.1}s | {}",
            log.tail_mean("loss", 20).unwrap(),
            eval_acc * 100.0,
            log.column("bytes_up").unwrap().iter().sum::<f64>() / 1e6,
            log.last("wall_s").unwrap(),
            path.display()
        );
        rows.push((
            scheme,
            log.tail_mean("loss", 20).unwrap(),
            eval_loss,
            eval_acc,
            log.column("bytes_up").unwrap().iter().sum::<f64>() / 1e6,
        ));
    }

    println!("\n=== E2E summary (record in EXPERIMENTS.md) ===");
    let mut t = Table::new(&[
        "scheme",
        "train loss",
        "eval loss",
        "eval acc",
        "upload MB/worker",
        "reduction vs dense",
    ]);
    let dense_mb = rows[0].4;
    for (scheme, train, eval, acc, mb) in &rows {
        t.row(vec![
            scheme.to_string(),
            format!("{train:.4}"),
            format!("{eval:.4}"),
            format!("{:.1}%", acc * 100.0),
            format!("{mb:.2}"),
            format!("{:.1}x", dense_mb / mb),
        ]);
    }
    println!("{}", t.render());
    println!(
        "expected shape (paper Fig 4c/Table 2): ScaleCom tracks the dense\n\
         baseline closely at ~23x less traffic (47x rate, 8B pairs vs 4B\n\
         dense); local top-k pays the gather build-up in download volume."
    );
    Ok(())
}
