//! Large-batch scaling study (the Table 3 / Fig 5 scenario, interactive):
//! scale the CNN workload from 8 to 32 workers with a linearly-scaled,
//! warmed-up learning rate and compare ScaleCom with and without the
//! low-pass filter against the uncompressed baseline.
//!
//! Run: `make artifacts && cargo run --release --example large_batch_scaling`

use scalecom::config::train::{CompressConfig, TrainConfig};
use scalecom::metrics::Table;
use scalecom::trainer::{LrSchedule, Trainer};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 50 } else { 200 };
    let zoo = scalecom::models::zoo_model("cnn")?;
    let base_workers = 8usize;
    let workers = 32usize;
    let base_lr = 0.05;
    let peak_lr = base_lr * (workers as f64 / base_workers as f64); // Goyal scaling
    let warmup = steps / 10;

    println!(
        "large-batch scaling: cnn, {base_workers} -> {workers} workers \
         (global batch {} -> {}), lr {base_lr} -> {peak_lr} with {warmup}-step warmup\n",
        base_workers * zoo.batch_per_worker,
        workers * zoo.batch_per_worker
    );

    let mut table = Table::new(&["run", "final train loss", "eval loss", "eval acc"]);
    for (label, scheme, beta) in [
        ("dense baseline", "none", 1.0f32),
        ("scalecom beta=1 (no filter)", "scalecom", 1.0),
        ("scalecom beta=0.1 (low-pass)", "scalecom", 0.1),
        ("scalecom beta=0.3", "scalecom", 0.3),
    ] {
        let cfg = TrainConfig {
            model: "cnn".into(),
            workers,
            steps,
            batch_per_worker: zoo.batch_per_worker,
            lr: peak_lr,
            eval_every: 0,
            compress: CompressConfig {
                scheme: scheme.into(),
                rate: zoo.default_rate,
                beta,
                warmup_steps: if scheme == "none" { 0 } else { warmup },
                use_flops_rule: true,
            },
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::from_config(cfg)?;
        trainer.schedule = LrSchedule::warmup_linear(base_lr, peak_lr, warmup);
        let mut log = trainer.run()?;
        log.name = format!(
            "large_batch_cnn_{}_b{}",
            scheme.replace('-', ""),
            (beta * 10.0) as u32
        );
        log.save_csv(std::path::Path::new("results"))?;
        let (eval_loss, eval_acc) = trainer.evaluate()?;
        table.row(vec![
            label.to_string(),
            format!("{:.4}", log.tail_mean("loss", 20).unwrap()),
            format!("{eval_loss:.4}"),
            format!("{:.1}%", eval_acc * 100.0),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper Fig 5 / Table 3: at scaled LR the unfiltered run (beta=1)\n\
         degrades; beta≈0.1-0.3 restores parity with the dense baseline."
    );
    Ok(())
}
