//! Quickstart: 4-worker distributed training of the MLP with ScaleCom,
//! ending with a Fig-A2-style step trace (leader selection, averaged
//! sparse gradient, residues) on a tiny slice of the gradient.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use scalecom::compress::Selection;
use scalecom::config::train::{CompressConfig, TrainConfig};
use scalecom::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let zoo = scalecom::models::zoo_model("mlp")?;
    let cfg = TrainConfig {
        model: "mlp".into(),
        workers: 4,
        steps: 80,
        batch_per_worker: zoo.batch_per_worker,
        lr: 0.1,
        eval_every: 20,
        compress: CompressConfig {
            scheme: "scalecom".into(),
            rate: zoo.default_rate,
            beta: 1.0,
            warmup_steps: 4,
            use_flops_rule: false,
        },
        ..TrainConfig::default()
    };
    println!(
        "ScaleCom quickstart: mlp, {} workers, {}x compression, global batch {}\n",
        cfg.workers,
        cfg.compress.rate,
        cfg.global_batch()
    );

    let mut trainer = Trainer::from_config(cfg)?;
    // Fig A2-style demonstration on the first compressed step: print the
    // first 8 coordinates of each worker's EF gradient, the leader's
    // selection restricted to that window, and the residues left behind.
    trainer.set_hook(Box::new(|snap| {
        if snap.t != 4 {
            return; // first post-warmup step
        }
        println!("--- step {} (leader = worker {}) ---", snap.t, snap.result.leader);
        for (w, ef) in snap.ef_grads.iter().enumerate() {
            println!(
                "before average, worker {w} EF grads[..8]: {:?}",
                &ef[..8].iter().map(|v| format!("{v:+.4}")).collect::<Vec<_>>()
            );
        }
        if let Some(Selection::Shared(idx)) = &snap.result.selection {
            let in_window: Vec<u32> =
                idx.iter().copied().filter(|&i| i < 8).collect();
            println!("leader-selected indices in [0,8): {in_window:?} (of {} total)", idx.len());
        }
        println!(
            "after average, update[..8]: {:?}",
            &snap.result.update[..8]
                .iter()
                .map(|v| format!("{v:+.4}"))
                .collect::<Vec<_>>()
        );
        for (w, mem) in snap.memories.iter().enumerate() {
            println!(
                "residual, worker {w} memory[..8]:  {:?}",
                &mem.memory()[..8]
                    .iter()
                    .map(|v| format!("{v:+.4}"))
                    .collect::<Vec<_>>()
            );
        }
        println!();
    }));

    let log = trainer.run()?;
    println!("step  loss    rate   bytes_up/worker");
    for row in log.rows.iter().step_by(10) {
        println!(
            "{:>4}  {:<6.4}  {:>4.0}x  {:>8.0}",
            row[0], row[1], row[3], row[4]
        );
    }
    let (eval_loss, eval_acc) = trainer.evaluate()?;
    println!(
        "\nfinal eval: loss {eval_loss:.4}, accuracy {:.1}%  (uncompressed parity \
         is demonstrated by `scalecom experiment table2`)",
        eval_acc * 100.0
    );
    Ok(())
}
