//! System-performance explorer: sweep the analytic perf model (§5 /
//! Appendix F) over every paper network, reporting where ScaleCom's
//! constant-cost communication wins and by how much.
//!
//! Run: `cargo run --release --example perf_scaling` (no artifacts needed)

use scalecom::metrics::Table;
use scalecom::models::paper::{paper_net, ALL_PAPER_NETS};
use scalecom::perfmodel::{speedup, step_time, Scheme, SystemConfig};

fn main() -> anyhow::Result<()> {
    println!("=== ScaleCom speedup across the paper's model zoo ===");
    println!("(100 TFLOPs/worker, 32 GBps, minibatch/worker 8, rate per Table 2)\n");
    let mut table = Table::new(&[
        "network",
        "params",
        "comm frac (dense)",
        "speedup @8w",
        "speedup @128w",
        "topk @128w",
    ]);
    for name in ALL_PAPER_NETS {
        let net = paper_net(name)?;
        let rate = net.paper_rate_std;
        let mk = |workers| SystemConfig {
            workers,
            compression: rate,
            minibatch_per_worker: if name == "transformer" { 512 } else { 8 },
            ..SystemConfig::default()
        };
        let dense = step_time(&net, &mk(8), Scheme::None);
        table.row(vec![
            name.to_string(),
            format!("{:.1}M", net.total_params() as f64 / 1e6),
            format!("{:.0}%", dense.comm_fraction() * 100.0),
            format!("{:.2}x", speedup(&net, &mk(8), Scheme::ScaleCom, Scheme::None)),
            format!("{:.2}x", speedup(&net, &mk(128), Scheme::ScaleCom, Scheme::None)),
            format!("{:.2}x", speedup(&net, &mk(128), Scheme::LocalTopK, Scheme::None)),
        ]);
    }
    println!("{}", table.render());

    println!("=== crossover analysis: when does compression stop paying? ===\n");
    let net = paper_net("resnet50")?;
    let mut table = Table::new(&[
        "minibatch/worker",
        "comm frac (dense)",
        "scalecom speedup",
    ]);
    for mb in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let sys = SystemConfig {
            workers: 64,
            minibatch_per_worker: mb,
            ..SystemConfig::default()
        };
        let dense = step_time(&net, &sys, Scheme::None);
        table.row(vec![
            mb.to_string(),
            format!("{:.0}%", dense.comm_fraction() * 100.0),
            format!("{:.2}x", speedup(&net, &sys, Scheme::ScaleCom, Scheme::None)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "larger per-worker minibatches amortize communication (Fig 6a: the\n\
         56% -> 20% comm-fraction drop from mb 8 -> 32), shrinking ScaleCom's\n\
         end-to-end win even at identical compression."
    );
    Ok(())
}
