"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: ``chunk_topk.py`` and
``lowpass.py`` must match them exactly (pytest + hypothesis sweeps in
``python/tests/``), and the Rust-native implementations in
``rust/src/compress/`` are cross-checked against the same semantics in
``rust/tests/kernel_parity.rs``.
"""

import jax.numpy as jnp


def chunk_top1_ref(x, chunk_size):
    """Indices+values of the max-|x| element of each chunk.

    Ties break to the lowest index (jnp.argmax semantics, matching the
    Rust implementation). The trailing partial chunk also contributes one
    element. Returns (idx [K] i32, vals [K] f32) with K = ceil(P/C).
    """
    p = x.shape[0]
    c = int(chunk_size)
    k = -(-p // c)  # ceil
    pad = k * c - p
    mag = jnp.abs(x)
    # padding positions must never win: magnitude -1
    mag = jnp.pad(mag, (0, pad), constant_values=-1.0)
    xpad = jnp.pad(x, (0, pad))
    mag2 = mag.reshape(k, c)
    am = jnp.argmax(mag2, axis=1)  # first occurrence on ties
    idx = (jnp.arange(k) * c + am).astype(jnp.int32)
    vals = xpad[idx]
    return idx, vals


def lowpass_update_ref(m, g, sel_mask, beta):
    """Low-pass error-feedback memory update, Eqn. (5) of the paper.

    m' = (1-beta)*m + beta*(m + g - sent)  with  sent = (m+g)*sel_mask
       = m + beta*g - beta*(m+g)*sel_mask   (elementwise)

    sel_mask is 1.0 on transmitted coordinates, 0.0 elsewhere.
    """
    ef = m + g
    return m + beta * g - beta * ef * sel_mask


def sparsify_ref(ef, idx):
    """Gather ef[idx] — the follower-side compression of CLT-k."""
    return jnp.take(ef, idx)


def mask_from_indices_ref(idx, dim):
    """0/1 mask of the selected coordinates."""
    return jnp.zeros((dim,), jnp.float32).at[idx].set(1.0)
