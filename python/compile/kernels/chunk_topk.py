"""L1 Pallas kernel: chunk-wise top-1 selection (the compression hot-spot).

The paper accelerates top-k with a chunked quasi-sort ([39], §4): the
gradient buffer is cut into chunks of C elements and the single
largest-magnitude element of each chunk is selected — O(1) work per
element (~3 FLOPs: abs, compare, conditional update) and embarrassingly
parallel across chunks.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the original is a
GPU kernel with one threadblock per chunk batch in shared memory. On TPU
the same insight maps to a VMEM-resident tile per grid step: we reshape
the flat gradient to [K, C] and give each grid step a (R, C) block —
R chunk rows resident in VMEM at once — reducing along the lane (C)
dimension with the VPU. No MXU involvement: selection is bandwidth-bound,
so the roofline target is HBM bandwidth, not FLOPs.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk rows per grid step. 8 rows x C lanes keeps the block well under
# VMEM limits for every rate we use (C <= 512 -> 16 KiB/block at f32).
ROWS_PER_BLOCK = 8


def _chunk_top1_kernel(x_ref, idx_ref, val_ref, *, chunk_size, rows, total):
    """One grid step: select the max-|x| element of each of `rows` chunks.

    x_ref:   (rows, chunk_size) f32 block in VMEM
    idx_ref: (rows,) i32 global indices of the winners
    val_ref: (rows,) f32 winner values (signed)
    """
    pid = pl.program_id(0)
    x = x_ref[...]
    # Global flat position of every element in the block; positions past
    # the real input (padding) get magnitude -1 so they can never win.
    row_ids = pid * rows + jax.lax.broadcasted_iota(jnp.int32, (rows, chunk_size), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (rows, chunk_size), 1)
    pos = row_ids * chunk_size + col_ids
    mag = jnp.where(pos < total, jnp.abs(x), -1.0)
    am = jnp.argmax(mag, axis=1)  # first occurrence on ties (lowest index)
    r = jnp.arange(rows)
    winner_pos = (pid * rows + r) * chunk_size + am
    idx_ref[...] = winner_pos.astype(jnp.int32)
    val_ref[...] = x[r, am]


@functools.partial(jax.jit, static_argnames=("chunk_size",))
def chunk_top1(x, chunk_size):
    """Pallas chunk-wise top-1 of a flat vector.

    Returns (idx [K] i32, vals [K] f32), K = ceil(P / chunk_size); matches
    ``ref.chunk_top1_ref`` exactly.
    """
    p = x.shape[0]
    c = int(chunk_size)
    k = -(-p // c)
    rows = min(ROWS_PER_BLOCK, k)
    k_pad = -(-k // rows) * rows
    # Pad the flat vector out to k_pad full chunks; in-kernel position
    # masking guarantees padding never wins within a live chunk, and the
    # rows beyond K are sliced off below.
    xpad = jnp.pad(x, (0, k_pad * c - p)).reshape(k_pad, c)
    grid = (k_pad // rows,)
    kernel = functools.partial(
        _chunk_top1_kernel, chunk_size=c, rows=rows, total=p
    )
    idx, vals = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows,), lambda i: (i,)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_pad,), jnp.int32),
            jax.ShapeDtypeStruct((k_pad,), jnp.float32),
        ],
        interpret=True,
    )(xpad)
    return idx[:k], vals[:k]


def vmem_bytes_per_block(chunk_size, rows=ROWS_PER_BLOCK):
    """Estimated VMEM footprint of one grid step (input block + outputs +
    the two iota/position intermediates) — used by the L1 perf notes in
    DESIGN.md/EXPERIMENTS.md §Perf."""
    c = int(chunk_size)
    block = rows * c * 4          # x tile (f32)
    pos = 2 * rows * c * 4        # row/col iota (i32)
    outs = 2 * rows * 4
    return block + pos + outs
