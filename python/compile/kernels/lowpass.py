"""L1 Pallas kernel: low-pass error-feedback memory update (Eqn. 5).

Elementwise over the flat gradient:  m' = m + beta*g - beta*(m+g)*sel.
Bandwidth-bound (3 reads + 1 write per element, ~4 FLOPs), so the TPU
mapping is a plain 1-D VMEM tiling along the flat dimension; the block
size keeps three f32 input tiles + one output tile under 1 MiB.

interpret=True for the same reason as chunk_topk.py.
"""



import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # elements per grid step (4 tiles x 16 KiB = 64 KiB VMEM)


def _lowpass_kernel(beta_ref, m_ref, g_ref, sel_ref, out_ref):
    beta = beta_ref[0]
    m = m_ref[...]
    g = g_ref[...]
    sel = sel_ref[...]
    ef = m + g
    out_ref[...] = m + beta * g - beta * ef * sel


@jax.jit
def lowpass_update(m, g, sel_mask, beta):
    """Pallas low-pass memory update; matches ``ref.lowpass_update_ref``."""
    p = m.shape[0]
    block = min(BLOCK, p)
    p_pad = -(-p // block) * block
    pad = p_pad - p
    mp = jnp.pad(m, (0, pad))
    gp = jnp.pad(g, (0, pad))
    sp = jnp.pad(sel_mask, (0, pad))
    beta_arr = jnp.reshape(beta, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        _lowpass_kernel,
        grid=(p_pad // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # beta broadcast
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p_pad,), jnp.float32),
        interpret=True,
    )(beta_arr, mp, gp, sp)
    return out[:p]


def vmem_bytes_per_block(block=BLOCK):
    """VMEM footprint of one grid step (3 input tiles + 1 output)."""
    return 4 * block * 4 + 4


