"""L2 model zoo: JAX forward/backward graphs lowered to HLO artifacts.

Each module exposes ``init_params(key, cfg)`` and ``loss_and_correct``;
``compile.model`` assembles them into the registry that ``aot.py`` lowers.
"""
