"""Decoder-only transformer LM (language stand-in — Transformer-base slot).

Pre-LN blocks: LN -> causal MHA -> residual, LN -> FFN(GeLU) -> residual;
learned positional embeddings; untied output projection. Cross-entropy
over every position.
"""

import jax
import jax.numpy as jnp


def init_params(key, cfg):
    v, s, d = cfg["vocab"], cfg["seq"], cfg["d_model"]
    n_layers, ffn = cfg["layers"], cfg["ffn"]
    keys = jax.random.split(key, 4 + 6 * n_layers)
    ki = iter(keys)

    def mat(k, a, b, scale=None):
        scale = scale if scale is not None else jnp.sqrt(1.0 / a)
        return jax.random.normal(k, (a, b), jnp.float32) * scale

    params = {
        "embed": mat(next(ki), v, d, 0.02),
        "pos": mat(next(ki), s, d, 0.02),
        "ln_f": {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)},
        "out": mat(next(ki), d, v),
        "blocks": [],
    }
    _ = next(ki)
    for _layer in range(n_layers):
        blk = {
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "wq": mat(next(ki), d, d),
            "wk": mat(next(ki), d, d),
            "wv": mat(next(ki), d, d),
            "wo": mat(next(ki), d, d),
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "w1": mat(next(ki), d, ffn),
            "w2": mat(next(ki), ffn, d),
        }
        params["blocks"].append(blk)
    return params


def _ln(x, p):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * p["g"] + p["b"]


def _attn(h, blk, heads):
    b, s, d = h.shape
    hd = d // heads

    def split(x):
        return x.reshape(b, s, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(h @ blk["wq"]), split(h @ blk["wk"]), split(h @ blk["wv"])
    scores = q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(mask[None, None] > 0, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = (attn @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ blk["wo"]


def logits_fn(params, tokens, heads):
    # tokens: [B, S] i32
    h = params["embed"][tokens] + params["pos"][None, :, :]
    for blk in params["blocks"]:
        h = h + _attn(_ln(h, blk["ln1"]), blk, heads)
        ff = jax.nn.gelu(_ln(h, blk["ln2"]) @ blk["w1"]) @ blk["w2"]
        h = h + ff
    h = _ln(h, params["ln_f"])
    return h @ params["out"]  # [B, S, V]


def loss_and_correct(params, x, y, heads=4):
    """x: [B, S] i32 tokens, y: [B, S] i32 next-token targets."""
    logits = logits_fn(params, x, heads)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), correct
