"""Small conv net (vision stand-in, large — paper's ResNet/MobileNet slot).

16x16x1 input -> conv3x3(8) ReLU -> maxpool2 -> conv3x3(16) ReLU ->
maxpool2 -> flatten -> dense 64 -> classes. The conv stages give the
model the high FLOPs-per-parameter profile that drives the paper's
per-layer compression-rate rule (conv layers land in the gentle 25-50X
bands, the dense head in the aggressive 400X band).
"""

import jax
import jax.numpy as jnp


def init_params(key, cfg):
    classes = cfg["classes"]
    side = cfg.get("side", 16)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv(k, kh, kw, cin, cout):
        w = jax.random.normal(k, (kh, kw, cin, cout), jnp.float32)
        return {
            "w": w * jnp.sqrt(2.0 / (kh * kw * cin)),
            "b": jnp.zeros((cout,), jnp.float32),
        }

    flat = (side // 4) * (side // 4) * 16
    wd = jax.random.normal(k3, (flat, 64), jnp.float32) * jnp.sqrt(2.0 / flat)
    # small-scale logit head: keeps the initial loss near ln(classes)
    wo = jax.random.normal(k4, (64, classes), jnp.float32) * 0.03
    return {
        "conv1": conv(k1, 3, 3, 1, 8),
        "conv2": conv(k2, 3, 3, 8, 16),
        "dense": {"w": wd, "b": jnp.zeros((64,), jnp.float32)},
        "out": {"w": wo, "b": jnp.zeros((classes,), jnp.float32)},
    }


def _conv2d(x, w):
    # x: [B, H, W, C], w: [kh, kw, cin, cout], SAME padding
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def logits_fn(params, x, side):
    b = x.shape[0]
    img = x.reshape(b, side, side, 1)
    h = jax.nn.relu(_conv2d(img, params["conv1"]["w"]) + params["conv1"]["b"])
    h = _maxpool2(h)
    h = jax.nn.relu(_conv2d(h, params["conv2"]["w"]) + params["conv2"]["b"])
    h = _maxpool2(h)
    h = h.reshape(b, -1)
    h = jax.nn.relu(h @ params["dense"]["w"] + params["dense"]["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


def loss_and_correct(params, x, y, side=16):
    """x: [B, side*side] f32, y: [B] i32."""
    logits = logits_fn(params, x, side)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), correct
