"""Bidirectional LSTM frame classifier (speech stand-in — SWB300 slot).

One bi-LSTM layer (jax.lax.scan over time, both directions) followed by a
per-frame dense softmax — the miniature of the paper's 4-bi-LSTM acoustic
model. Per-frame cross-entropy, matching CD-HMM state classification.
"""

import jax
import jax.numpy as jnp


def init_params(key, cfg):
    feat, hidden, classes = cfg["feature_dim"], cfg["hidden"], cfg["classes"]
    k1, k2, k3 = jax.random.split(key, 3)

    def lstm_dir(k):
        kw, ku = jax.random.split(k)
        return {
            # gates stacked [i, f, g, o]: inputs feat -> 4H, hidden H -> 4H
            "wx": jax.random.normal(kw, (feat, 4 * hidden), jnp.float32)
            * jnp.sqrt(1.0 / feat),
            "wh": jax.random.normal(ku, (hidden, 4 * hidden), jnp.float32)
            * jnp.sqrt(1.0 / hidden),
            "b": jnp.zeros((4 * hidden,), jnp.float32),
        }

    wo = jax.random.normal(k3, (2 * hidden, classes), jnp.float32) * jnp.sqrt(
        1.0 / (2 * hidden)
    )
    return {
        "fwd": lstm_dir(k1),
        "bwd": lstm_dir(k2),
        "out": {"w": wo, "b": jnp.zeros((classes,), jnp.float32)},
    }


def _lstm_scan(p, xs, hidden):
    """xs: [T, B, F] -> outputs [T, B, H]."""
    b = xs.shape[1]
    h0 = jnp.zeros((b, hidden), jnp.float32)
    c0 = jnp.zeros((b, hidden), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        z = x_t @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    _, hs = jax.lax.scan(step, (h0, c0), xs)
    return hs


def logits_fn(params, x, seq, feat, hidden):
    # x: [B, T*F] flat frames -> [T, B, F]
    b = x.shape[0]
    xs = x.reshape(b, seq, feat).transpose(1, 0, 2)
    h_f = _lstm_scan(params["fwd"], xs, hidden)
    h_b = _lstm_scan(params["bwd"], xs[::-1], hidden)[::-1]
    h = jnp.concatenate([h_f, h_b], axis=-1)  # [T, B, 2H]
    logits = h @ params["out"]["w"] + params["out"]["b"]
    return logits.transpose(1, 0, 2)  # [B, T, C]


def loss_and_correct(params, x, y, seq=12, feat=8, hidden=32):
    """x: [B, T*F] f32, y: [B, T] i32 frame labels."""
    logits = logits_fn(params, x, seq, feat, hidden)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), correct
