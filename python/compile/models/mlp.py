"""MLP classifier (vision stand-in, small — paper's ResNet34/CIFAR10 slot).

Architecture: feat -> 64 -> 32 -> classes, ReLU, softmax cross-entropy.
"""

import jax
import jax.numpy as jnp


def init_params(key, cfg):
    feat, classes = cfg["feature_dim"], cfg["classes"]
    h1, h2 = cfg.get("hidden1", 64), cfg.get("hidden2", 32)
    k1, k2, k3 = jax.random.split(key, 3)

    def dense(k, fan_in, fan_out):
        w = jax.random.normal(k, (fan_in, fan_out), jnp.float32)
        return {"w": w * jnp.sqrt(2.0 / fan_in), "b": jnp.zeros((fan_out,), jnp.float32)}

    return {
        "l1": dense(k1, feat, h1),
        "l2": dense(k2, h1, h2),
        "l3": dense(k3, h2, classes),
    }


def logits_fn(params, x):
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    h = jax.nn.relu(h @ params["l2"]["w"] + params["l2"]["b"])
    return h @ params["l3"]["w"] + params["l3"]["b"]


def loss_and_correct(params, x, y):
    """x: [B, F] f32, y: [B] i32 -> (mean CE loss, correct count f32)."""
    logits = logits_fn(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), correct
