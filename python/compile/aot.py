"""AOT lowering: jax/pallas -> HLO text artifacts + manifest.

Runs once at build time (`make artifacts`); the Rust binary is
self-contained afterwards. Per model it emits:

  <name>.hlo.txt           train step  (params, x, y) -> (loss, grads)
  <name>_eval.hlo.txt      eval step   (params, x, y) -> (loss, correct)
  <name>_compress.hlo.txt  CLT-k leader (m, g, beta) -> (idx, vals, m')
  <name>_apply.hlo.txt     CLT-k follower (m, g, idx, beta) -> (vals, m')
  <name>_init.bin          initial flat parameters (f32 little-endian)

plus a global `manifest.json` describing shapes, dtypes, the layer
partition of the flat gradient, and the chunk size the compress kernel
was lowered with.

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, specs, path: str) -> int:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_model(mdef: M.ModelDef, out_dir: str) -> dict:
    """Lower all four artifacts for one model; return its manifest entry."""
    flat, _ = M.flat_init(mdef)
    dim = int(flat.shape[0])
    k = -(-dim // mdef.chunk)

    # initial parameters (identical on every worker, as in sync SGD)
    init_path = os.path.join(out_dir, f"{mdef.name}_init.bin")
    with open(init_path, "wb") as f:
        import numpy as np

        f.write(np.asarray(flat, dtype="<f4").tobytes())

    pf = spec((dim,), jnp.float32)
    x = spec(mdef.x_shape, mdef.x_dtype)
    y = spec(mdef.y_shape, jnp.int32)
    mv = spec((dim,), jnp.float32)
    beta = spec((), jnp.float32)
    idx = spec((k,), jnp.int32)

    sizes = {}
    sizes["train"] = lower_to_file(
        M.make_train_fn(mdef), (pf, x, y), os.path.join(out_dir, f"{mdef.name}.hlo.txt")
    )
    sizes["eval"] = lower_to_file(
        M.make_eval_fn(mdef),
        (pf, x, y),
        os.path.join(out_dir, f"{mdef.name}_eval.hlo.txt"),
    )
    sizes["compress"] = lower_to_file(
        M.make_compress_fn(mdef, dim),
        (mv, mv, beta),
        os.path.join(out_dir, f"{mdef.name}_compress.hlo.txt"),
    )
    sizes["apply"] = lower_to_file(
        M.make_apply_fn(mdef, dim),
        (mv, mv, idx, beta),
        os.path.join(out_dir, f"{mdef.name}_apply.hlo.txt"),
    )

    entry = {
        "dim": dim,
        "batch": mdef.batch,
        "chunk": mdef.chunk,
        "k": k,
        "train": f"{mdef.name}.hlo.txt",
        "eval": f"{mdef.name}_eval.hlo.txt",
        "compress": f"{mdef.name}_compress.hlo.txt",
        "apply": f"{mdef.name}_apply.hlo.txt",
        "init_params": f"{mdef.name}_init.bin",
        "x": {"shape": list(mdef.x_shape), "dtype": dtype_name(mdef.x_dtype)},
        "y": {"shape": list(mdef.y_shape), "dtype": "i32"},
        "layers": M.layer_partition(mdef),
        "stands_in_for": mdef.stands_in_for,
        "hlo_bytes": sizes,
    }
    return entry


def dtype_name(dt) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32"}[dt]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="all",
        help="comma-separated model names, or 'all'",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    reg = M.registry()
    names = list(reg) if args.models == "all" else args.models.split(",")
    # Merge with an existing manifest so partial re-lowering (e.g.
    # `--models cnn`) doesn't drop the other models' entries.
    man_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"version": 1, "models": {}}
    if os.path.exists(man_path):
        try:
            old = json.load(open(man_path))
            if old.get("version") == 1:
                manifest["models"].update(old.get("models", {}))
        except (json.JSONDecodeError, OSError):
            pass  # regenerate from scratch
    for name in names:
        if name not in reg:
            print(f"unknown model '{name}' (have: {', '.join(reg)})", file=sys.stderr)
            return 1
        print(f"[aot] lowering {name} ...", flush=True)
        manifest["models"][name] = build_model(reg[name], args.out_dir)
        print(
            f"[aot]   dim={manifest['models'][name]['dim']} "
            f"k={manifest['models'][name]['k']}",
            flush=True,
        )

    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {man_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
