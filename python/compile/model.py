"""L2 registry: every trainable model as `(params, x, y) -> (loss, ...)`
jax functions plus the metadata `aot.py` needs to lower them.

The registry dimensions mirror `rust/src/models/zoo.rs` exactly; the Rust
trainer validates the manifest against its zoo entry at load time.
"""

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.flatten_util
import jax.numpy as jnp

from compile.models import cnn, lstm, mlp, transformer


@dataclass
class ModelDef:
    name: str
    cfg: Dict[str, Any]
    init: Callable  # key -> params pytree
    loss_and_correct: Callable  # (params, x, y) -> (loss, correct)
    batch: int
    x_shape: Tuple[int, ...]  # includes batch dim
    x_dtype: Any
    y_shape: Tuple[int, ...]
    # default chunk size == compression rate for the compress artifact
    chunk: int = 100
    # per-sample FLOPs multiplier for matmul leaves (seq positions)
    seq_mult: int = 1
    stands_in_for: str = ""


def _mlp() -> ModelDef:
    cfg = {"feature_dim": 32, "classes": 10}
    return ModelDef(
        name="mlp",
        cfg=cfg,
        init=functools.partial(mlp.init_params, cfg=cfg),
        loss_and_correct=mlp.loss_and_correct,
        batch=32,
        x_shape=(32, 32),
        x_dtype=jnp.float32,
        y_shape=(32,),
        chunk=92,
        stands_in_for="ResNet34/CIFAR10",
    )


def _cnn() -> ModelDef:
    cfg = {"classes": 10, "side": 16}
    return ModelDef(
        name="cnn",
        cfg=cfg,
        init=functools.partial(cnn.init_params, cfg=cfg),
        loss_and_correct=functools.partial(cnn.loss_and_correct, side=16),
        batch=32,
        x_shape=(32, 256),
        x_dtype=jnp.float32,
        y_shape=(32,),
        chunk=112,
        seq_mult=196,  # ~H*W positions per conv application
        stands_in_for="ResNet18-50+MobileNetV2/ImageNet",
    )


def _transformer(name="transformer", vocab=32, seq=16, d=64, layers=2, ffn=128,
                 heads=4, batch=16, chunk=47, stands_in="Transformer-base/WMT14"):
    cfg = {"vocab": vocab, "seq": seq, "d_model": d, "layers": layers, "ffn": ffn}
    return ModelDef(
        name=name,
        cfg=cfg,
        init=functools.partial(transformer.init_params, cfg=cfg),
        loss_and_correct=functools.partial(transformer.loss_and_correct, heads=heads),
        batch=batch,
        x_shape=(batch, seq),
        x_dtype=jnp.int32,
        y_shape=(batch, seq),
        chunk=chunk,
        seq_mult=seq,
        stands_in_for=stands_in,
    )


def _lstm() -> ModelDef:
    cfg = {"feature_dim": 8, "hidden": 32, "classes": 6}
    seq = 12
    return ModelDef(
        name="lstm",
        cfg=cfg,
        init=functools.partial(lstm.init_params, cfg=cfg),
        loss_and_correct=functools.partial(
            lstm.loss_and_correct, seq=seq, feat=8, hidden=32
        ),
        batch=32,
        x_shape=(32, seq * 8),
        x_dtype=jnp.float32,
        y_shape=(32, seq),
        chunk=400,
        seq_mult=seq,
        stands_in_for="4-bi-LSTM/SWB300",
    )


def registry() -> Dict[str, ModelDef]:
    models = [
        _mlp(),
        _cnn(),
        _transformer(),
        _transformer(
            name="transformer-med",
            vocab=64,
            seq=32,
            d=128,
            layers=4,
            ffn=256,
            heads=4,
            batch=16,
            chunk=47,
            stands_in="Transformer-base/WMT14 (E2E driver)",
        ),
        _lstm(),
    ]
    return {m.name: m for m in models}


# ----------------------------------------------------------------------
# Flat-parameter plumbing
# ----------------------------------------------------------------------


def flat_init(mdef: ModelDef, seed: int = 0):
    """Initial parameters as (flat f32 vector, unravel fn)."""
    params = mdef.init(jax.random.PRNGKey(seed))
    flat, unravel = jax.flatten_util.ravel_pytree(params)
    return flat.astype(jnp.float32), unravel


def layer_partition(mdef: ModelDef) -> List[Dict[str, Any]]:
    """Flat-vector layer slices: (name, offset, len, flops_per_sample).

    Matmul-like leaves (ndim >= 2) get 2*prod(shape)*seq_mult FLOPs per
    sample; vectors (biases, LN scales) get 0, which makes the Rust
    per-layer rate rule fall back to the model default for them.
    """
    params = mdef.init(jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    offset = 0
    for path, leaf in leaves:
        name = "/".join(_path_str(p) for p in path)
        size = int(leaf.size)
        flops = 2.0 * size * mdef.seq_mult if leaf.ndim >= 2 else 0.0
        out.append(
            {
                "name": name,
                "offset": offset,
                "len": size,
                "flops_per_sample": flops,
                "compress": True,
            }
        )
        offset += size
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# ----------------------------------------------------------------------
# The four artifact functions per model
# ----------------------------------------------------------------------


def make_train_fn(mdef: ModelDef):
    """(params_flat, x, y) -> (loss, grads_flat)."""
    _, unravel = flat_init(mdef)

    def loss_only(pf, x, y):
        loss, _ = mdef.loss_and_correct(unravel(pf), x, y)
        return loss

    def train_step(pf, x, y):
        loss, grads = jax.value_and_grad(loss_only)(pf, x, y)
        return loss, grads

    return train_step


def make_eval_fn(mdef: ModelDef):
    """(params_flat, x, y) -> (loss, correct_count)."""
    _, unravel = flat_init(mdef)

    def eval_step(pf, x, y):
        return mdef.loss_and_correct(unravel(pf), x, y)

    return eval_step


def make_compress_fn(mdef: ModelDef, dim: int):
    """Leader-side CLT-k step on the L1 Pallas kernels:
    (m, g, beta) -> (idx, vals, m_next)."""
    from compile.kernels.chunk_topk import chunk_top1
    from compile.kernels.lowpass import lowpass_update

    chunk = mdef.chunk

    def compress(m, g, beta):
        ef = m + g
        idx, vals = chunk_top1(ef, chunk)
        mask = jnp.zeros((dim,), jnp.float32).at[idx].set(1.0)
        m_next = lowpass_update(m, g, mask, beta)
        return idx, vals, m_next

    return compress


def make_apply_fn(mdef: ModelDef, dim: int):
    """Follower-side CLT-k step: (m, g, idx, beta) -> (vals, m_next)."""
    from compile.kernels.lowpass import lowpass_update

    def apply(m, g, idx, beta):
        ef = m + g
        vals = jnp.take(ef, idx)
        mask = jnp.zeros((dim,), jnp.float32).at[idx].set(1.0)
        m_next = lowpass_update(m, g, mask, beta)
        return vals, m_next

    return apply
