"""L2 model zoo correctness: shapes, gradient plumbing, learnability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


REG = M.registry()


def fake_batch(mdef, seed=0):
    rng = np.random.default_rng(seed)
    if mdef.x_dtype == jnp.int32:
        vocab = mdef.cfg["vocab"]
        x = jnp.asarray(rng.integers(0, vocab, mdef.x_shape).astype(np.int32))
    else:
        x = jnp.asarray(rng.normal(size=mdef.x_shape).astype(np.float32))
    classes = mdef.cfg.get("classes", mdef.cfg.get("vocab"))
    y = jnp.asarray(rng.integers(0, classes, mdef.y_shape).astype(np.int32))
    return x, y


@pytest.mark.parametrize("name", list(REG))
def test_train_fn_shapes_and_finiteness(name):
    mdef = REG[name]
    flat, _ = M.flat_init(mdef)
    train = jax.jit(M.make_train_fn(mdef))
    x, y = fake_batch(mdef)
    loss, grads = train(flat, x, y)
    assert np.isfinite(float(loss))
    assert grads.shape == flat.shape
    assert np.isfinite(np.asarray(grads)).all()
    assert float(jnp.linalg.norm(grads)) > 0.0


@pytest.mark.parametrize("name", list(REG))
def test_eval_fn_correct_count_in_range(name):
    mdef = REG[name]
    flat, _ = M.flat_init(mdef)
    ev = jax.jit(M.make_eval_fn(mdef))
    x, y = fake_batch(mdef)
    loss, correct = ev(flat, x, y)
    assert np.isfinite(float(loss))
    n_preds = int(np.prod(mdef.y_shape))
    assert 0.0 <= float(correct) <= n_preds


@pytest.mark.parametrize("name", ["mlp", "cnn", "lstm", "transformer"])
def test_sgd_reduces_loss_on_fixed_batch(name):
    mdef = REG[name]
    flat, _ = M.flat_init(mdef)
    train = jax.jit(M.make_train_fn(mdef))
    x, y = fake_batch(mdef, seed=3)
    loss0, _ = train(flat, x, y)
    p = flat
    # the recurrent net needs a hotter LR and more steps to memorize
    # random frame labels; feedforward nets drop fast at lr=0.1
    lr, steps = {"lstm": (1.0, 100)}.get(name, (0.1, 50))
    for _ in range(steps):
        loss, g = train(p, x, y)
        p = p - lr * g
    loss_end, _ = train(p, x, y)
    assert float(loss_end) < float(loss0) * 0.9, (float(loss0), float(loss_end))


def test_initial_loss_near_uniform_for_classifier():
    mdef = REG["mlp"]
    flat, _ = M.flat_init(mdef)
    ev = jax.jit(M.make_eval_fn(mdef))
    x, y = fake_batch(mdef)
    loss, _ = ev(flat, x, y)
    assert abs(float(loss) - np.log(10)) < 1.0


def test_transformer_causality():
    """Changing a future token must not change past logits."""
    from compile.models import transformer as T

    cfg = {"vocab": 16, "seq": 8, "d_model": 32, "layers": 2, "ffn": 64}
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 16, (2, 8)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % 16  # perturb last position
    l1 = T.logits_fn(params, jnp.asarray(toks), heads=4)
    l2 = T.logits_fn(params, jnp.asarray(toks2), heads=4)
    np.testing.assert_allclose(
        np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))


def test_lstm_uses_both_directions():
    """Perturbing the last frame must change the first frame's logits
    (through the backward pass) — proves bidirectionality."""
    from compile.models import lstm as L

    cfg = {"feature_dim": 4, "hidden": 8, "classes": 3}
    params = L.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, 6 * 4)).astype(np.float32)
    x2 = x.copy()
    x2[:, -4:] += 1.0  # last frame
    l1 = L.logits_fn(params, jnp.asarray(x), seq=6, feat=4, hidden=8)
    l2 = L.logits_fn(params, jnp.asarray(x2), seq=6, feat=4, hidden=8)
    assert not np.allclose(np.asarray(l1[:, 0]), np.asarray(l2[:, 0]))


def test_layer_partition_contiguous_and_complete():
    for name, mdef in REG.items():
        layers = M.layer_partition(mdef)
        flat, _ = M.flat_init(mdef)
        offset = 0
        for l in layers:
            assert l["offset"] == offset, name
            assert l["len"] > 0
            offset += l["len"]
        assert offset == flat.shape[0], name


def test_flat_init_deterministic():
    a, _ = M.flat_init(REG["mlp"], seed=0)
    b, _ = M.flat_init(REG["mlp"], seed=0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c, _ = M.flat_init(REG["mlp"], seed=1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_registry_dims_match_rust_zoo():
    """These constants are mirrored in rust/src/models/zoo.rs — keep in sync."""
    assert REG["mlp"].batch == 32 and REG["mlp"].x_shape == (32, 32)
    assert REG["cnn"].x_shape == (32, 256)
    assert REG["transformer"].x_shape == (16, 16)
    assert REG["transformer"].cfg["vocab"] == 32
    assert REG["transformer-med"].x_shape == (16, 32)
    assert REG["transformer-med"].cfg["vocab"] == 64
    assert REG["lstm"].x_shape == (32, 96)
    assert REG["lstm"].y_shape == (32, 12)
