"""AOT pipeline: artifact emission, manifest schema, and the semantic
equivalence of the compress/apply artifact functions with the reference
compressor (the contract the Rust runtime relies on)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.kernels.ref import chunk_top1_ref, lowpass_update_ref, mask_from_indices_ref

REG = M.registry()


@pytest.fixture(scope="module")
def mlp_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.build_model(REG["mlp"], str(out))
    return out, entry


def test_artifacts_written(mlp_artifacts):
    out, entry = mlp_artifacts
    for key in ["train", "eval", "compress", "apply"]:
        path = out / entry[key]
        assert path.exists(), key
        text = path.read_text()
        assert text.startswith("HloModule"), f"{key} is not HLO text"
        assert len(text) > 1000
    init = out / entry["init_params"]
    assert init.stat().st_size == 4 * entry["dim"]


def test_manifest_schema(mlp_artifacts):
    _, entry = mlp_artifacts
    assert entry["dim"] > 0
    assert entry["k"] == -(-entry["dim"] // entry["chunk"])
    assert entry["x"]["dtype"] in ("f32", "i32")
    assert entry["y"]["dtype"] == "i32"
    offset = 0
    for l in entry["layers"]:
        assert l["offset"] == offset
        offset += l["len"]
    assert offset == entry["dim"]


def test_manifest_json_roundtrip(mlp_artifacts, tmp_path):
    _, entry = mlp_artifacts
    p = tmp_path / "m.json"
    with open(p, "w") as f:
        json.dump({"version": 1, "models": {"mlp": entry}}, f)
    loaded = json.load(open(p))
    assert loaded["models"]["mlp"]["dim"] == entry["dim"]


def test_compress_fn_matches_reference():
    """The lowered compress fn must equal ref-selection + ref-update."""
    mdef = REG["mlp"]
    flat, _ = M.flat_init(mdef)
    dim = int(flat.shape[0])
    compress = jax.jit(M.make_compress_fn(mdef, dim))
    rng = np.random.default_rng(5)
    m = jnp.asarray(rng.normal(size=dim).astype(np.float32))
    g = jnp.asarray(rng.normal(size=dim).astype(np.float32))
    idx, vals, m_next = compress(m, g, jnp.float32(0.1))
    ef = m + g
    ri, rv = chunk_top1_ref(ef, mdef.chunk)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-6)
    mask = mask_from_indices_ref(ri, dim)
    rm = lowpass_update_ref(m, g, mask, 0.1)
    np.testing.assert_allclose(np.asarray(m_next), np.asarray(rm), atol=1e-6)


def test_apply_fn_follows_leader_indices():
    """Follower path: values gathered at the *leader's* indices and the
    same low-pass memory update."""
    mdef = REG["mlp"]
    flat, _ = M.flat_init(mdef)
    dim = int(flat.shape[0])
    apply = jax.jit(M.make_apply_fn(mdef, dim))
    rng = np.random.default_rng(7)
    m = jnp.asarray(rng.normal(size=dim).astype(np.float32))
    g = jnp.asarray(rng.normal(size=dim).astype(np.float32))
    k = -(-dim // mdef.chunk)
    leader_idx = jnp.asarray(
        np.sort(rng.choice(dim, size=k, replace=False)).astype(np.int32)
    )
    vals, m_next = apply(m, g, leader_idx, jnp.float32(0.3))
    ef = np.asarray(m + g)
    np.testing.assert_allclose(np.asarray(vals), ef[np.asarray(leader_idx)], atol=1e-6)
    mask = mask_from_indices_ref(leader_idx, dim)
    rm = lowpass_update_ref(m, g, mask, 0.3)
    np.testing.assert_allclose(np.asarray(m_next), np.asarray(rm), atol=1e-6)


def test_commutativity_through_artifact_functions():
    """CLT-k Definition (1) through the *lowered* functions: averaging the
    per-worker sparsified values equals sparsifying the averaged EF
    gradient at the leader's indices."""
    mdef = REG["mlp"]
    flat, _ = M.flat_init(mdef)
    dim = int(flat.shape[0])
    compress = jax.jit(M.make_compress_fn(mdef, dim))
    apply = jax.jit(M.make_apply_fn(mdef, dim))
    rng = np.random.default_rng(11)
    n = 4
    ms = [jnp.asarray(rng.normal(size=dim).astype(np.float32)) for _ in range(n)]
    gs = [jnp.asarray(rng.normal(size=dim).astype(np.float32)) for _ in range(n)]
    idx, vals0, _ = compress(ms[0], gs[0], jnp.float32(1.0))
    avg_vals = np.asarray(vals0, dtype=np.float64)
    for i in range(1, n):
        vi, _ = apply(ms[i], gs[i], idx, jnp.float32(1.0))
        avg_vals += np.asarray(vi, dtype=np.float64)
    avg_vals /= n
    ef_avg = sum(np.asarray(m + g, dtype=np.float64) for m, g in zip(ms, gs)) / n
    np.testing.assert_allclose(avg_vals, ef_avg[np.asarray(idx)], atol=1e-5)


def test_dtype_name_mapping():
    assert aot.dtype_name(jnp.float32) == "f32"
    assert aot.dtype_name(jnp.int32) == "i32"
