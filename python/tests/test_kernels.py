"""L1 kernel correctness: Pallas vs pure-jnp reference (the core
correctness signal for the compression hot-spot), including hypothesis
sweeps over shapes, chunk sizes and discount factors."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.chunk_topk import chunk_top1, vmem_bytes_per_block
from compile.kernels.lowpass import lowpass_update
from compile.kernels.ref import (
    chunk_top1_ref,
    lowpass_update_ref,
    mask_from_indices_ref,
    sparsify_ref,
)


def _rand(p, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, p).astype(np.float32))


# ----------------------------------------------------------------------
# chunk_top1
# ----------------------------------------------------------------------


@pytest.mark.parametrize(
    "p,c",
    [(8, 4), (40, 4), (100, 7), (4000, 400), (37, 64), (8, 1), (65, 8), (1, 1), (5, 5)],
)
def test_chunk_top1_matches_ref(p, c):
    x = _rand(p, seed=p * 31 + c)
    ri, rv = chunk_top1_ref(x, c)
    ki, kv = chunk_top1(x, c)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(rv), np.asarray(kv))


@settings(max_examples=60, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=3000),
    c=st.integers(min_value=1, max_value=512),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_chunk_top1_hypothesis_sweep(p, c, seed):
    x = _rand(p, seed=seed)
    ri, rv = chunk_top1_ref(x, c)
    ki, kv = chunk_top1(x, c)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(rv), np.asarray(kv))


def test_chunk_top1_output_size_is_rate():
    x = _rand(4000)
    idx, vals = chunk_top1(x, 400)
    assert idx.shape == (10,) and vals.shape == (10,)


def test_chunk_top1_selects_argmax_per_chunk():
    x = jnp.asarray([1.0, -3.0, 2.0, 0.5, 0.1, -0.2, 9.0, 0.0], jnp.float32)
    idx, vals = chunk_top1(x, 4)
    assert list(np.asarray(idx)) == [1, 6]
    assert list(np.asarray(vals)) == [-3.0, 9.0]


def test_chunk_top1_tie_prefers_lowest_index():
    x = jnp.asarray([2.0, -2.0, 1.0, 0.0], jnp.float32)
    idx, _ = chunk_top1(x, 4)
    assert int(idx[0]) == 0


def test_chunk_top1_all_zero_input():
    x = jnp.zeros((16,), jnp.float32)
    idx, vals = chunk_top1(x, 4)
    assert list(np.asarray(idx)) == [0, 4, 8, 12]
    assert np.all(np.asarray(vals) == 0.0)


def test_chunk_top1_padding_never_wins():
    # last chunk has 1 real element (0.0) + 3 padded: real must win
    x = jnp.asarray([5.0, 1.0, 1.0, 1.0, 0.0], jnp.float32)
    idx, vals = chunk_top1(x, 4)
    assert list(np.asarray(idx)) == [0, 4]
    assert int(idx[1]) < 5  # never an out-of-range padded index


def test_chunk_top1_indices_within_range():
    for p in [3, 17, 63, 1000]:
        x = _rand(p, seed=p)
        idx, _ = chunk_top1(x, 8)
        assert np.all(np.asarray(idx) < p)


def test_vmem_estimate_reasonable():
    # single block stays far below the ~16 MiB VMEM of a TPU core
    assert vmem_bytes_per_block(512) < 1 << 20


# ----------------------------------------------------------------------
# lowpass_update
# ----------------------------------------------------------------------


@pytest.mark.parametrize("p", [1, 10, 4095, 4096, 4097, 20000])
@pytest.mark.parametrize("beta", [0.1, 0.3, 1.0])
def test_lowpass_matches_ref(p, beta):
    m = _rand(p, seed=p)
    g = _rand(p, seed=p + 1)
    rng = np.random.default_rng(p + 2)
    sel = jnp.asarray((rng.random(p) < 0.2).astype(np.float32))
    r = lowpass_update_ref(m, g, sel, beta)
    k = lowpass_update(m, g, sel, jnp.float32(beta))
    np.testing.assert_allclose(np.asarray(r), np.asarray(k), atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=9000),
    beta=st.floats(min_value=0.01, max_value=1.0),
    frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_lowpass_hypothesis_sweep(p, beta, frac, seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=p).astype(np.float32))
    g = jnp.asarray(rng.normal(size=p).astype(np.float32))
    sel = jnp.asarray((rng.random(p) < frac).astype(np.float32))
    r = lowpass_update_ref(m, g, sel, beta)
    k = lowpass_update(m, g, sel, jnp.float32(beta))
    np.testing.assert_allclose(np.asarray(r), np.asarray(k), atol=1e-5)


def test_lowpass_beta1_is_classic_error_feedback():
    # beta=1: selected coordinates zero out, unselected accumulate fully.
    m = jnp.asarray([1.0, 2.0], jnp.float32)
    g = jnp.asarray([0.5, 0.5], jnp.float32)
    sel = jnp.asarray([1.0, 0.0], jnp.float32)
    out = lowpass_update(m, g, sel, jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(out), [0.0, 2.5], atol=1e-7)


def test_lowpass_selected_decay_formula():
    # selected coordinate: m' = (1-beta)*m_old
    m = jnp.asarray([4.0], jnp.float32)
    g = jnp.asarray([1.0], jnp.float32)
    sel = jnp.asarray([1.0], jnp.float32)
    out = lowpass_update(m, g, sel, jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(out), [3.0], atol=1e-6)


# ----------------------------------------------------------------------
# composition: leader select + sparsify + memory update
# ----------------------------------------------------------------------


def test_clt_step_composition_conserves_when_beta1():
    """m' + scatter(sent) == m + g when beta=1 (error-feedback identity)."""
    p, c = 1000, 50
    m = _rand(p, 1)
    g = _rand(p, 2)
    ef = m + g
    idx, vals = chunk_top1(ef, c)
    np.testing.assert_allclose(
        np.asarray(vals), np.asarray(sparsify_ref(ef, idx)), atol=1e-6
    )
    mask = mask_from_indices_ref(idx, p)
    m_next = lowpass_update(m, g, mask, jnp.float32(1.0))
    recon = np.asarray(m_next).copy()
    recon[np.asarray(idx)] += np.asarray(vals)
    np.testing.assert_allclose(recon, np.asarray(ef), atol=1e-5)
