//! End-to-end serve-plane tests: a real daemon on loopback ports, real
//! framed TCP clients, a real `/metrics` scrape — the acceptance
//! criteria of the serve subsystem, in-process.
//!
//! Everything binds port 0, so the tests are parallel-safe and need no
//! fixed ports free.

use scalecom::comm::codec::WireCodecConfig;
use scalecom::comm::parallel::LaneTransport;
use scalecom::comm::wire::{self, Purpose, WireMsg, WIRE_CODEC_VERSION};
use scalecom::runtime::socket::{compare_digests, parse_digest};
use scalecom::serve::protocol::parse_spec;
use scalecom::serve::{run_local, ClientConn, Daemon, ServeConfig, SubmitOutcome};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

const CONNECT: Duration = Duration::from_secs(5);

fn daemon(
    workers: usize,
    transport: LaneTransport,
    max_queue: usize,
    max_concurrent: usize,
) -> Daemon {
    Daemon::start(&ServeConfig {
        bind: "127.0.0.1:0".into(),
        metrics_bind: "127.0.0.1:0".into(),
        workers,
        group_size: 0,
        transport,
        max_queue,
        max_concurrent,
        metrics_job_retention: 64,
    })
    .expect("daemon start")
}

/// Poll the daemon's summary line until it contains `needle`.
fn wait_stats(c: &mut ClientConn, needle: &str) -> String {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let text = c.query_stats(0).expect("stats round-trip");
        if text.contains(needle) {
            return text;
        }
        assert!(
            Instant::now() < deadline,
            "timed out waiting for '{needle}'; last: {text}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn two_concurrent_jobs_on_shared_lanes_match_one_shot_digests() {
    // The real thing: socket-transport lanes, two tenants with
    // different schemes running concurrently on ONE mesh.
    let d = daemon(2, LaneTransport::Socket(WireCodecConfig::default()), 8, 2);
    let addr = d.control_addr();
    let specs = [
        "scheme=scalecom dim=96 rate=8 steps=6 warmup=1 seed=11",
        "scheme=local-topk dim=64 rate=4 steps=6 seed=7",
    ];
    let outcomes: Vec<(&str, SubmitOutcome, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = specs
            .iter()
            .map(|&spec| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = ClientConn::connect(&addr, CONNECT).expect("connect");
                    let mut log = Vec::new();
                    let out = c.submit(spec, true, &mut log).expect("submit");
                    (spec, out, String::from_utf8(log).expect("utf8 log"))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect()
    });
    let mut ids = Vec::new();
    for (spec, out, log) in &outcomes {
        let SubmitOutcome::Done { job, digest } = out else {
            panic!("{spec}: expected Done, got {out:?}");
        };
        ids.push(*job);
        assert!(
            !digest.starts_with("error:"),
            "{spec}: served job failed: {digest}"
        );
        assert!(log.contains(&format!("accepted job={job}")), "{log}");
        assert!(
            log.contains(&format!("progress job={job} step=6/6")),
            "per-step progress must stream to the client:\n{log}"
        );
        // Acceptance: the served digest is bit-identical to the one-shot
        // run of the same spec (shared code path, same mesh width).
        let wl = parse_spec(spec).expect("spec parses");
        let local = run_local(&wl, 2).expect("one-shot run");
        assert_eq!(
            digest, &local,
            "{spec}: served digest drifted from the one-shot run"
        );
        compare_digests(
            &parse_digest(digest).expect("served digest parses"),
            &parse_digest(&local).expect("local digest parses"),
            0.0,
            0.0,
        )
        .expect("structural digest parity");
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 2, "concurrent jobs must get distinct ids");
    assert_eq!(
        d.shutdown(),
        None,
        "a multi-tenant run must leave no latched lane fault"
    );
}

#[test]
fn metrics_scrape_over_tcp_reports_queue_and_job_series() {
    let d = daemon(2, LaneTransport::Channel, 8, 2);
    let mut c = ClientConn::connect(&d.control_addr(), CONNECT).expect("connect");
    let out = c
        .submit("scheme=scalecom steps=4 seed=3", true, &mut Vec::<u8>::new())
        .expect("submit");
    assert!(matches!(out, SubmitOutcome::Done { .. }), "{out:?}");
    let mut s = TcpStream::connect(d.metrics_addr()).expect("metrics connect");
    s.write_all(b"GET /metrics HTTP/1.0\r\nHost: test\r\n\r\n")
        .expect("request");
    let mut body = String::new();
    s.read_to_string(&mut body).expect("response");
    assert!(body.starts_with("HTTP/1.0 200 OK\r\n"), "{body}");
    for needle in [
        "Content-Type: text/plain; version=0.0.4",
        "scalecom_serve_queue_depth 0",
        "scalecom_serve_running 0",
        "scalecom_serve_jobs_submitted_total 1",
        "scalecom_serve_jobs_completed_total 1",
        "scalecom_serve_scheduler_wait_seconds_count 1",
        "scalecom_job_steps_total{job=\"1\",scheme=\"scalecom\",state=\"done\"} 4",
        "scalecom_job_comm_bytes_total{job=\"1\",direction=\"up\"}",
        "scalecom_serve_lane_faulted 0",
    ] {
        assert!(body.contains(needle), "missing '{needle}' in scrape:\n{body}");
    }
    // Any other path 404s instead of dumping metrics.
    let mut s = TcpStream::connect(d.metrics_addr()).expect("metrics connect");
    s.write_all(b"GET /nope HTTP/1.0\r\n\r\n").expect("request");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("response");
    assert!(resp.starts_with("HTTP/1.0 404"), "{resp}");
    assert_eq!(d.shutdown(), None);
}

#[test]
fn queue_overflow_rejects_typed_and_cancel_hits_both_states() {
    // One slot, one queue position: the third tenant must bounce with
    // the typed backpressure reason, not an error or a hang.
    let d = daemon(2, LaneTransport::Channel, 1, 1);
    let addr = d.control_addr();
    let slow = "scheme=scalecom steps=200 step-delay-ms=20 seed=1";
    // The submitting connections keep receiving streamed JobProgress
    // frames even un-followed, so stats and cancels go through a
    // dedicated control connection with a quiet stream.
    let mut ctl = ClientConn::connect(&addr, CONNECT).expect("control connect");
    let mut c1 = ClientConn::connect(&addr, CONNECT).expect("connect 1");
    let SubmitOutcome::Done { job: j1, .. } =
        c1.submit(slow, false, &mut Vec::<u8>::new()).expect("submit 1")
    else {
        panic!("job 1 not admitted");
    };
    // Make sure job 1 actually occupies the running slot before filling
    // the queue, so admission order is deterministic.
    wait_stats(&mut ctl, "running=1");
    let mut c2 = ClientConn::connect(&addr, CONNECT).expect("connect 2");
    let SubmitOutcome::Done { job: j2, .. } =
        c2.submit(slow, false, &mut Vec::<u8>::new()).expect("submit 2")
    else {
        panic!("job 2 not admitted");
    };
    let mut c3 = ClientConn::connect(&addr, CONNECT).expect("connect 3");
    match c3.submit(slow, false, &mut Vec::<u8>::new()).expect("submit 3") {
        SubmitOutcome::Rejected(reason) => {
            assert!(
                reason.contains("queue full (depth 1/1)"),
                "typed backpressure reason, got: {reason}"
            );
        }
        other => panic!("expected a typed rejection, got {other:?}"),
    }
    // Cancel both states: queued → dequeued (0), running → signalled (1).
    assert_eq!(ctl.cancel(j2).expect("cancel queued"), 0);
    assert_eq!(ctl.cancel(j1).expect("cancel running"), 1);
    // The running job acknowledges at its next step boundary.
    wait_stats(&mut ctl, "running=0");
    let table = ctl.query_stats(1).expect("job table");
    assert!(table.contains(&format!("job={j1} state=cancelled")), "{table}");
    assert!(table.contains(&format!("job={j2} state=cancelled")), "{table}");
    // An unknown id is refused, not invented.
    let err = ctl.cancel(9999).expect_err("unknown job");
    assert!(err.to_string().contains("unknown or already finished"), "{err:#}");
    assert_eq!(d.shutdown(), None);
}

#[test]
fn admission_storm_survives_concurrent_dispatch() {
    // Regression: admission and the JobState insert used to live in
    // separate lock scopes, so a completing job's re-dispatch could pop
    // a just-admitted id before its state entry existed and panic,
    // poisoning the jobs mutex and wedging the daemon. Hammer exactly
    // that interleaving — tiny jobs completing (and re-dispatching)
    // while new ones are admitted from several connections at once.
    let d = daemon(2, LaneTransport::Channel, 16, 2);
    let addr = d.control_addr();
    let outcomes: Vec<SubmitOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = ClientConn::connect(&addr, CONNECT).expect("connect");
                    let mut seen = Vec::new();
                    for i in 0..6 {
                        let spec =
                            format!("scheme=scalecom dim=32 rate=4 steps=1 seed={}", t * 10 + i);
                        seen.push(
                            c.submit(&spec, true, &mut Vec::<u8>::new()).expect("submit"),
                        );
                    }
                    seen
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect()
    });
    for out in &outcomes {
        match out {
            SubmitOutcome::Done { digest, .. } => {
                assert!(!digest.starts_with("error:"), "served job failed: {digest}");
            }
            // Backpressure is a legal answer under the storm — but only
            // the typed one.
            SubmitOutcome::Rejected(reason) => {
                assert!(reason.contains("queue full"), "{reason}");
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    // The daemon is still healthy afterwards: the jobs mutex was never
    // poisoned, stats answer, and shutdown drains without a fault.
    let mut c = ClientConn::connect(&addr, CONNECT).expect("post-storm connect");
    let stats = c.query_stats(0).expect("stats after the storm");
    assert!(stats.contains("running="), "{stats}");
    assert_eq!(d.shutdown(), None);
}

#[test]
fn mid_run_shutdown_drains_cleanly_with_no_lane_fault() {
    // Satellite: drained shutdown closes the socket mesh with EOFs, not
    // RSTs — observable as the absence of a latched lane fault.
    let d = daemon(3, LaneTransport::Socket(WireCodecConfig::default()), 4, 2);
    let addr = d.control_addr();
    let mut ctl = ClientConn::connect(&addr, CONNECT).expect("control connect");
    let mut c = ClientConn::connect(&addr, CONNECT).expect("connect");
    let out = c
        .submit(
            "scheme=scalecom steps=500 step-delay-ms=20 seed=2",
            false,
            &mut Vec::<u8>::new(),
        )
        .expect("submit");
    assert!(matches!(out, SubmitOutcome::Done { .. }), "{out:?}");
    // The submitting conn keeps receiving progress frames; poll from a
    // quiet control connection instead.
    wait_stats(&mut ctl, "running=1");
    assert!(d.lane_fault().is_none(), "healthy before the drain");
    // Shutdown mid-run: the job is signalled, stops at its next step
    // boundary, every thread joins, the mesh tears down cleanly.
    assert_eq!(
        d.shutdown(),
        None,
        "drained shutdown must leave no latched lane fault"
    );
}

#[test]
fn bad_specs_and_foreign_hellos_bounce_typed() {
    let d = daemon(2, LaneTransport::Channel, 4, 1);
    let addr = d.control_addr();
    let mut c = ClientConn::connect(&addr, CONNECT).expect("connect");
    match c.submit("frobnicate=1", true, &mut Vec::<u8>::new()).expect("submit") {
        SubmitOutcome::Rejected(reason) => {
            assert!(reason.contains("bad job spec"), "{reason}");
            assert!(reason.contains("unknown spec key"), "{reason}");
        }
        other => panic!("expected a typed rejection, got {other:?}"),
    }
    // The connection survives a rejection and still answers stats.
    let stats = c.query_stats(0).expect("stats after rejection");
    assert!(stats.contains("rejected=1"), "{stats}");
    // A mesh-purpose hello on the client plane is version-gated away.
    let mut s = TcpStream::connect(addr).expect("raw connect");
    wire::write_msg(
        &mut s,
        &WireMsg::Hello {
            rank: 0,
            purpose: Purpose::Ring,
            codec: WIRE_CODEC_VERSION,
        },
    )
    .expect("hello");
    match wire::read_msg(&mut s).expect("gate reply") {
        WireMsg::JobRejected { reason } => {
            assert!(reason.contains("client hello"), "{reason}");
        }
        other => panic!("expected JobRejected, got {other:?}"),
    }
    assert_eq!(d.shutdown(), None);
}
