//! Multi-process socket-transport lock: N real `scalecom node` processes
//! on localhost must reproduce the sequential backend's coordination
//! exactly — and fail *cleanly* when a process dies.
//!
//! - **Parity**: a 4-process ring (1 coordinator + 3 workers) runs the
//!   synthetic workload per scheme family; the coordinator's digest
//!   (selections, leaders, reduced values, per-step `CommCost` booked
//!   through `Fabric::record_*`) is parsed from its stdout and held to
//!   `runtime::socket::sequential_digest` under the backend parity
//!   contract: selections/`CommCost` exact, gather values bit-identical,
//!   ring f32 within rtol 1e-5 / atol 1e-6. The same lock runs once
//!   more with `--group-size 2` (2 groups × 2 workers), so the
//!   hierarchical ring-of-rings exchange is held to the flat reference
//!   over real processes too.
//! - **Fault injection**: kill one worker process mid-run; the
//!   coordinator must exit non-zero with a clean `anyhow` error on
//!   stderr within a bounded timeout — a dead peer may never hang the
//!   ring.
//!
//! Every child is spawned from `CARGO_BIN_EXE_scalecom` and hard-killed
//! on drop, so a failing assertion cannot leak processes into CI.

use scalecom::runtime::socket::{compare_digests, parse_digest, sequential_digest, NodeWorkload};
use std::io::{BufRead, BufReader, Read};
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_scalecom")
}

/// Reserve `k` distinct loopback ports by binding and releasing them.
fn free_addrs(k: usize) -> Vec<String> {
    let listeners: Vec<TcpListener> = (0..k)
        .map(|_| TcpListener::bind(("127.0.0.1", 0)).expect("reserve port"))
        .collect();
    listeners
        .iter()
        .map(|l| l.local_addr().expect("addr").to_string())
        .collect()
}

/// Children that are guaranteed dead after the test, pass or fail.
struct Cluster {
    children: Vec<Child>,
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn spawn_node(peers: &[String], rank: usize, wl: &NodeWorkload, timeout_secs: u64) -> Child {
    spawn_node_with(peers, rank, wl, timeout_secs, &[])
}

fn spawn_node_with(
    peers: &[String],
    rank: usize,
    wl: &NodeWorkload,
    timeout_secs: u64,
    extra: &[&str],
) -> Child {
    let mut cmd = Command::new(bin());
    cmd.arg("node")
        .arg("--role")
        .arg(if rank == 0 { "coordinator" } else { "worker" })
        .arg("--bind")
        .arg(&peers[rank])
        .arg("--peers")
        .arg(peers.join(","))
        .arg("--scheme")
        .arg(&wl.scheme)
        .arg("--dim")
        .arg(wl.dim.to_string())
        .arg("--rate")
        .arg(wl.rate.to_string())
        .arg("--steps")
        .arg(wl.steps.to_string())
        .arg("--compress-warmup")
        .arg(wl.warmup.to_string())
        .arg("--seed")
        .arg(wl.seed.to_string())
        .arg("--beta")
        .arg(wl.beta.to_string())
        .arg("--topology")
        .arg("ring")
        .arg("--step-delay-ms")
        .arg(wl.step_delay_ms.to_string())
        .arg("--timeout-secs")
        .arg(timeout_secs.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    for a in extra {
        cmd.arg(a);
    }
    cmd.spawn().expect("spawn scalecom node")
}

/// Drain a child's stdout on a thread (a full pipe must never stall the
/// run) and return a handle that yields the full text.
fn capture_stdout(child: &mut Child) -> std::thread::JoinHandle<String> {
    let stdout = child.stdout.take().expect("piped stdout");
    std::thread::spawn(move || {
        let mut s = String::new();
        let _ = BufReader::new(stdout).read_to_string(&mut s);
        s
    })
}

fn capture_stderr(child: &mut Child) -> std::thread::JoinHandle<String> {
    let stderr = child.stderr.take().expect("piped stderr");
    std::thread::spawn(move || {
        let mut s = String::new();
        let _ = BufReader::new(stderr).read_to_string(&mut s);
        s
    })
}

fn wait_with_deadline(child: &mut Child, deadline: Instant, what: &str) -> std::process::ExitStatus {
    loop {
        if let Some(status) = child.try_wait().expect("try_wait") {
            return status;
        }
        assert!(
            Instant::now() < deadline,
            "{what}: still running at the deadline — the socket runtime hung"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Launch a 4-process localhost ring for `wl`, assert every process
/// exits cleanly, and return the coordinator's stdout.
fn run_cluster(wl: &NodeWorkload) -> String {
    run_cluster_with(wl, &[])
}

/// [`run_cluster`] with extra per-node CLI flags (every rank gets the
/// same flags — e.g. `--group-size` must match across the mesh).
fn run_cluster_with(wl: &NodeWorkload, extra: &[&str]) -> String {
    let n = 4;
    let peers = free_addrs(n);
    let mut cluster = Cluster {
        children: (0..n)
            .map(|rank| spawn_node_with(&peers, rank, wl, 60, extra))
            .collect(),
    };
    let outputs: Vec<std::thread::JoinHandle<String>> = cluster
        .children
        .iter_mut()
        .map(capture_stdout)
        .collect();
    let errs: Vec<std::thread::JoinHandle<String>> = cluster
        .children
        .iter_mut()
        .map(capture_stderr)
        .collect();
    let deadline = Instant::now() + Duration::from_secs(120);
    let statuses: Vec<_> = cluster
        .children
        .iter_mut()
        .enumerate()
        .map(|(rank, c)| wait_with_deadline(c, deadline, &format!("rank {rank}")))
        .collect();
    let outputs: Vec<String> = outputs.into_iter().map(|h| h.join().expect("reader")).collect();
    let errs: Vec<String> = errs.into_iter().map(|h| h.join().expect("reader")).collect();
    for (rank, status) in statuses.iter().enumerate() {
        assert!(
            status.success(),
            "rank {rank} failed ({status}): stderr:\n{}",
            errs[rank]
        );
    }
    outputs.into_iter().next().expect("coordinator stdout")
}

#[test]
fn four_process_ring_matches_sequential_digest_shared_path() {
    // CLT-k with a dense warmup: covers the dense all-reduce, the leader
    // index broadcast, and the shared-index sparse ring reduce.
    let wl = NodeWorkload {
        steps: 40,
        warmup: 5,
        ..NodeWorkload::default()
    };
    let stdout = run_cluster(&wl);
    let got = parse_digest(&stdout).expect("coordinator digest");
    let want = sequential_digest(&wl, 4).expect("sequential reference");
    compare_digests(&got, &want, 1e-5, 1e-6)
        .unwrap_or_else(|e| panic!("multi-process vs sequential: {e:#}\n---\n{stdout}"));
}

#[test]
fn four_process_ring_matches_sequential_digest_gather_path() {
    // Local top-k: per-worker selections, star gather at the
    // coordinator, gradient build-up accounting.
    let wl = NodeWorkload {
        scheme: "local-topk".into(),
        steps: 30,
        ..NodeWorkload::default()
    };
    let stdout = run_cluster(&wl);
    let got = parse_digest(&stdout).expect("coordinator digest");
    let want = sequential_digest(&wl, 4).expect("sequential reference");
    compare_digests(&got, &want, 1e-5, 1e-6)
        .unwrap_or_else(|e| panic!("multi-process vs sequential: {e:#}\n---\n{stdout}"));
}

#[test]
fn four_process_ring_matches_sequential_digest_dense() {
    let wl = NodeWorkload {
        scheme: "none".into(),
        steps: 25,
        ..NodeWorkload::default()
    };
    let stdout = run_cluster(&wl);
    let got = parse_digest(&stdout).expect("coordinator digest");
    let want = sequential_digest(&wl, 4).expect("sequential reference");
    compare_digests(&got, &want, 1e-5, 1e-6)
        .unwrap_or_else(|e| panic!("multi-process vs sequential: {e:#}\n---\n{stdout}"));
}

#[test]
fn four_process_hier_ring_matches_sequential_digest() {
    // 2 groups × 2 workers (`--group-size 2`): the dense warmup
    // all-reduce, the CLT-k index broadcast, and the shared-index sparse
    // ring reduce all run the two-level intra/uplink/broadcast exchange
    // over real processes — digest-locked to the flat sequential
    // reference under the standard parity contract.
    let wl = NodeWorkload {
        steps: 30,
        warmup: 4,
        ..NodeWorkload::default()
    };
    let stdout = run_cluster_with(&wl, &["--group-size", "2"]);
    let got = parse_digest(&stdout).expect("coordinator digest");
    let want = sequential_digest(&wl, 4).expect("sequential reference");
    compare_digests(&got, &want, 1e-5, 1e-6)
        .unwrap_or_else(|e| panic!("multi-process hier vs sequential: {e:#}\n---\n{stdout}"));
}

#[test]
fn killed_worker_fails_the_coordinator_cleanly_without_hanging() {
    // A run long enough (step delay × steps ≈ 7 min) that it cannot
    // finish before we kill a worker; short socket timeouts so the
    // bounded-failure claim is actually exercised.
    let wl = NodeWorkload {
        steps: 200_000,
        step_delay_ms: 2,
        ..NodeWorkload::default()
    };
    let n = 4;
    let peers = free_addrs(n);
    let mut cluster = Cluster {
        children: (0..n).map(|rank| spawn_node(&peers, rank, &wl, 15)).collect(),
    };
    // Stream the coordinator's stdout line by line so we can kill a
    // worker only once the run is demonstrably mid-flight.
    let stdout = cluster.children[0].stdout.take().expect("piped stdout");
    let (line_tx, line_rx) = mpsc::channel::<String>();
    let reader = std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            match line {
                Ok(l) => {
                    if line_tx.send(l).is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    });
    let stderr_handle = capture_stderr(&mut cluster.children[0]);

    let start = Instant::now();
    let mut steps_seen = 0;
    while steps_seen < 3 {
        match line_rx.recv_timeout(Duration::from_secs(30)) {
            Ok(line) => {
                if line.starts_with("step ") {
                    steps_seen += 1;
                }
            }
            Err(_) => panic!(
                "coordinator produced no step lines within 30s of {:?}",
                start.elapsed()
            ),
        }
    }

    // Kill worker rank 2 mid-run. Its sockets close; the failure must
    // propagate around the ring to the coordinator as a clean error.
    cluster.children[2].kill().expect("kill worker 2");
    let _ = cluster.children[2].wait();

    let deadline = Instant::now() + Duration::from_secs(45);
    let status = wait_with_deadline(&mut cluster.children[0], deadline, "coordinator after kill");
    assert!(
        !status.success(),
        "coordinator must fail when a worker dies mid-run"
    );
    let stderr = stderr_handle.join().expect("stderr reader");
    assert!(
        stderr.contains("error:"),
        "coordinator must surface a clean error, got stderr:\n{stderr}"
    );
    drop(reader); // detached: the pipe closes with the child
}

#[test]
fn killed_worker_rejoins_and_digest_matches_fault_free_run_bit_exactly() {
    // The reconnect-with-resume determinism contract, end to end over
    // real processes: SIGKILL one worker mid-run, relaunch it with the
    // same command line, and the coordinator's digest must come out
    // *bit-identical* to a fault-free run of the same cluster (and
    // still within the parity tolerances of the sequential reference).
    let wl = NodeWorkload {
        steps: 30,
        warmup: 3,
        step_delay_ms: 50,
        ..NodeWorkload::default()
    };
    let n = 4;
    let scratch =
        std::env::temp_dir().join(format!("scalecom_mp_rejoin_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    let snap_clean = scratch.join("clean");
    let snap_faulted = scratch.join("faulted");
    std::fs::create_dir_all(&snap_clean).expect("scratch dir");
    std::fs::create_dir_all(&snap_faulted).expect("scratch dir");
    let flags = |dir: &std::path::Path| -> Vec<String> {
        vec![
            "--heartbeat-ms".into(),
            "100".into(),
            "--reconnect".into(),
            "--snapshot-dir".into(),
            dir.display().to_string(),
        ]
    };

    // Fault-free reference with the identical fault-tolerance flags
    // (heartbeats and snapshots on, nobody dies).
    let want = {
        let extra = flags(&snap_clean);
        let extra: Vec<&str> = extra.iter().map(String::as_str).collect();
        let peers = free_addrs(n);
        let mut cluster = Cluster {
            children: (0..n)
                .map(|rank| spawn_node_with(&peers, rank, &wl, 20, &extra))
                .collect(),
        };
        let outs: Vec<_> = cluster.children.iter_mut().map(capture_stdout).collect();
        let errs: Vec<_> = cluster.children.iter_mut().map(capture_stderr).collect();
        let deadline = Instant::now() + Duration::from_secs(120);
        let statuses: Vec<_> = cluster
            .children
            .iter_mut()
            .enumerate()
            .map(|(rank, c)| wait_with_deadline(c, deadline, &format!("clean rank {rank}")))
            .collect();
        let outs: Vec<String> = outs.into_iter().map(|h| h.join().expect("reader")).collect();
        let errs: Vec<String> = errs.into_iter().map(|h| h.join().expect("reader")).collect();
        for (rank, status) in statuses.iter().enumerate() {
            assert!(
                status.success(),
                "clean rank {rank} failed ({status}):\n{}",
                errs[rank]
            );
        }
        parse_digest(&outs[0]).expect("fault-free digest")
    };

    // Faulted run: stream the coordinator's stdout, kill worker 2 once
    // the run is demonstrably mid-flight, relaunch it immediately.
    let extra = flags(&snap_faulted);
    let extra_refs: Vec<&str> = extra.iter().map(String::as_str).collect();
    let peers = free_addrs(n);
    let mut cluster = Cluster {
        children: (0..n)
            .map(|rank| spawn_node_with(&peers, rank, &wl, 20, &extra_refs))
            .collect(),
    };
    let stdout = cluster.children[0].stdout.take().expect("piped stdout");
    let (line_tx, line_rx) = mpsc::channel::<String>();
    let reader = std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            match line {
                Ok(l) => {
                    if line_tx.send(l).is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    });
    let coord_err = capture_stderr(&mut cluster.children[0]);
    let mut side_outs: Vec<_> =
        cluster.children.iter_mut().skip(1).map(capture_stdout).collect();
    let mut side_errs: Vec<_> =
        cluster.children.iter_mut().skip(1).map(capture_stderr).collect();

    let mut lines: Vec<String> = Vec::new();
    let mut steps_seen = 0;
    while steps_seen < 5 {
        match line_rx.recv_timeout(Duration::from_secs(30)) {
            Ok(line) => {
                if line.starts_with("step ") {
                    steps_seen += 1;
                }
                lines.push(line);
            }
            Err(_) => panic!("coordinator produced no step lines within 30s"),
        }
    }
    cluster.children[2].kill().expect("kill worker 2");
    let _ = cluster.children[2].wait();
    let mut rejoined = spawn_node_with(&peers, 2, &wl, 20, &extra_refs);
    side_outs.push(capture_stdout(&mut rejoined));
    side_errs.push(capture_stderr(&mut rejoined));
    cluster.children.push(rejoined);

    // Drain the coordinator to completion.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        match line_rx.recv_timeout(Duration::from_millis(200)) {
            Ok(line) => lines.push(line),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                assert!(
                    Instant::now() < deadline,
                    "coordinator hung after the kill+rejoin"
                );
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    reader.join().expect("reader");
    let status = wait_with_deadline(
        &mut cluster.children[0],
        Instant::now() + Duration::from_secs(30),
        "coordinator after rejoin",
    );
    let coord_err = coord_err.join().expect("stderr reader");
    assert!(status.success(), "coordinator failed ({status}):\n{coord_err}");
    // Survivors (ranks 1, 3) and the relaunched worker must all finish
    // cleanly; the killed original (index 2) is expected dead.
    for idx in [1usize, 3, 4] {
        let status = wait_with_deadline(
            &mut cluster.children[idx],
            Instant::now() + Duration::from_secs(30),
            &format!("child {idx} after rejoin"),
        );
        assert!(status.success(), "child {idx} failed ({status})");
    }
    for h in side_outs {
        let _ = h.join();
    }
    for h in side_errs {
        let _ = h.join();
    }

    let stdout = lines.join("\n");
    assert!(
        stdout.contains("health degraded"),
        "no recovery wave in coordinator output:\n{stdout}"
    );
    assert!(
        stdout.contains("resume from="),
        "no resume agreement in coordinator output:\n{stdout}"
    );
    let got = parse_digest(&stdout).expect("faulted digest");
    // Bit-identical to the fault-free run — the rollback+replay
    // determinism contract.
    compare_digests(&got, &want, 0.0, 0.0)
        .unwrap_or_else(|e| panic!("kill+rejoin vs fault-free: {e:#}\n---\n{stdout}"));
    // And still within the backend parity contract of the sequential
    // reference.
    let seq = sequential_digest(&wl, n).expect("sequential reference");
    compare_digests(&got, &seq, 1e-5, 1e-6)
        .unwrap_or_else(|e| panic!("kill+rejoin vs sequential: {e:#}\n---\n{stdout}"));
    let _ = std::fs::remove_dir_all(&scratch);
}
