//! Backend-matrix parity lock: every concurrent backend — `threaded`
//! (scoped thread-per-worker, per-step channel mesh), `pipelined`
//! (persistent double-buffering worker pool), and `socket` (the same
//! pool with every collective hop crossing a loopback TCP socket
//! through the wire codec) — must be indistinguishable from the
//! sequential reference across every compression scheme, worker count,
//! and step. (The multi-process socket deployment is parity-locked
//! separately, against real processes, in
//! `rust/tests/socket_multiprocess.rs`.)
//!
//! Determinism contract (see `comm::parallel` module docs):
//!   - selections, leaders, rates, byte accounting, `CommStats`: EXACT;
//!   - memory states and gather-path updates: EXACT (per-worker math /
//!     worker-order reductions);
//!   - ring-reduced f32 values: equal within reduction-order tolerance
//!     rtol = 1e-5, atol = 1e-6 (ring chunk order is a rotation of the
//!     sequential 0..n order);
//!   - concurrent-backend runs are bit-identical to each other (fixed
//!     dataflow), including the pipelined double-buffered mode.
//!
//! The matrix includes a **mid-run memory-snapshot equivalence check**
//! so the persistent pool (whose lanes own the memories) cannot silently
//! drift from the scoped-thread semantics between step 0 and the end of
//! a run.
//!
//! CI runs this suite once per backend via `SCALECOM_TEST_BACKENDS`
//! (comma-separated labels); unset, every concurrent backend is tested.

use scalecom::comm::{Backend, BucketPlan, Fabric, FabricConfig, Topology, WireCodecConfig};
use scalecom::compress::rate::LayerSlice;
use scalecom::compress::{schemes::make_compressor, LayerPartition};
use scalecom::coordinator::{Coordinator, Mode, StepResult};
use scalecom::util::floats::allclose;
use scalecom::util::rng::Rng;

/// Documented f32 reduction-order tolerance for ring-reduced values.
const RTOL: f32 = 1e-5;
const ATOL: f32 = 1e-6;

const SCHEMES: &[&str] = &[
    "scalecom",       // CLT-k, chunked quasi-sort
    "scalecom-exact", // CLT-k, exact top-k
    "true-topk",
    "local-topk",
    "gtop-k",
    "random-k",
    "sketch-k",
];

const WORKER_COUNTS: &[usize] = &[2, 4, 8, 16];

/// Concurrent backends under test, filterable per CI matrix job with
/// `SCALECOM_TEST_BACKENDS=threaded` / `=pipelined` / `=socket` / a
/// comma list. `sequential` is always the reference side of every
/// comparison, so a selection that leaves nothing to compare is a
/// misconfiguration — fail loudly instead of passing the whole parity
/// lock vacuously.
fn backends_under_test() -> Vec<Backend> {
    let backends: Vec<Backend> = match std::env::var("SCALECOM_TEST_BACKENDS") {
        Ok(s) => s
            .split(',')
            .map(|b| {
                Backend::parse(b.trim())
                    .expect("SCALECOM_TEST_BACKENDS holds backend labels")
            })
            .filter(|&b| b != Backend::Sequential)
            .collect(),
        Err(_) => vec![Backend::Threaded, Backend::Pipelined, Backend::Socket],
    };
    assert!(
        !backends.is_empty(),
        "SCALECOM_TEST_BACKENDS selected no concurrent backend — the parity \
         matrix would pass without comparing anything (sequential is always \
         the reference side; pick threaded, pipelined, and/or socket)"
    );
    backends
}

fn coordinator(
    scheme: &str,
    n: usize,
    dim: usize,
    rate: usize,
    warmup: usize,
    topo: Topology,
    backend: Backend,
) -> Coordinator {
    let fabric = Fabric::new(FabricConfig {
        workers: n,
        topology: topo,
        ..FabricConfig::default()
    });
    let mode = if scheme == "none" {
        Mode::Dense
    } else {
        Mode::Compressed(make_compressor(scheme, rate, 7).unwrap())
    };
    let k = (dim / rate).max(1);
    Coordinator::new(n, dim, mode, 0.5, k, fabric, warmup).with_backend(backend)
}

fn rand_grads(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            let mut v = vec![0.0; dim];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect()
}

fn assert_step_parity(ctx: &str, t: usize, a: &StepResult, b: &StepResult) {
    assert_eq!(a.selection, b.selection, "selection mismatch ({ctx} t={t})");
    assert_eq!(a.leader, b.leader, "leader mismatch ({ctx} t={t})");
    assert_eq!(a.dense, b.dense, "dense flag mismatch ({ctx} t={t})");
    assert_eq!(a.rate, b.rate, "rate mismatch ({ctx} t={t})");
    assert_eq!(a.comm, b.comm, "comm cost mismatch ({ctx} t={t})");
    if let Err(i) = allclose(&a.update, &b.update, RTOL, ATOL) {
        panic!(
            "update mismatch at coord {i} ({ctx} t={t}): seq={} other={}",
            a.update[i], b.update[i]
        );
    }
}

fn assert_memory_parity(ctx: &str, seq: &Coordinator, other: &Coordinator) {
    let ma = seq.memory_snapshot();
    let mb = other.memory_snapshot();
    for (w, (a, b)) in ma.iter().zip(&mb).enumerate() {
        if let Err(i) = allclose(a.memory(), b.memory(), RTOL, ATOL) {
            panic!(
                "memory divergence {ctx} worker={w} coord {i}: {} vs {}",
                a.memory()[i],
                b.memory()[i]
            );
        }
    }
}

/// Drive the sequential reference and `backend` through identical
/// gradient streams and compare every observable per step, the memory
/// snapshots at mid-run and at the end, and the full comm ledger.
fn run_parity(
    scheme: &str,
    n: usize,
    dim: usize,
    rate: usize,
    steps: usize,
    warmup: usize,
    backend: Backend,
) {
    let topo = if n % 2 == 0 { Topology::Ring } else { Topology::ParameterServer };
    let ctx = format!("scheme={scheme} n={n} backend={}", backend.label());
    let mut seq = coordinator(scheme, n, dim, rate, warmup, topo, Backend::Sequential);
    let mut other = coordinator(scheme, n, dim, rate, warmup, topo, backend);
    let mut rng = Rng::for_stream(0xBACC, n as u64);
    for t in 0..steps {
        let grads = rand_grads(&mut rng, n, dim);
        let a = seq.step(t, &grads);
        let b = other.step(t, &grads);
        assert_step_parity(&ctx, t, &a, &b);
        if t == steps / 2 {
            // mid-run: the persistent pool must be in lockstep *during*
            // the run, not just after draining it
            assert_memory_parity(&format!("{ctx} (mid-run t={t})"), &seq, &other);
        }
    }
    assert_memory_parity(&format!("{ctx} (final)"), &seq, &other);
    // byte-exact communication ledger
    assert_eq!(
        seq.fabric.stats().ops,
        other.fabric.stats().ops,
        "CommStats mismatch {ctx}"
    );
}

#[test]
fn all_schemes_match_across_worker_counts_over_50_steps() {
    for backend in backends_under_test() {
        for &scheme in SCHEMES {
            for &n in WORKER_COUNTS {
                run_parity(scheme, n, 96, 8, 50, 0, backend);
            }
        }
    }
}

#[test]
fn dense_mode_and_warmup_transition_match() {
    for backend in backends_under_test() {
        for n in [2usize, 3, 8] {
            run_parity("none", n, 128, 4, 50, 0, backend);
            // warmup: dense steps 0..5, compressed after — covers the switch
            run_parity("scalecom", n, 128, 4, 50, 5, backend);
        }
    }
}

#[test]
fn single_worker_degenerate_case_matches() {
    for backend in backends_under_test() {
        for scheme in ["none", "scalecom", "local-topk", "true-topk"] {
            run_parity(scheme, 1, 64, 4, 50, 0, backend);
        }
    }
}

#[test]
fn layered_selection_matches_across_backends() {
    let partition = || {
        LayerPartition::from_layers(vec![
            LayerSlice {
                name: "first".into(),
                offset: 0,
                len: 16,
                flops_per_sample: 0.0,
                compress: false, // dense layer
            },
            LayerSlice {
                name: "rest".into(),
                offset: 16,
                len: 112,
                flops_per_sample: 0.0,
                compress: true,
            },
        ])
    };
    for backend in backends_under_test() {
        let n = 4;
        let dim = 128;
        let mut seq =
            coordinator("scalecom-auto", n, dim, 8, 0, Topology::Ring, Backend::Sequential)
                .with_layered(partition(), vec![16, 14]);
        let mut other = coordinator("scalecom-auto", n, dim, 8, 0, Topology::Ring, backend)
            .with_layered(partition(), vec![16, 14]);
        let mut rng = Rng::new(55);
        let ctx = format!("scalecom-auto(layered) backend={}", backend.label());
        for t in 0..50 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = seq.step(t, &grads);
            let b = other.step(t, &grads);
            assert_step_parity(&ctx, t, &a, &b);
        }
    }
}

#[test]
fn concurrent_backends_are_deterministic_run_to_run() {
    // The channel dataflow fixes every reduction order: two runs of the
    // same concurrent backend must agree bit-for-bit, independent of OS
    // scheduling — including the pipelined double-buffered mode.
    for backend in backends_under_test() {
        let run = || {
            let n = 8;
            let dim = 256;
            let mut c = coordinator("scalecom", n, dim, 16, 0, Topology::Ring, backend);
            let mut rng = Rng::new(99);
            let mut updates = Vec::new();
            for t in 0..20 {
                let grads = rand_grads(&mut rng, n, dim);
                if backend.is_pooled() {
                    if let Some(r) = c.step_overlapped(t, &grads) {
                        updates.push(r.update);
                    }
                } else {
                    updates.push(c.step(t, &grads).update);
                }
            }
            updates.extend(c.finish_overlapped().into_iter().map(|r| r.update));
            updates
        };
        let a = run();
        let b = run();
        assert_eq!(
            a,
            b,
            "{} backend must be bit-deterministic",
            backend.label()
        );
    }
}

#[test]
fn pipelined_streaming_matches_sequential_per_step() {
    // The double-buffered driving mode (submit t+1 while t's collective
    // is in flight) must produce the exact same per-step stream as the
    // sequential reference — the one-step-lag contract. Both pooled
    // backends (pipelined: channel lanes; socket: loopback TCP lanes)
    // carry it.
    for backend in [Backend::Pipelined, Backend::Socket] {
        for &scheme in &["scalecom", "local-topk", "none"] {
        for &n in &[2usize, 4, 8] {
            let dim = 96;
            let topo = Topology::Ring;
            let ctx = format!("streaming scheme={scheme} n={n} backend={}", backend.label());
            let mut seq =
                coordinator(scheme, n, dim, 8, 2, topo, Backend::Sequential);
            let mut pipe = coordinator(scheme, n, dim, 8, 2, topo, backend);
            let mut rng = Rng::for_stream(0xF1FE, n as u64);
            let steps = 30;
            let mut seq_results = Vec::new();
            let mut streamed = Vec::new();
            for t in 0..steps {
                let grads = rand_grads(&mut rng, n, dim);
                seq_results.push(seq.step(t, &grads));
                if let Some(r) = pipe.step_overlapped(t, &grads) {
                    streamed.push(r);
                }
            }
            streamed.extend(pipe.finish_overlapped());
            assert_eq!(streamed.len(), steps, "{ctx}");
            for (t, (a, b)) in seq_results.iter().zip(&streamed).enumerate() {
                assert_step_parity(&ctx, t, a, b);
            }
            assert_memory_parity(&ctx, &seq, &pipe);
            assert_eq!(seq.fabric.stats().ops, pipe.fabric.stats().ops, "{ctx}");
        }
        }
    }
}

// ----------------------------------------------------------------------
// Bucketed axis: the per-bucket overlap driver (`step_bucketed`) joins
// the matrix. Contract: for a fixed layered config and bucket plan,
// every backend's bucketed run matches the sequential bucketed reference
// (selections/rates/CommStats exact, gather bit-identical, ring values
// within rtol/atol), and the 1-bucket plan is bit-identical to the
// monolithic path.
// ----------------------------------------------------------------------

/// Four layers of uneven sizes over `dim = 96`, each with its own
/// budget — the layered config every bucketed case runs on.
fn bucketed_fixture() -> (LayerPartition, Vec<usize>) {
    let lens = [16usize, 40, 8, 32];
    let mut layers = Vec::new();
    let mut off = 0;
    for (i, &len) in lens.iter().enumerate() {
        layers.push(LayerSlice {
            name: format!("layer{i}"),
            offset: off,
            len,
            // layer2 rides dense (the paper exempts sensitive layers)
            flops_per_sample: 0.0,
            compress: i != 2,
        });
        off += len;
    }
    let partition = LayerPartition::from_layers(layers);
    let ks = vec![4usize, 6, 8, 5];
    (partition, ks)
}

/// Bucket caps that produce 1, 2, and 4 buckets over the fixture.
fn plans_under_test(partition: &LayerPartition) -> Vec<BucketPlan> {
    let plans: Vec<BucketPlan> = [0usize, 56 * 4, 16 * 4]
        .iter()
        .map(|&cap| BucketPlan::from_partition(partition, cap))
        .collect();
    assert_eq!(plans[0].num_buckets(), 1, "cap 0 = monolithic plan");
    assert_eq!(plans[1].num_buckets(), 2);
    assert_eq!(plans[2].num_buckets(), 4, "tight cap = one bucket per layer");
    plans
}

fn run_bucketed_parity(scheme: &str, n: usize, backend: Backend, plan: &BucketPlan, steps: usize) {
    let dim = 96;
    let rate = 8;
    let warmup = 3; // cover the dense-warmup fallback inside step_bucketed
    let (partition, ks) = bucketed_fixture();
    let topo = if n % 2 == 0 { Topology::Ring } else { Topology::ParameterServer };
    let ctx = format!(
        "bucketed scheme={scheme} n={n} buckets={} backend={}",
        plan.num_buckets(),
        backend.label()
    );
    let mut seq = coordinator(scheme, n, dim, rate, warmup, topo, Backend::Sequential)
        .with_layered(partition.clone(), ks.clone())
        .with_buckets(plan.clone());
    let mut other = coordinator(scheme, n, dim, rate, warmup, topo, backend)
        .with_layered(partition, ks)
        .with_buckets(plan.clone());
    let mut rng = Rng::for_stream(0xB0C4, n as u64);
    for t in 0..steps {
        let grads = rand_grads(&mut rng, n, dim);
        let a = seq.step_bucketed(t, &grads);
        let b = other.step_bucketed(t, &grads);
        assert_step_parity(&ctx, t, &a, &b);
        if t == steps / 2 {
            assert_memory_parity(&format!("{ctx} (mid-run t={t})"), &seq, &other);
        }
    }
    assert_memory_parity(&format!("{ctx} (final)"), &seq, &other);
    assert_eq!(
        seq.fabric.stats().ops,
        other.fabric.stats().ops,
        "CommStats mismatch {ctx}"
    );
}

#[test]
fn bucketed_matrix_matches_sequential_reference() {
    // schemes × backends × bucket counts (1, 2, 4): the bucketed driver
    // obeys the same cross-backend contract as the monolithic step.
    let (partition, _) = bucketed_fixture();
    let plans = plans_under_test(&partition);
    for backend in backends_under_test() {
        for &scheme in &["scalecom", "scalecom-exact", "local-topk", "random-k"] {
            for plan in &plans {
                for &n in &[2usize, 4, 8] {
                    run_bucketed_parity(scheme, n, backend, plan, 30);
                }
            }
        }
    }
}

#[test]
fn one_bucket_plan_is_bit_identical_to_the_monolithic_path() {
    // The degenerate plan must not merely be close — it takes the exact
    // monolithic code path, so every observable matches bit for bit on
    // every backend.
    let (partition, ks) = bucketed_fixture();
    let single = BucketPlan::from_partition(&partition, 0);
    for backend in backends_under_test() {
        let n = 4;
        let dim = 96;
        let mk = |with_plan: bool| {
            let c = coordinator("scalecom-exact", n, dim, 8, 0, Topology::Ring, backend)
                .with_layered(partition.clone(), ks.clone());
            if with_plan {
                c.with_buckets(single.clone())
            } else {
                c
            }
        };
        let mut mono = mk(false);
        let mut buck = mk(true);
        let mut rng = Rng::new(13);
        for t in 0..20 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = mono.step(t, &grads);
            let b = buck.step_bucketed(t, &grads);
            assert_eq!(a.update, b.update, "backend={} t={t}", backend.label());
            assert_eq!(a.selection, b.selection, "backend={} t={t}", backend.label());
            assert_eq!(a.comm, b.comm, "backend={} t={t}", backend.label());
        }
        assert_eq!(
            mono.fabric.stats().ops,
            buck.fabric.stats().ops,
            "backend={}",
            backend.label()
        );
    }
}

#[test]
fn bucketed_selection_equals_monolithic_selection_on_every_backend() {
    // Layer-aligned bucketing must never change WHAT is selected — only
    // how the exchange is scheduled. (Updates are compared against the
    // sequential bucketed reference in the matrix above; here the merged
    // selection is locked against the monolithic layered step.)
    let (partition, ks) = bucketed_fixture();
    let plans = plans_under_test(&partition);
    for backend in backends_under_test() {
        for &scheme in &["scalecom-exact", "local-topk"] {
            let n = 4;
            let dim = 96;
            let mut mono = coordinator(scheme, n, dim, 8, 0, Topology::Ring, Backend::Sequential)
                .with_layered(partition.clone(), ks.clone());
            let mut bucketed: Vec<Coordinator> = plans
                .iter()
                .map(|p| {
                    coordinator(scheme, n, dim, 8, 0, Topology::Ring, backend)
                        .with_layered(partition.clone(), ks.clone())
                        .with_buckets(p.clone())
                })
                .collect();
            let mut rng = Rng::new(101);
            for t in 0..20 {
                let grads = rand_grads(&mut rng, n, dim);
                let a = mono.step(t, &grads);
                for (p, c) in plans.iter().zip(bucketed.iter_mut()) {
                    let b = c.step_bucketed(t, &grads);
                    assert_eq!(
                        a.selection,
                        b.selection,
                        "scheme={scheme} backend={} buckets={} t={t}",
                        backend.label(),
                        p.num_buckets()
                    );
                    assert_eq!(a.rate, b.rate, "scheme={scheme} t={t}");
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// Hierarchy axis: the pooled backends re-run the parity contract with
// the dense ring collective on the hierarchical ring-of-rings topology
// (`--group-size`). The hierarchy is a scheduling choice, not an
// arithmetic one — selections, leaders, rates, and the byte-exact
// CommStats ledger must match the flat-ring sequential reference
// exactly; ring-reduced f32 values stay within the same rtol/atol (the
// 3-phase reduce reassociates the sum differently than the flat ring).
// ----------------------------------------------------------------------

/// Pooled coordinator with the hierarchical group size latched BEFORE
/// the lanes are built (the topology is baked in at lane construction).
fn hier_coordinator(
    scheme: &str,
    n: usize,
    dim: usize,
    rate: usize,
    warmup: usize,
    backend: Backend,
    group_size: usize,
) -> Coordinator {
    let fabric = Fabric::new(FabricConfig {
        workers: n,
        topology: Topology::Ring,
        ..FabricConfig::default()
    });
    let mode = if scheme == "none" {
        Mode::Dense
    } else {
        Mode::Compressed(make_compressor(scheme, rate, 7).unwrap())
    };
    let k = (dim / rate).max(1);
    Coordinator::new(n, dim, mode, 0.5, k, fabric, warmup)
        .with_group_size(group_size)
        .with_backend(backend)
}

fn run_hier_parity(
    scheme: &str,
    n: usize,
    group_size: usize,
    steps: usize,
    warmup: usize,
    backend: Backend,
) {
    let dim = 96;
    let rate = 8;
    let ctx = format!(
        "hier scheme={scheme} n={n} g={group_size} backend={}",
        backend.label()
    );
    let mut seq =
        coordinator(scheme, n, dim, rate, warmup, Topology::Ring, Backend::Sequential);
    let mut other = hier_coordinator(scheme, n, dim, rate, warmup, backend, group_size);
    let mut rng = Rng::for_stream(0x41E2, n as u64);
    for t in 0..steps {
        let grads = rand_grads(&mut rng, n, dim);
        let a = seq.step(t, &grads);
        let b = other.step(t, &grads);
        assert_step_parity(&ctx, t, &a, &b);
        if t == steps / 2 {
            assert_memory_parity(&format!("{ctx} (mid-run t={t})"), &seq, &other);
        }
    }
    assert_memory_parity(&format!("{ctx} (final)"), &seq, &other);
    assert_eq!(
        seq.fabric.stats().ops,
        other.fabric.stats().ops,
        "CommStats mismatch {ctx}"
    );
}

#[test]
fn hierarchical_ring_matrix_matches_the_flat_sequential_reference() {
    // schemes × pooled backends × group sizes {2, 4} × n ∈ {4, 8, 16};
    // tilings the shared validator rejects ((4,4): a single group has no
    // uplink ring) are skipped with the same predicate it enforces.
    for backend in backends_under_test().into_iter().filter(Backend::is_pooled) {
        for &scheme in &["scalecom", "scalecom-exact", "local-topk", "none"] {
            for &n in &[4usize, 8, 16] {
                for &g in &[2usize, 4] {
                    if n % g != 0 || n / g < 2 {
                        continue;
                    }
                    run_hier_parity(scheme, n, g, 30, 2, backend);
                }
            }
        }
    }
}

#[test]
fn hierarchical_runs_are_bit_deterministic() {
    // Same fixed dataflow as the flat ring: two hierarchical runs of the
    // same backend must agree bit for bit.
    for backend in backends_under_test().into_iter().filter(Backend::is_pooled) {
        let run = || {
            let n = 8;
            let dim = 128;
            let mut c = hier_coordinator("scalecom", n, dim, 8, 0, backend, 4);
            let mut rng = Rng::new(23);
            let mut updates = Vec::new();
            for t in 0..15 {
                let grads = rand_grads(&mut rng, n, dim);
                updates.push(c.step(t, &grads).update);
            }
            updates
        };
        assert_eq!(run(), run(), "{} hier run must be bit-deterministic", backend.label());
    }
}

#[test]
fn coordinator_rejects_bad_group_sizes_and_live_lane_retiling() {
    let mk = || {
        Coordinator::new(
            4,
            32,
            Mode::Compressed(make_compressor("scalecom", 8, 7).unwrap()),
            0.5,
            4,
            Fabric::new(FabricConfig {
                workers: 4,
                topology: Topology::Ring,
                ..FabricConfig::default()
            }),
            0,
        )
    };
    let mut c = mk();
    let err = c.try_set_group_size(3).unwrap_err();
    assert!(err.to_string().contains("does not divide"), "{err}");
    let err = c.try_set_group_size(4).unwrap_err();
    assert!(err.to_string().contains("at least 2 groups"), "{err}");
    // Once the pooled lanes are built the topology is latched.
    let mut c = mk().with_group_size(2).with_backend(Backend::Pipelined);
    c.try_set_group_size(2).unwrap(); // same value: fine
    let err = c.try_set_group_size(0).unwrap_err();
    assert!(err.to_string().contains("already built"), "{err}");
}

// ----------------------------------------------------------------------
// Wire-compression axis: the socket backend re-runs the parity contract
// with the entropy codec enabled. Compression must be observably
// invisible — selections, leaders, rates, and the byte-exact CommStats
// ledger unchanged, gather bit-identical, ring values within the same
// rtol/atol as the uncompressed run — and, because f32 bits ship
// untouched in every mode, the compressed socket run must be
// bit-identical to the uncompressed socket run.
// ----------------------------------------------------------------------

/// Socket coordinator with the wire codec set BEFORE the mesh is built
/// (the codec is baked into every lane endpoint at mesh-formation time).
fn socket_coordinator(
    scheme: &str,
    n: usize,
    dim: usize,
    rate: usize,
    warmup: usize,
    topo: Topology,
    wire: WireCodecConfig,
) -> Coordinator {
    let fabric = Fabric::new(FabricConfig {
        workers: n,
        topology: topo,
        ..FabricConfig::default()
    });
    let mode = if scheme == "none" {
        Mode::Dense
    } else {
        Mode::Compressed(make_compressor(scheme, rate, 7).unwrap())
    };
    let k = (dim / rate).max(1);
    Coordinator::new(n, dim, mode, 0.5, k, fabric, warmup)
        .with_wire_codec(wire)
        .with_backend(Backend::Socket)
}

#[test]
fn socket_wire_compression_modes_match_the_sequential_reference() {
    if !backends_under_test().contains(&Backend::Socket) {
        return; // this axis belongs to the socket matrix job
    }
    for mode in ["off", "delta", "full"] {
        let wire = WireCodecConfig::from_strings(mode, "auto", "auto").unwrap();
        for &scheme in &["scalecom", "local-topk"] {
            for &n in &[2usize, 4, 8] {
                let dim = 96;
                let rate = 8;
                let topo = Topology::Ring;
                let ctx = format!("wire={mode} scheme={scheme} n={n} backend=socket");
                let mut seq =
                    coordinator(scheme, n, dim, rate, 0, topo, Backend::Sequential);
                let mut sock = socket_coordinator(scheme, n, dim, rate, 0, topo, wire);
                let mut rng = Rng::for_stream(0xC0DE, n as u64);
                for t in 0..30 {
                    let grads = rand_grads(&mut rng, n, dim);
                    let a = seq.step(t, &grads);
                    let b = sock.step(t, &grads);
                    assert_step_parity(&ctx, t, &a, &b);
                }
                assert_memory_parity(&ctx, &seq, &sock);
                assert_eq!(
                    seq.fabric.stats().ops,
                    sock.fabric.stats().ops,
                    "CommStats mismatch {ctx}"
                );
            }
        }
    }
}

#[test]
fn socket_runs_are_bit_identical_with_compression_on_and_off() {
    if !backends_under_test().contains(&Backend::Socket) {
        return; // this axis belongs to the socket matrix job
    }
    // Same fixed channel dataflow, same f32 bits on the wire in every
    // mode — so the three socket runs must agree bit for bit, gather and
    // ring paths alike, not merely within tolerance.
    let run = |mode: &str, scheme: &str| {
        let wire = WireCodecConfig::from_strings(mode, "auto", "auto").unwrap();
        let n = 4;
        let dim = 160;
        let mut c = socket_coordinator(scheme, n, dim, 8, 0, Topology::Ring, wire);
        let mut rng = Rng::new(77);
        let mut updates = Vec::new();
        for t in 0..25 {
            let grads = rand_grads(&mut rng, n, dim);
            updates.push(c.step(t, &grads).update);
        }
        updates
    };
    for scheme in ["scalecom", "local-topk"] {
        let off = run("off", scheme);
        let delta = run("delta", scheme);
        let full = run("full", scheme);
        assert_eq!(off, delta, "scheme={scheme}: delta-packed run diverged");
        assert_eq!(off, full, "scheme={scheme}: byte-compressed run diverged");
    }
}

#[test]
fn gather_path_is_bit_identical_not_just_close() {
    // The build-up path reduces at the root in worker order — the exact
    // sequential arithmetic — so parity here is equality, not tolerance.
    for backend in backends_under_test() {
        let n = 8;
        let dim = 160;
        let mut seq = coordinator(
            "local-topk",
            n,
            dim,
            8,
            0,
            Topology::ParameterServer,
            Backend::Sequential,
        );
        let mut other =
            coordinator("local-topk", n, dim, 8, 0, Topology::ParameterServer, backend);
        let mut rng = Rng::new(31);
        for t in 0..50 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = seq.step(t, &grads);
            let b = other.step(t, &grads);
            assert_eq!(a.update, b.update, "backend={} t={t}", backend.label());
            for (ma, mb) in seq.memory_snapshot().iter().zip(&other.memory_snapshot()) {
                assert_eq!(ma.memory(), mb.memory(), "backend={} t={t}", backend.label());
            }
        }
    }
}
