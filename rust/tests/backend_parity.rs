//! Backend parity lock: the threaded (thread-per-worker, channel
//! collectives) backend must be indistinguishable from the sequential
//! reference across every compression scheme, worker count, and step.
//!
//! Determinism contract (see `comm::parallel` module docs):
//!   - selections, leaders, rates, byte accounting, `CommStats`: EXACT;
//!   - memory states and gather-path updates: EXACT (per-worker math /
//!     worker-order reductions);
//!   - ring-reduced f32 values: equal within reduction-order tolerance
//!     rtol = 1e-5, atol = 1e-6 (ring chunk order is a rotation of the
//!     sequential 0..n order);
//!   - threaded runs are bit-identical to each other (fixed dataflow).

use scalecom::comm::{Backend, Fabric, FabricConfig, Topology};
use scalecom::compress::rate::LayerSlice;
use scalecom::compress::{schemes::make_compressor, LayerPartition};
use scalecom::coordinator::{Coordinator, Mode, StepResult};
use scalecom::util::floats::allclose;
use scalecom::util::rng::Rng;

/// Documented f32 reduction-order tolerance for ring-reduced values.
const RTOL: f32 = 1e-5;
const ATOL: f32 = 1e-6;

const SCHEMES: &[&str] = &[
    "scalecom",       // CLT-k, chunked quasi-sort
    "scalecom-exact", // CLT-k, exact top-k
    "true-topk",
    "local-topk",
    "gtop-k",
    "random-k",
    "sketch-k",
];

fn coordinator(
    scheme: &str,
    n: usize,
    dim: usize,
    rate: usize,
    warmup: usize,
    topo: Topology,
    backend: Backend,
) -> Coordinator {
    let fabric = Fabric::new(FabricConfig {
        workers: n,
        topology: topo,
        ..FabricConfig::default()
    });
    let mode = if scheme == "none" {
        Mode::Dense
    } else {
        Mode::Compressed(make_compressor(scheme, rate, 7).unwrap())
    };
    let k = (dim / rate).max(1);
    Coordinator::new(n, dim, mode, 0.5, k, fabric, warmup).with_backend(backend)
}

fn rand_grads(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            let mut v = vec![0.0; dim];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect()
}

fn assert_step_parity(scheme: &str, n: usize, t: usize, a: &StepResult, b: &StepResult) {
    let ctx = || format!("scheme={scheme} n={n} t={t}");
    assert_eq!(a.selection, b.selection, "selection mismatch ({})", ctx());
    assert_eq!(a.leader, b.leader, "leader mismatch ({})", ctx());
    assert_eq!(a.dense, b.dense, "dense flag mismatch ({})", ctx());
    assert_eq!(a.rate, b.rate, "rate mismatch ({})", ctx());
    assert_eq!(a.comm, b.comm, "comm cost mismatch ({})", ctx());
    if let Err(i) = allclose(&a.update, &b.update, RTOL, ATOL) {
        panic!(
            "update mismatch at coord {i} ({}): seq={} thr={}",
            ctx(),
            a.update[i],
            b.update[i]
        );
    }
}

/// Drive both backends through identical gradient streams and compare
/// every observable per step plus the final memory/comm ledgers.
fn run_parity(scheme: &str, n: usize, dim: usize, rate: usize, steps: usize, warmup: usize) {
    let topo = if n % 2 == 0 { Topology::Ring } else { Topology::ParameterServer };
    let mut seq = coordinator(scheme, n, dim, rate, warmup, topo, Backend::Sequential);
    let mut thr = coordinator(scheme, n, dim, rate, warmup, topo, Backend::Threaded);
    let mut rng = Rng::for_stream(0xBACC, n as u64);
    for t in 0..steps {
        let grads = rand_grads(&mut rng, n, dim);
        let a = seq.step(t, &grads);
        let b = thr.step(t, &grads);
        assert_step_parity(scheme, n, t, &a, &b);
    }
    // error-feedback memories stay in lockstep (bit-exact: per-worker math)
    for (w, (ma, mb)) in seq.memories.iter().zip(&thr.memories).enumerate() {
        if let Err(i) = allclose(ma.memory(), mb.memory(), RTOL, ATOL) {
            panic!(
                "memory divergence scheme={scheme} n={n} worker={w} coord {i}: {} vs {}",
                ma.memory()[i],
                mb.memory()[i]
            );
        }
    }
    // byte-exact communication ledger
    assert_eq!(
        seq.fabric.stats().ops,
        thr.fabric.stats().ops,
        "CommStats mismatch scheme={scheme} n={n}"
    );
}

#[test]
fn all_schemes_match_across_worker_counts_over_50_steps() {
    for &scheme in SCHEMES {
        for n in [2usize, 4, 8, 16] {
            run_parity(scheme, n, 96, 8, 50, 0);
        }
    }
}

#[test]
fn dense_mode_and_warmup_transition_match() {
    for n in [2usize, 3, 8] {
        run_parity("none", n, 128, 4, 50, 0);
        // warmup: dense steps 0..5, compressed after — covers the switch
        run_parity("scalecom", n, 128, 4, 50, 5);
    }
}

#[test]
fn single_worker_degenerate_case_matches() {
    for scheme in ["none", "scalecom", "local-topk", "true-topk"] {
        run_parity(scheme, 1, 64, 4, 50, 0);
    }
}

#[test]
fn layered_selection_matches_across_backends() {
    let partition = || {
        LayerPartition::from_layers(vec![
            LayerSlice {
                name: "first".into(),
                offset: 0,
                len: 16,
                flops_per_sample: 0.0,
                compress: false, // dense layer
            },
            LayerSlice {
                name: "rest".into(),
                offset: 16,
                len: 112,
                flops_per_sample: 0.0,
                compress: true,
            },
        ])
    };
    let n = 4;
    let dim = 128;
    let mut seq = coordinator("scalecom-auto", n, dim, 8, 0, Topology::Ring, Backend::Sequential)
        .with_layered(partition(), vec![16, 14]);
    let mut thr = coordinator("scalecom-auto", n, dim, 8, 0, Topology::Ring, Backend::Threaded)
        .with_layered(partition(), vec![16, 14]);
    let mut rng = Rng::new(55);
    for t in 0..50 {
        let grads = rand_grads(&mut rng, n, dim);
        let a = seq.step(t, &grads);
        let b = thr.step(t, &grads);
        assert_step_parity("scalecom-auto(layered)", n, t, &a, &b);
    }
}

#[test]
fn threaded_backend_is_deterministic_run_to_run() {
    // The channel dataflow fixes every reduction order: two threaded runs
    // must agree bit-for-bit, independent of OS scheduling.
    let run = || {
        let n = 8;
        let dim = 256;
        let mut c =
            coordinator("scalecom", n, dim, 16, 0, Topology::Ring, Backend::Threaded);
        let mut rng = Rng::new(99);
        let mut updates = Vec::new();
        for t in 0..20 {
            let grads = rand_grads(&mut rng, n, dim);
            updates.push(c.step(t, &grads).update);
        }
        updates
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "threaded backend must be bit-deterministic");
}

#[test]
fn gather_path_is_bit_identical_not_just_close() {
    // The build-up path reduces at the root in worker order — the exact
    // sequential arithmetic — so parity here is equality, not tolerance.
    let n = 8;
    let dim = 160;
    let mut seq =
        coordinator("local-topk", n, dim, 8, 0, Topology::ParameterServer, Backend::Sequential);
    let mut thr =
        coordinator("local-topk", n, dim, 8, 0, Topology::ParameterServer, Backend::Threaded);
    let mut rng = Rng::new(31);
    for t in 0..50 {
        let grads = rand_grads(&mut rng, n, dim);
        let a = seq.step(t, &grads);
        let b = thr.step(t, &grads);
        assert_eq!(a.update, b.update, "t={t}");
        for (ma, mb) in seq.memories.iter().zip(&thr.memories) {
            assert_eq!(ma.memory(), mb.memory(), "t={t}");
        }
    }
}
