//! L1/L3 parity: the Pallas compress/apply artifacts must agree exactly
//! with the native Rust compressor + low-pass memory, and the kernel-
//! routed trainer must reproduce the native trainer's trajectory.
//! Requires `make artifacts`; skips (green) on a bare checkout.

use scalecom::compress::chunk::chunk_top1_indices;
use scalecom::compress::EfMemory;
use scalecom::config::train::{CompressConfig, TrainConfig};
use scalecom::runtime::{default_artifacts_dir, Engine, Manifest};
use scalecom::trainer::Trainer;
use scalecom::util::floats::allclose;
use scalecom::util::rng::Rng;

/// Skip (pass vacuously, with a note) when artifacts are absent.
macro_rules! require_artifacts {
    () => {
        if !scalecom::runtime::artifacts_present() {
            eprintln!(
                "skipping {}: artifacts/manifest.json not found — run `make artifacts`",
                module_path!()
            );
            return;
        }
    };
}

fn load(model: &str) -> (Engine, scalecom::runtime::LoadedModel) {
    let manifest = Manifest::load(&default_artifacts_dir()).expect("make artifacts first");
    let engine = Engine::cpu().unwrap();
    let lm = engine.load_model(&manifest, model).unwrap();
    (engine, lm)
}

#[test]
fn kernel_compress_matches_native_chunk_top1() {
    require_artifacts!();
    let (_e, lm) = load("mlp");
    let dim = lm.mm.dim;
    let mut rng = Rng::new(3);
    let mut m = vec![0.0f32; dim];
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut m, 0.5);
    rng.fill_normal(&mut g, 1.0);

    let (idx, vals, m_next) = lm.kernel_compress(&m, &g, 0.1).unwrap();

    // native selection on the same EF gradient
    let ef: Vec<f32> = m.iter().zip(&g).map(|(a, b)| a + b).collect();
    let native_idx = chunk_top1_indices(&ef, lm.mm.chunk);
    assert_eq!(idx, native_idx, "selection parity");
    let native_vals: Vec<f32> = native_idx.iter().map(|&i| ef[i as usize]).collect();
    assert!(allclose(&vals, &native_vals, 1e-5, 1e-6).is_ok());

    // native memory update
    let mut mem = EfMemory::new(dim, 0.1);
    mem.set_memory(m.clone());
    mem.update_after_send(&g, &idx);
    if let Err(i) = allclose(&m_next, mem.memory(), 1e-4, 1e-5) {
        panic!(
            "memory parity failed at {i}: kernel={} native={}",
            m_next[i],
            mem.memory()[i]
        );
    }
}

#[test]
fn kernel_apply_matches_native_follower() {
    require_artifacts!();
    let (_e, lm) = load("mlp");
    let dim = lm.mm.dim;
    let k = lm.mm.k;
    let mut rng = Rng::new(5);
    let mut m = vec![0.0f32; dim];
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut m, 0.5);
    rng.fill_normal(&mut g, 1.0);
    let idx = rng.sample_indices(dim, k);

    let (vals, m_next) = lm.kernel_apply(&m, &g, &idx, 0.3).unwrap();
    let ef: Vec<f32> = m.iter().zip(&g).map(|(a, b)| a + b).collect();
    let native_vals: Vec<f32> = idx.iter().map(|&i| ef[i as usize]).collect();
    assert!(allclose(&vals, &native_vals, 1e-5, 1e-6).is_ok());

    let mut mem = EfMemory::new(dim, 0.3);
    mem.set_memory(m.clone());
    mem.update_after_send(&g, &idx);
    assert!(allclose(&m_next, mem.memory(), 1e-4, 1e-5).is_ok());
}

#[test]
fn kernel_trainer_matches_native_trainer_trajectory() {
    require_artifacts!();
    let zoo = scalecom::models::zoo_model("mlp").unwrap();
    let cfg = TrainConfig {
        model: "mlp".into(),
        workers: 3,
        steps: 15,
        batch_per_worker: zoo.batch_per_worker,
        compress: CompressConfig {
            scheme: "scalecom".into(),
            rate: zoo.default_rate,
            beta: 0.1,
            ..CompressConfig::default()
        },
        ..TrainConfig::default()
    };
    let native = Trainer::from_config(cfg.clone()).unwrap().run().unwrap();
    let mut kt = Trainer::from_config(cfg).unwrap();
    kt.use_kernel = true;
    let kernel = kt.run().unwrap();

    let nl = native.column("loss").unwrap();
    let kl = kernel.column("loss").unwrap();
    for (t, (a, b)) in nl.iter().zip(&kl).enumerate() {
        assert!(
            (a - b).abs() < 2e-3 * (1.0 + a.abs()),
            "step {t}: native {a} vs kernel {b}"
        );
    }
    // same per-step compression rate
    assert_eq!(native.column("rate"), kernel.column("rate"));
}

#[test]
fn eval_artifact_counts_correct_predictions() {
    require_artifacts!();
    let (_e, lm) = load("mlp");
    let params = lm.load_init_params().unwrap();
    let zoo = scalecom::models::zoo_model("mlp").unwrap();
    let ds = zoo.dataset(1);
    let batch = ds.eval_batch(lm.mm.batch);
    let (loss, correct) = lm.eval_step(&params, &batch).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!(correct >= 0.0 && correct <= lm.mm.batch as f32);
}

#[test]
fn train_step_rejects_wrong_shapes() {
    require_artifacts!();
    let (_e, lm) = load("mlp");
    let params = lm.load_init_params().unwrap();
    let zoo = scalecom::models::zoo_model("mlp").unwrap();
    let ds = zoo.dataset(1);
    let mut batch = ds.batch(0, 1, 0, lm.mm.batch);
    batch.x.pop(); // corrupt
    assert!(lm.train_step(&params, &batch).is_err());

    let short_params = vec![0.0f32; lm.mm.dim - 1];
    let batch2 = ds.batch(0, 1, 0, lm.mm.batch);
    assert!(lm.train_step(&short_params, &batch2).is_err());
}

#[test]
fn gradients_differ_across_worker_shards() {
    require_artifacts!();
    let (_e, lm) = load("mlp");
    let params = lm.load_init_params().unwrap();
    let zoo = scalecom::models::zoo_model("mlp").unwrap();
    let ds = zoo.dataset(1);
    let b0 = ds.batch(0, 2, 0, lm.mm.batch);
    let b1 = ds.batch(1, 2, 0, lm.mm.batch);
    let (_, g0) = lm.train_step(&params, &b0).unwrap();
    let (_, g1) = lm.train_step(&params, &b1).unwrap();
    assert_ne!(g0, g1, "different shards must give different gradients");
    // but statistically correlated (same distribution) — cosine < 1
    let cos = scalecom::stats::cosine_distance(&g0, &g1);
    assert!(cos < 0.9, "shard gradients should correlate, dist={cos}");
}
