//! Smoke tests for the analytic (non-training) experiment drivers and
//! the CLI surface; the training drivers are exercised by their own
//! `--quick` paths in examples/EXPERIMENTS runs. The analytic drivers
//! below need no artifacts; the one training-backed smoke test gates on
//! artifact presence so a bare checkout stays green.

#[test]
fn perfmodel_experiments_run() {
    scalecom::experiments::run("fig1b", true).unwrap();
    scalecom::experiments::run("fig6", true).unwrap();
    scalecom::experiments::run("figA8", true).unwrap();
}

#[test]
fn fig1a_runs_quick() {
    scalecom::experiments::run("fig1a", true).unwrap();
}

#[test]
fn unknown_experiment_rejected() {
    let err = scalecom::experiments::run("fig99", true).unwrap_err();
    assert!(err.to_string().contains("unknown experiment"));
}

#[test]
fn experiment_list_covers_all_paper_items() {
    let ids: Vec<&str> = scalecom::experiments::list().iter().map(|(i, _)| *i).collect();
    for required in [
        "table1", "fig1a", "fig1b", "fig1c", "fig2", "fig3", "table2", "table3",
        "fig6", "figA8", "figA1",
    ] {
        assert!(ids.contains(&required), "missing {required}");
    }
}

#[test]
fn training_experiment_runs_quick_when_artifacts_present() {
    if !scalecom::runtime::artifacts_present() {
        eprintln!(
            "skipping training experiment smoke: artifacts/manifest.json not \
             found — run `make artifacts`"
        );
        return;
    }
    scalecom::experiments::run("fig2", true).unwrap();
}

#[test]
fn perf_model_headline_numbers_sane() {
    use scalecom::models::paper::paper_net;
    use scalecom::perfmodel::{speedup, Scheme, SystemConfig};
    let net = paper_net("resnet50").unwrap();
    let sys = SystemConfig {
        workers: 128,
        minibatch_per_worker: 8,
        ..SystemConfig::default()
    };
    let s = speedup(&net, &sys, Scheme::ScaleCom, Scheme::None);
    assert!(s > 1.5 && s < 3.5, "headline 2x claim, got {s}");
}
