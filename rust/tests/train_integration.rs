//! Integration tests over the full three-layer stack (PJRT artifacts +
//! coordinator + trainer). Requires `make artifacts`; on a bare checkout
//! (no `artifacts/manifest.json`) every test skips with a message so
//! `cargo test -q` stays green.

use scalecom::config::train::{CompressConfig, TrainConfig};
use scalecom::trainer::Trainer;

/// Skip (pass vacuously, with a note) when artifacts are absent.
macro_rules! require_artifacts {
    () => {
        if !scalecom::runtime::artifacts_present() {
            eprintln!(
                "skipping {}: artifacts/manifest.json not found — run `make artifacts`",
                module_path!()
            );
            return;
        }
    };
}

fn base_cfg(model: &str, scheme: &str, workers: usize, steps: usize) -> TrainConfig {
    let zoo = scalecom::models::zoo_model(model).unwrap();
    TrainConfig {
        model: model.to_string(),
        workers,
        steps,
        batch_per_worker: zoo.batch_per_worker,
        compress: CompressConfig {
            scheme: scheme.to_string(),
            rate: zoo.default_rate,
            ..CompressConfig::default()
        },
        ..TrainConfig::default()
    }
}

#[test]
fn mlp_dense_baseline_learns() {
    require_artifacts!();
    let log = Trainer::from_config(base_cfg("mlp", "none", 2, 60))
        .unwrap()
        .run()
        .unwrap();
    let first = log.rows.first().unwrap()[1];
    let last = log.tail_mean("loss", 10).unwrap();
    assert!(
        last < first * 0.3,
        "loss should drop sharply: {first} -> {last}"
    );
}

#[test]
fn mlp_scalecom_reaches_parity_with_dense() {
    require_artifacts!();
    let dense = Trainer::from_config(base_cfg("mlp", "none", 4, 200))
        .unwrap()
        .run()
        .unwrap();
    // table-2 recipe: short dense warmup (<10% of steps) then compress
    let mut comp_cfg = base_cfg("mlp", "scalecom", 4, 200);
    comp_cfg.compress.warmup_steps = 10;
    let comp = Trainer::from_config(comp_cfg).unwrap().run().unwrap();
    let dense_loss = dense.tail_mean("loss", 20).unwrap();
    let comp_loss = comp.tail_mean("loss", 20).unwrap();
    // Table-2-style parity: compressed within a small absolute gap.
    assert!(
        (comp_loss - dense_loss).abs() < 0.35,
        "dense={dense_loss:.4} scalecom={comp_loss:.4}"
    );
}

#[test]
fn training_is_deterministic_for_fixed_seed() {
    require_artifacts!();
    let a = Trainer::from_config(base_cfg("mlp", "scalecom", 3, 20))
        .unwrap()
        .run()
        .unwrap();
    let b = Trainer::from_config(base_cfg("mlp", "scalecom", 3, 20))
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(a.column("loss"), b.column("loss"));
    let mut cfg = base_cfg("mlp", "scalecom", 3, 20);
    cfg.seed = 7;
    let c = Trainer::from_config(cfg).unwrap().run().unwrap();
    assert_ne!(a.column("loss"), c.column("loss"));
}

#[test]
fn ring_and_ps_topologies_give_identical_updates() {
    require_artifacts!();
    let mut ps_cfg = base_cfg("mlp", "scalecom", 4, 30);
    ps_cfg.fabric_topology = "ps".into();
    let mut ring_cfg = base_cfg("mlp", "scalecom", 4, 30);
    ring_cfg.fabric_topology = "ring".into();
    let ps = Trainer::from_config(ps_cfg).unwrap().run().unwrap();
    let ring = Trainer::from_config(ring_cfg).unwrap().run().unwrap();
    // Functionally identical reduction; only the cost model differs.
    assert_eq!(ps.column("loss"), ring.column("loss"));
    assert_ne!(ps.column("comm_time_s"), ring.column("comm_time_s"));
}

#[test]
fn compression_warmup_goes_dense_first() {
    require_artifacts!();
    let mut cfg = base_cfg("mlp", "scalecom", 2, 10);
    cfg.compress.warmup_steps = 5;
    let log = Trainer::from_config(cfg).unwrap().run().unwrap();
    let rates = log.column("rate").unwrap();
    for t in 0..5 {
        assert_eq!(rates[t], 1.0, "step {t} should be dense warmup");
    }
    for t in 5..10 {
        assert!(rates[t] > 10.0, "step {t} should be compressed");
    }
}

#[test]
fn comm_bytes_reflect_compression_rate() {
    require_artifacts!();
    let dense = Trainer::from_config(base_cfg("mlp", "none", 4, 5))
        .unwrap()
        .run()
        .unwrap();
    let comp = Trainer::from_config(base_cfg("mlp", "scalecom", 4, 5))
        .unwrap()
        .run()
        .unwrap();
    let dense_up = dense.last("bytes_up").unwrap();
    let comp_up = comp.last("bytes_up").unwrap();
    // ~92x rate, 8B sparse pairs vs 4B dense → ~46x fewer bytes
    assert!(
        dense_up / comp_up > 20.0,
        "dense {dense_up} vs compressed {comp_up}"
    );
}

#[test]
fn eval_reports_high_accuracy_after_training() {
    require_artifacts!();
    let mut cfg = base_cfg("mlp", "scalecom", 4, 120);
    cfg.eval_every = 0;
    let mut t = Trainer::from_config(cfg).unwrap();
    t.run().unwrap();
    let (_, acc) = t.evaluate().unwrap();
    assert!(acc > 0.9, "eval accuracy {acc}");
}

#[test]
fn all_schemes_run_end_to_end_briefly() {
    require_artifacts!();
    for scheme in [
        "none",
        "scalecom",
        "scalecom-exact",
        "local-topk",
        "true-topk",
        "random-k",
        "gtop-k",
    ] {
        let log = Trainer::from_config(base_cfg("mlp", scheme, 3, 6))
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(log.rows.len(), 6, "{scheme}");
        let loss = log.last("loss").unwrap();
        assert!(loss.is_finite(), "{scheme} produced loss {loss}");
    }
}

#[test]
fn per_layer_flops_rule_runs_and_reports_rate() {
    require_artifacts!();
    let mut cfg = base_cfg("cnn", "scalecom", 2, 6);
    cfg.compress.use_flops_rule = true;
    let log = Trainer::from_config(cfg).unwrap().run().unwrap();
    let rate = log.last("rate").unwrap();
    assert!(rate > 5.0, "layered rate {rate}");
}

#[test]
fn beta_switch_takes_effect() {
    require_artifacts!();
    let mut cfg = base_cfg("mlp", "scalecom", 2, 10);
    cfg.compress.beta = 0.1;
    let mut t = Trainer::from_config(cfg).unwrap();
    t.beta_switch = Some((5, 1.0));
    t.run().unwrap();
    assert_eq!(t.coordinator.memory_snapshot()[0].beta(), 1.0);
}

#[test]
fn concurrent_backends_train_to_the_same_losses_as_sequential() {
    require_artifacts!();
    let mut seq_cfg = base_cfg("mlp", "scalecom", 4, 30);
    seq_cfg.backend = "sequential".into();
    let seq = Trainer::from_config(seq_cfg).unwrap().run().unwrap();
    let sl = seq.column("loss").unwrap();
    for backend in ["threaded", "pipelined"] {
        let mut cfg = base_cfg("mlp", "scalecom", 4, 30);
        cfg.backend = backend.into();
        let other = Trainer::from_config(cfg).unwrap().run().unwrap();
        let ol = other.column("loss").unwrap();
        for (t, (a, b)) in sl.iter().zip(&ol).enumerate() {
            // f32 reduction-order tolerance, amplified a little by training
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                "step {t}: sequential {a} vs {backend} {b}"
            );
        }
        // identical bytes on the wire
        assert_eq!(seq.column("bytes_up"), other.column("bytes_up"));
        assert_eq!(seq.column("bytes_down"), other.column("bytes_down"));
    }
}

#[test]
fn batch_size_mismatch_is_rejected() {
    require_artifacts!();
    let mut cfg = base_cfg("mlp", "none", 2, 5);
    cfg.batch_per_worker = 7; // artifact was lowered with 32
    let err = match Trainer::from_config(cfg) {
        Err(e) => e,
        Ok(_) => panic!("mismatched batch size must be rejected"),
    };
    assert!(err.to_string().contains("artifact"), "{err}");
}
