//! Compressor micro-benchmarks (Table 1 "overhead" column).
//!
//! Measures selection throughput per element for every scheme at the
//! paper's gradient scale, plus the error-feedback memory update and the
//! sparsify/gather primitives — the L3 compression hot path.

use scalecom::bench::{black_box, Bencher};
use scalecom::compress::chunk::chunk_top1_indices;
use scalecom::compress::{schemes::make_compressor, sparsify, EfMemory};
use scalecom::util::rng::Rng;
use scalecom::util::select::{top_k_indices_by_magnitude, top_k_via_heap};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };

    // ResNet18-scale flat gradient (11.7M) is the paper's reference; use
    // 2M to keep bench wall-time sane, report per-element.
    let dim: usize = if quick { 200_000 } else { 2_000_000 };
    let rate = 112usize;
    let k = dim / rate;
    let mut rng = Rng::new(1);
    let mut grad = vec![0.0f32; dim];
    rng.fill_normal(&mut grad, 1.0);

    println!("# selection primitives over dim={dim} (k={k}, rate={rate}x)");
    let r = b.bench("select/quickselect_topk", || {
        black_box(top_k_indices_by_magnitude(&grad, k));
    });
    println!("#   -> {:.3} ns/elem", r.per_elem(dim));
    let r = b.bench("select/heap_topk", || {
        black_box(top_k_via_heap(&grad, k));
    });
    println!("#   -> {:.3} ns/elem", r.per_elem(dim));
    let r = b.bench("select/chunk_top1 (paper quasi-sort)", || {
        black_box(chunk_top1_indices(&grad, rate));
    });
    println!("#   -> {:.3} ns/elem", r.per_elem(dim));

    println!("# full scheme selection, 4 workers");
    let grads: Vec<Vec<f32>> = (0..4)
        .map(|_| {
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();
    let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    for scheme in ["scalecom", "scalecom-exact", "local-topk", "true-topk", "random-k"] {
        let mut c = make_compressor(scheme, rate, 1).unwrap();
        let mut t = 0usize;
        let r = b.bench(&format!("scheme/{scheme}"), || {
            black_box(c.select(t, &views, k));
            t += 1;
        });
        println!("#   -> {:.3} ns/elem", r.per_elem(dim));
    }

    println!("# error-feedback memory + sparsify");
    let idx = chunk_top1_indices(&grad, rate);
    let mut mem = EfMemory::new(dim, 0.1);
    b.bench("memory/lowpass_update", || {
        mem.update_after_send(&grad, &idx);
    });
    b.bench("memory/ef_grad", || {
        black_box(mem.ef_grad(&grad));
    });
    b.bench("sparsify/gather_k", || {
        black_box(sparsify(&grad, &idx));
    });
}
