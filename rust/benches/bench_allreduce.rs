//! Collective benchmarks: wall time of the three reduce paths (dense,
//! shared-index sparse, gather) vs worker count — the microbench behind
//! Fig 1(a) — plus the end-to-end compressed pipeline (chunked top-k
//! select → sparsify → reduce → memory update) on both execution
//! backends.
//!
//! Usage:
//!   cargo bench --bench bench_allreduce [-- --quick] [-- --backend sequential|threaded]
//!
//! Without `--backend`, the pipeline section runs both backends so the
//! speedup is visible side by side; the acceptance target is ≥2x for
//! `pipeline/threaded/n8` over `pipeline/sequential/n8`.

use scalecom::bench::{black_box, Bencher};
use scalecom::comm::{Backend, Fabric, FabricConfig, Topology};
use scalecom::compress::schemes::CltK;
use scalecom::compress::SparseGrad;
use scalecom::coordinator::{Coordinator, Mode};
use scalecom::util::rng::Rng;

fn fabric(n: usize, topo: Topology) -> Fabric {
    Fabric::new(FabricConfig {
        workers: n,
        topology: topo,
        ..FabricConfig::default()
    })
}

fn rand_grads(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect()
}

/// One full compressed step — CLT-k chunked selection over the ring —
/// on the chosen backend. This is the "chunked top-k + ring reduce" path
/// the threaded engine is built to accelerate.
fn bench_pipeline(b: &mut Bencher, backend: Backend, n: usize, dim: usize, rate: usize) {
    let mut coord = Coordinator::new(
        n,
        dim,
        Mode::Compressed(Box::new(CltK::chunked(rate))),
        0.5,
        (dim / rate).max(1),
        fabric(n, Topology::Ring),
        0,
    )
    .with_backend(backend);
    let mut rng = Rng::new(n as u64);
    let grads = rand_grads(&mut rng, n, dim);
    let mut t = 0usize;
    b.bench(&format!("pipeline/{}/n{n}", backend.label()), || {
        black_box(coord.step(t, &grads));
        t += 1;
    });
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let backends = scalecom::comm::parallel::backends_from_args(&args);

    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let dim: usize = if quick { 100_000 } else { 1_000_000 };
    let rate = 112;
    let k = dim / rate;

    // --- raw collectives (cost-model fabric, sequential execution) ------
    for n in [4usize, 16, 64] {
        let mut rng = Rng::new(n as u64);
        let grads = rand_grads(&mut rng, n, dim);

        b.bench(&format!("dense_allreduce/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.dense_allreduce_avg(&grads));
        });

        // shared-index (ScaleCom) path
        let idx: Vec<u32> = (0..k as u32).map(|i| i * rate as u32).collect();
        let sparses: Vec<SparseGrad> = grads
            .iter()
            .map(|g| SparseGrad::gather_from(g, &idx))
            .collect();
        b.bench(&format!("sparse_allreduce_shared/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.sparse_allreduce_shared(&sparses, 0));
        });

        // gather (local top-k) path with mostly-disjoint per-worker sets
        let gathers: Vec<SparseGrad> = (0..n)
            .map(|w| {
                let mut ix: Vec<u32> = (0..k)
                    .map(|i| ((w + i * n) % dim) as u32)
                    .collect();
                ix.sort_unstable();
                ix.dedup();
                SparseGrad::gather_from(&grads[w], &ix)
            })
            .collect();
        b.bench(&format!("sparse_gather_avg/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.sparse_gather_avg(&gathers));
        });

        // ring topology variant for the shared path (Remark 3)
        b.bench(&format!("sparse_allreduce_shared_ring/n{n}"), || {
            let mut f = fabric(n, Topology::Ring);
            black_box(f.sparse_allreduce_shared(&sparses, 0));
        });

        // threaded channel collective over real worker threads
        b.bench(&format!("threaded_dense_allreduce/n{n}"), || {
            black_box(scalecom::runtime::threaded::dense_allreduce_avg(&grads));
        });
    }

    // --- full pipeline: backend comparison ------------------------------
    println!("# pipeline = EF-grad + chunked top-k select + sparsify + ring reduce + memory update");
    for n in [2usize, 8] {
        for &backend in &backends {
            bench_pipeline(&mut b, backend, n, dim, rate);
        }
    }
    if backends.len() == 2 {
        let find = |name: &str| {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.median_ns)
        };
        if let (Some(seq), Some(thr)) = (
            find("pipeline/sequential/n8"),
            find("pipeline/threaded/n8"),
        ) {
            println!("# pipeline n8 speedup (threaded vs sequential): {:.2}x", seq / thr);
        }
    }
}
