//! Collective benchmarks: wall time of the three reduce paths (dense,
//! shared-index sparse, gather) vs worker count — the microbench behind
//! Fig 1(a) — plus the end-to-end compressed pipeline (chunked top-k
//! select → sparsify → reduce → memory update) on every execution
//! backend, and the compute/communication overlap efficiency of the
//! pipelined engine against the analytic `max(compute, comm)` model.
//!
//! Usage:
//!   cargo bench --bench bench_allreduce [-- --quick] [-- --backend sequential|threaded|pipelined|socket]
//!     [-- --codec] [-- --assert-codec] [-- --bucketed] [-- --hier] [-- --simnet] [-- --obs]
//!     [-- --assert-trace-overhead] [-- --json path]
//!
//! The `obs/*` section measures the tracing spine's overhead contract:
//! the disabled span guard's per-call cost (one relaxed load — the
//! "tracing off is a no-op" half), the n=8 pipelined step with tracing
//! off vs on (the ≤5% half), and the per-step latency distribution
//! through the log-bucketed `obs::Histogram` with p50/p95/p99 derived
//! entries in the JSON artifact. `--obs` runs only this section;
//! `--assert-trace-overhead` turns the contract into a CI gate
//! (lenient 1.15x vs the 1.05 quiet-hardware target, same policy as
//! the overlap gate).
//!
//! The `hier/*` section re-runs the chunked CLT-k pipeline on the pooled
//! backends with the dense ring collective on the two-level
//! ring-of-rings (`--group-size` in the trainer): flat (g0) vs g=2/g=4
//! at n = 8/16, with the step-time ratio tracked in the JSON artifact so
//! the bench-trend gate catches topology regressions. `--hier` runs only
//! that section (the CI hier smoke job).
//!
//! The `codec/*` section measures the wire entropy codec: bytes-on-wire
//! and encode/decode ns per frame for dense chunks, sparse gathers, and
//! index broadcasts under every `--wire-compression` mode, with derived
//! index-shrink and overhead-vs-wire-time metrics in the JSON artifact.
//! `--codec` runs only that section; `--assert-codec` turns its targets
//! (≥ 2x index shrink at ≤1% density, ≤ 10% overhead at 1 GbE) into a
//! CI gate.
//!
//! Without `--backend`, the pipeline section runs all backends so the
//! speedups are visible side by side — including `socket`, the same
//! persistent pool with every collective hop crossing a loopback TCP
//! socket through the wire codec (its step-time gap vs `pipelined` IS
//! the framing + kernel cost of a real transport). Acceptance targets on
//! the chunked top-k + ring path at n=8:
//!   - `pipeline/threaded/n8`  ≥ 2x over `pipeline/sequential/n8`;
//!   - `pipeline/pipelined/n8` step time ≤ 0.75x `pipeline/threaded/n8`
//!     (the persistent pool + double-buffer win).
//!
//! The overlap section (n = 2..16) separates the pipelined engine's two
//! modes: `sync` submits and waits every step (no lookahead), `stream`
//! double-buffers via `step_overlapped`, and `comm_only` drives just the
//! staged comm lanes. With Tc = sync − comm and Tm = comm, the analytic
//! model (`perfmodel::step_time_overlapped`) predicts
//! stream ≈ max(Tc, Tm); measured efficiency is the fraction of the
//! hideable min(Tc, Tm) the engine actually hides.
//!
//! The bucketed section (n = 2..16, every selected backend) compares the
//! monolithic layered step against the per-bucket scheduler
//! (`Coordinator::step_bucketed`, 8 buckets in backward order); at n=8
//! the measured efficiency is printed next to
//! `perfmodel::step_time_bucketed`'s prediction. `-- --bucketed` runs
//! only this section (the CI bucketed smoke job).

use scalecom::bench::{black_box, Bencher};
use scalecom::comm::parallel::{CollectiveResult, CommJob, CommLanes};
use scalecom::comm::{Backend, BucketPlan, Fabric, FabricConfig, Topology};
use scalecom::compress::rate::LayerSlice;
use scalecom::compress::schemes::CltK;
use scalecom::compress::{LayerPartition, SparseGrad};
use scalecom::coordinator::{Coordinator, Mode};
use scalecom::json::Json;
use scalecom::perfmodel;
use scalecom::simnet::{self, SimConfig, TopologyProfile, SIM_SCHEMES};
use scalecom::util::rng::Rng;
use std::collections::BTreeMap;

fn fabric(n: usize, topo: Topology) -> Fabric {
    Fabric::new(FabricConfig {
        workers: n,
        topology: topo,
        ..FabricConfig::default()
    })
}

fn rand_grads(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect()
}

fn pipeline_coord(backend: Backend, n: usize, dim: usize, rate: usize) -> Coordinator {
    Coordinator::new(
        n,
        dim,
        Mode::Compressed(Box::new(CltK::chunked(rate))),
        0.5,
        (dim / rate).max(1),
        fabric(n, Topology::Ring),
        0,
    )
    .with_backend(backend)
}

/// One full compressed step — CLT-k chunked selection over the ring —
/// on the chosen backend. This is the "chunked top-k + ring reduce" path
/// the threaded and pipelined engines are built to accelerate. The
/// pooled backends (pipelined/socket) run in their double-buffered
/// streaming mode (step t+1's EF/selection compute overlaps step t's
/// in-flight collective).
fn bench_pipeline(b: &mut Bencher, backend: Backend, n: usize, dim: usize, rate: usize) {
    let mut coord = pipeline_coord(backend, n, dim, rate);
    let mut rng = Rng::new(n as u64);
    let grads = rand_grads(&mut rng, n, dim);
    let mut t = 0usize;
    let name = format!("pipeline/{}/n{n}", backend.label());
    if backend.is_pooled() {
        b.bench(&name, || {
            black_box(coord.step_overlapped(t, &grads));
            t += 1;
        });
        let _ = coord.finish_overlapped();
    } else {
        b.bench(&name, || {
            black_box(coord.step(t, &grads));
            t += 1;
        });
    }
}

/// Uniform layer partition with `buckets` layers (one bucket each) and
/// the per-layer budgets for `rate`.
fn uniform_buckets(dim: usize, rate: usize, buckets: usize) -> (LayerPartition, Vec<usize>, BucketPlan) {
    assert_eq!(dim % buckets, 0, "uniform bucket split");
    let len = dim / buckets;
    let layers: Vec<LayerSlice> = (0..buckets)
        .map(|i| LayerSlice {
            name: format!("seg{i}"),
            offset: i * len,
            len,
            flops_per_sample: 0.0,
            compress: true,
        })
        .collect();
    let partition = LayerPartition::from_layers(layers);
    let ks = partition.per_layer_k(rate as f64, 32, false);
    let plan = BucketPlan::from_partition(&partition, len * 4);
    assert_eq!(plan.num_buckets(), buckets);
    (partition, ks, plan)
}

/// The bucketed-exchange section: the same layered CLT-k step driven
/// monolithically (`step`) vs per bucket (`step_bucketed`, the
/// backward-order overlap driver). The ratio IS the measured overlap
/// win; at n=8 it is printed next to `perfmodel::step_time_bucketed`'s
/// prediction for the same bucket count.
fn bench_bucketed(b: &mut Bencher, backend: Backend, n: usize, dim: usize, rate: usize, buckets: usize) -> (f64, f64) {
    let (partition, ks, plan) = uniform_buckets(dim, rate, buckets);
    let mk = || {
        pipeline_coord(backend, n, dim, rate).with_layered(partition.clone(), ks.clone())
    };
    let mut mono = mk();
    let mut rng = Rng::new(n as u64 + 31);
    let grads = rand_grads(&mut rng, n, dim);
    let label = backend.label();
    let mut t0 = 0usize;
    let t_mono = b
        .bench(&format!("bucketed/mono/{label}/n{n}"), || {
            black_box(mono.step(t0, &grads));
            t0 += 1;
        })
        .median_ns;
    let mut buck = mk().with_buckets(plan);
    let mut t1 = 0usize;
    let t_buck = b
        .bench(&format!("bucketed/b{buckets}/{label}/n{n}"), || {
            black_box(buck.step_bucketed(t1, &grads));
            t1 += 1;
        })
        .median_ns;
    println!(
        "# bucketed {label} n={n}: mono {:.1}us bucketed({buckets}) {:.1}us | overlap efficiency {:.2}x",
        t_mono / 1e3,
        t_buck / 1e3,
        t_mono / t_buck
    );
    (t_mono, t_buck)
}

/// Measured overlap efficiency of the pipelined engine vs the analytic
/// max(compute, comm) model, at n = 2..16.
fn bench_overlap(b: &mut Bencher, n: usize, dim: usize, rate: usize, derived: &mut Vec<(String, f64)>) {
    let k = (dim / rate).max(1);

    // Tm: the staged collective alone, on a persistent mesh.
    let mut rng = Rng::new(7 + n as u64);
    let vals = rand_grads(&mut rng, n, k);
    let lanes = CommLanes::new(n);
    let t_comm = b
        .bench(&format!("overlap/comm_only/n{n}"), || {
            lanes.submit(
                vals.iter()
                    .map(|v| CommJob::RingAvg { bucket: 0, buf: v.clone() })
                    .collect(),
            );
            match lanes.wait() {
                CollectiveResult::Reduced { vals: v, .. } => {
                    black_box(v);
                }
                other => unreachable!("expected ring result, got {other:?}"),
            }
        })
        .median_ns;
    drop(lanes);

    let grads = rand_grads(&mut rng, n, dim);

    // Tc + Tm: submit + wait every step — no lookahead.
    let mut sync = pipeline_coord(Backend::Pipelined, n, dim, rate);
    let mut t0 = 0usize;
    let t_sync = b
        .bench(&format!("overlap/pipelined_sync/n{n}"), || {
            black_box(sync.step(t0, &grads));
            t0 += 1;
        })
        .median_ns;

    // Double-buffered: the overlap the engine exists for.
    let mut stream = pipeline_coord(Backend::Pipelined, n, dim, rate);
    let mut t1 = 0usize;
    let t_stream = b
        .bench(&format!("overlap/pipelined_stream/n{n}"), || {
            black_box(stream.step_overlapped(t1, &grads));
            t1 += 1;
        })
        .median_ns;
    let _ = stream.finish_overlapped();

    let t_compute = (t_sync - t_comm).max(0.0);
    let model = t_compute.max(t_comm);
    let hideable = t_compute.min(t_comm);
    let measured_eff = if hideable > 0.0 {
        ((t_sync - t_stream) / hideable).clamp(0.0, 1.0)
    } else {
        0.0
    };
    println!(
        "# overlap n={n}: sync {:.1}us stream {:.1}us comm {:.1}us | \
         model max(Tc,Tm) {:.1}us | measured efficiency {:.2} (model 1.00)",
        t_sync / 1e3,
        t_stream / 1e3,
        t_comm / 1e3,
        model / 1e3,
        measured_eff
    );
    derived.push((format!("overlap/n{n}_measured_efficiency"), measured_eff));
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    // CI gate: exit non-zero when the pipelined engine loses its step-time
    // edge over threaded (lenient 0.90 vs the 0.75 quiet-hardware target,
    // to absorb shared-runner noise). Requires both backends to run.
    let assert_overlap = args.iter().any(|a| a == "--assert-overlap");
    // Run ONLY the bucketed-exchange section (the CI bucketed smoke job).
    let bucketed_only = args.iter().any(|a| a == "--bucketed");
    // Run ONLY the hierarchical-topology section (the CI hier smoke job).
    let hier_only = args.iter().any(|a| a == "--hier");
    // Run ONLY the simnet scaling section (virtual time, no threads).
    let simnet_only = args.iter().any(|a| a == "--simnet");
    // Run ONLY the wire-codec section (the CI codec smoke job).
    let codec_only = args.iter().any(|a| a == "--codec");
    // CI gate on the codec section: fail when the delta+varint index
    // packing stops shrinking sparse-frame index bytes ≥ 2x at a ≤1%
    // top-k rate, or when codec encode+decode overhead exceeds 10% of
    // the raw frame's wire time at the 1 GbE reference.
    let assert_codec = args.iter().any(|a| a == "--assert-codec");
    // Run ONLY the tracing-overhead + step-distribution section.
    let obs_only = args.iter().any(|a| a == "--obs");
    // CI gate on the tracing spine's overhead contract: fail when the
    // n=8 pipelined step with recording on exceeds 1.15x the recording-
    // off step (lenient vs the 1.05 quiet-hardware target), or when the
    // disabled span guard stops being a near-free call.
    let assert_trace_overhead = args.iter().any(|a| a == "--assert-trace-overhead");
    // Machine-readable results: every bench median + the derived
    // speedups/efficiencies, so the perf trajectory is tracked across
    // PRs (CI uploads the file as an artifact).
    let json_path: Option<String> = args
        .iter()
        .position(|a| a == "--json")
        .map(|i| args.get(i + 1).expect("--json requires a path").clone());
    let backends = scalecom::comm::parallel::backends_from_args(&args);

    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let mut derived: Vec<(String, f64)> = Vec::new();
    let dim: usize = if quick { 100_000 } else { 1_000_000 };
    let rate = 112;
    let k = dim / rate;

    if simnet_only {
        run_simnet_section(quick, &mut derived);
        write_json(json_path.as_deref(), &b, &derived);
        return;
    }
    if bucketed_only {
        run_bucketed_section(&mut b, &backends, quick, dim, rate, &mut derived);
        write_json(json_path.as_deref(), &b, &derived);
        return;
    }
    if hier_only {
        run_hier_section(&mut b, &backends, quick, dim, rate, &mut derived);
        write_json(json_path.as_deref(), &b, &derived);
        return;
    }
    if codec_only {
        let violations = run_codec_section(&mut b, quick, &mut derived, assert_codec);
        write_json(json_path.as_deref(), &b, &derived);
        fail_on_codec_violations(&violations);
        return;
    }
    if obs_only {
        let violations = run_obs_section(&mut b, quick, dim, rate, &mut derived, assert_trace_overhead);
        write_json(json_path.as_deref(), &b, &derived);
        fail_on_trace_violations(&violations);
        return;
    }

    // --- raw collectives (cost-model fabric, sequential execution) ------
    for n in [4usize, 16, 64] {
        let mut rng = Rng::new(n as u64);
        let grads = rand_grads(&mut rng, n, dim);

        b.bench(&format!("dense_allreduce/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.dense_allreduce_avg(&grads));
        });

        // shared-index (ScaleCom) path
        let idx: Vec<u32> = (0..k as u32).map(|i| i * rate as u32).collect();
        let sparses: Vec<SparseGrad> = grads
            .iter()
            .map(|g| SparseGrad::gather_from(g, &idx))
            .collect();
        b.bench(&format!("sparse_allreduce_shared/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.sparse_allreduce_shared(&sparses, 0));
        });

        // gather (local top-k) path with mostly-disjoint per-worker sets
        let gathers: Vec<SparseGrad> = (0..n)
            .map(|w| {
                let mut ix: Vec<u32> = (0..k)
                    .map(|i| ((w + i * n) % dim) as u32)
                    .collect();
                ix.sort_unstable();
                ix.dedup();
                SparseGrad::gather_from(&grads[w], &ix)
            })
            .collect();
        b.bench(&format!("sparse_gather_avg/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.sparse_gather_avg(&gathers));
        });

        // ring topology variant for the shared path (Remark 3)
        b.bench(&format!("sparse_allreduce_shared_ring/n{n}"), || {
            let mut f = fabric(n, Topology::Ring);
            black_box(f.sparse_allreduce_shared(&sparses, 0));
        });

        // threaded channel collective over real worker threads
        b.bench(&format!("threaded_dense_allreduce/n{n}"), || {
            black_box(scalecom::runtime::threaded::dense_allreduce_avg(&grads));
        });
    }

    // --- full pipeline: backend comparison ------------------------------
    println!("# pipeline = EF-grad + chunked top-k select + sparsify + ring reduce + memory update");
    for n in [2usize, 8] {
        for &backend in &backends {
            bench_pipeline(&mut b, backend, n, dim, rate);
        }
    }
    let find = |b: &Bencher, name: &str| {
        b.results()
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median_ns)
    };
    if let (Some(seq), Some(thr)) = (
        find(&b, "pipeline/sequential/n8"),
        find(&b, "pipeline/threaded/n8"),
    ) {
        println!("# pipeline n8 speedup (threaded vs sequential): {:.2}x", seq / thr);
        derived.push(("pipeline/n8_threaded_vs_sequential_speedup".into(), seq / thr));
    }
    if let (Some(thr), Some(pipe)) = (
        find(&b, "pipeline/threaded/n8"),
        find(&b, "pipeline/pipelined/n8"),
    ) {
        println!(
            "# pipeline n8 speedup (pipelined vs threaded): {:.2}x \
             (step-time ratio {:.2}, target ≤ 0.75)",
            thr / pipe,
            pipe / thr
        );
        derived.push(("pipeline/n8_pipelined_vs_threaded_speedup".into(), thr / pipe));
    }
    if let (Some(pipe), Some(sock)) = (
        find(&b, "pipeline/pipelined/n8"),
        find(&b, "pipeline/socket/n8"),
    ) {
        println!(
            "# pipeline n8 transport cost (socket vs pipelined): {:.2}x step \
             time — the price of real framing + kernel round-trips",
            sock / pipe
        );
        derived.push(("pipeline/n8_socket_vs_pipelined_ratio".into(), sock / pipe));
    }
    if assert_overlap {
        let thr = find(&b, "pipeline/threaded/n8")
            .expect("--assert-overlap needs the threaded pipeline bench (drop --backend)");
        let pipe = find(&b, "pipeline/pipelined/n8")
            .expect("--assert-overlap needs the pipelined pipeline bench (drop --backend)");
        let ratio = pipe / thr;
        derived.push(("pipeline/n8_overlap_gate_ratio".into(), ratio));
        if ratio > 0.90 {
            eprintln!(
                "OVERLAP REGRESSION: pipelined/threaded step-time ratio \
                 {ratio:.2} > 0.90 at n=8 — the persistent pool lost its edge"
            );
            // The perf snapshot is most valuable on the regressing run:
            // flush what was measured before failing the gate.
            write_json(json_path.as_deref(), &b, &derived);
            std::process::exit(1);
        }
        println!("# overlap gate OK: pipelined/threaded step-time ratio {ratio:.2} <= 0.90");
    }

    // --- overlap efficiency: measured vs analytic max(Tc, Tm) ----------
    if backends.contains(&Backend::Pipelined) {
        println!("# overlap: sync = submit+wait, stream = double-buffered, comm_only = staged lanes");
        for n in [2usize, 4, 8, 16] {
            bench_overlap(&mut b, n, dim, rate, &mut derived);
        }
    }

    // --- bucketed exchange: per-bucket scheduler vs monolithic ----------
    run_bucketed_section(&mut b, &backends, quick, dim, rate, &mut derived);

    // --- hierarchical topology: flat ring vs ring-of-rings --------------
    run_hier_section(&mut b, &backends, quick, dim, rate, &mut derived);

    // --- wire entropy codec: bytes-on-wire + encode/decode cost ---------
    let violations = run_codec_section(&mut b, quick, &mut derived, assert_codec);

    // --- tracing spine: off = no-op, on = bounded overhead ---------------
    let trace_violations =
        run_obs_section(&mut b, quick, dim, rate, &mut derived, assert_trace_overhead);

    // --- simnet: the paper-style scaling curve in virtual time ----------
    run_simnet_section(quick, &mut derived);

    write_json(json_path.as_deref(), &b, &derived);
    fail_on_codec_violations(&violations);
    fail_on_trace_violations(&trace_violations);
}

/// Exit non-zero on `--assert-trace-overhead` violations — AFTER the
/// JSON snapshot is flushed (same policy as the codec/overlap gates).
fn fail_on_trace_violations(violations: &[String]) {
    if violations.is_empty() {
        return;
    }
    for v in violations {
        eprintln!("TRACE OVERHEAD REGRESSION: {v}");
    }
    std::process::exit(1);
}

/// Tracing-spine section: the overhead contract plus the step-latency
/// distribution.
///
/// 1. `obs/span_disabled` — the cost of an instrumentation site with
///    recording off: build + drop a [`scalecom::obs::SpanGuard`]. This
///    is one relaxed atomic load and must stay in the nanoseconds.
/// 2. `obs/step_trace_{off,on}/n8` — the full n=8 pipelined compressed
///    step with the recorder disarmed vs armed; the ratio is the price
///    of `--trace-out` on a real run (contract: ≤ 5% on quiet
///    hardware, gated at 15% to absorb shared-runner noise).
/// 3. `allreduce/n8_step_p{50,95,99}_ns` — per-step wall time pushed
///    through the same log-bucketed [`scalecom::obs::Histogram`] that
///    backs serve `/metrics`, so the JSON artifact tracks the tail,
///    not just the median.
fn run_obs_section(
    b: &mut Bencher,
    quick: bool,
    dim: usize,
    rate: usize,
    derived: &mut Vec<(String, f64)>,
    assert_trace_overhead: bool,
) -> Vec<String> {
    use scalecom::obs;
    println!("# obs = tracing spine overhead (off must be a no-op, on ≤ 5% step time) + step-latency tail");
    let mut violations = Vec::new();

    obs::set_enabled(false);
    let disabled_ns = b
        .bench("obs/span_disabled", || {
            black_box(obs::span(obs::Category::Select).step(black_box(7)));
        })
        .median_ns;
    println!("# obs: disabled span guard costs {disabled_ns:.1} ns/site");
    derived.push(("obs/span_disabled_ns".into(), disabled_ns));
    if assert_trace_overhead && disabled_ns > 100.0 {
        violations.push(format!(
            "disabled span guard costs {disabled_ns:.0} ns/site (> 100 ns) — \
             the tracing-off path is no longer a no-op"
        ));
    }

    let n = 8;
    let mut rng = Rng::new(88);
    let grads = rand_grads(&mut rng, n, dim);

    let mut coord_off = pipeline_coord(Backend::Pipelined, n, dim, rate);
    let mut t_off = 0usize;
    let off_ns = b
        .bench("obs/step_trace_off/n8", || {
            black_box(coord_off.step_overlapped(t_off, &grads));
            t_off += 1;
        })
        .median_ns;
    let _ = coord_off.finish_overlapped();

    obs::set_enabled(true);
    let mut coord_on = pipeline_coord(Backend::Pipelined, n, dim, rate);
    let mut t_on = 0usize;
    let on_ns = b
        .bench("obs/step_trace_on/n8", || {
            black_box(coord_on.step_overlapped(t_on, &grads));
            t_on += 1;
        })
        .median_ns;
    let _ = coord_on.finish_overlapped();
    obs::set_enabled(false);
    // Free the spans the armed run recorded; the rings are bounded, but
    // later sections shouldn't inherit a half-full recorder.
    let _ = obs::span::drain_all();

    let ratio = on_ns / off_ns;
    println!(
        "# obs n8 step: trace off {:.1} us, on {:.1} us — recording overhead {:+.1}% \
         (target ≤ 5%, gate ≤ 15%)",
        off_ns / 1e3,
        on_ns / 1e3,
        (ratio - 1.0) * 100.0
    );
    derived.push(("obs/n8_trace_overhead_ratio".into(), ratio));
    if assert_trace_overhead {
        if ratio > 1.15 {
            violations.push(format!(
                "tracing-on step time is {ratio:.3}x tracing-off at n=8 (> 1.15) — \
                 recording is no longer cheap enough to leave armed"
            ));
        } else {
            println!("# trace-overhead gate OK: on/off step-time ratio {ratio:.3} <= 1.15");
        }
    }

    // Step-latency distribution through the serving-path histogram: the
    // bench harness reports medians; the tail (p95/p99) is where pool
    // hiccups and socket stalls live.
    let hist = obs::Histogram::new();
    let mut coord = pipeline_coord(Backend::Pipelined, n, dim, rate);
    let steps = if quick { 30 } else { 100 };
    for t in 0..steps {
        let start = std::time::Instant::now();
        black_box(coord.step_overlapped(t, &grads));
        hist.record_ns(start.elapsed().as_nanos() as u64);
    }
    let _ = coord.finish_overlapped();
    let snap = hist.snapshot();
    for (label, p) in [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)] {
        let ns = snap.percentile_ns(p) as f64;
        println!(
            "# obs n8 step latency {label}: {:.1} us (log-bucket upper edge, {} samples)",
            ns / 1e3,
            snap.count
        );
        derived.push((format!("allreduce/n8_step_{label}_ns"), ns));
    }

    violations
}

/// Exit non-zero on `--assert-codec` violations — AFTER the JSON
/// snapshot is flushed (the perf artifact is most valuable on the
/// regressing run, same policy as the overlap gate).
fn fail_on_codec_violations(violations: &[String]) {
    if violations.is_empty() {
        return;
    }
    for v in violations {
        eprintln!("CODEC REGRESSION: {v}");
    }
    std::process::exit(1);
}

/// Wire entropy-codec section: bytes-on-wire and encode/decode cost per
/// frame for the payloads the socket transport actually ships — a dense
/// ring chunk of incompressible random f32s, sparse gathers at top-k
/// rates 112x and 400x (≤ 1% density), and a CLT-k index broadcast.
///
/// The derived overhead fractions relate codec cost to the UNCOMPRESSED
/// frame's serialization time at 1 GbE (the gated reference) and 10 GbE.
/// The in-process fabric models 32 GB/s links, where no byte codec can
/// pay for itself — the codec exists for real Ethernet transports, so
/// those are the honest denominators.
///
/// Returns the `--assert-codec` violations (empty when the gate holds).
fn run_codec_section(
    b: &mut Bencher,
    quick: bool,
    derived: &mut Vec<(String, f64)>,
    assert_codec: bool,
) -> Vec<String> {
    use scalecom::comm::codec::{
        index_deltas_len, CodecStats, FrameCodec, WireCodecConfig, WireCompression,
    };
    use scalecom::comm::wire::{self, WireMsg};

    let dim: usize = if quick { 100_000 } else { 1_000_000 };
    println!(
        "# codec = wire entropy codec: bytes-on-wire + encode/decode per frame \
         (dim={dim}; overhead vs the raw frame's wire time at 1 / 10 GbE)"
    );
    let mut rng = Rng::new(42);

    // Frames under test. Sparse index gaps are drawn uniformly from
    // 1..2·rate (mean ≈ rate), the distribution a top-k selection over
    // i.i.d. gradients actually produces.
    let mut dense_vals = vec![0.0f32; dim];
    rng.fill_normal(&mut dense_vals, 1.0);
    let mut frames: Vec<(String, WireMsg)> =
        vec![("dense".into(), WireMsg::DenseChunk { bucket: 0, vals: dense_vals })];
    let mut sparse_meta: Vec<(String, Vec<u32>)> = Vec::new();
    for rate in [112usize, 400] {
        let mut idx: Vec<u32> = Vec::with_capacity(dim / rate + 1);
        let mut pos = 0usize;
        loop {
            pos += 1 + rng.next_below(2 * rate as u64 - 1) as usize;
            if pos >= dim {
                break;
            }
            idx.push(pos as u32);
        }
        let mut vals = vec![0.0f32; idx.len()];
        rng.fill_normal(&mut vals, 1.0);
        sparse_meta.push((format!("sparse_r{rate}"), idx.clone()));
        if rate == 112 {
            frames.push((format!("indices_r{rate}"), WireMsg::Indices(idx.clone())));
        }
        frames.push((
            format!("sparse_r{rate}"),
            WireMsg::Sparse { bucket: 0, grad: SparseGrad::new(dim, idx, vals) },
        ));
    }

    // Bench every mode × frame; keep the medians for the overhead math.
    let mut med: BTreeMap<(String, String), (f64, f64)> = BTreeMap::new();
    for (mode_label, mode) in [
        ("off", WireCompression::Off),
        ("delta", WireCompression::Delta),
        ("full", WireCompression::Full),
    ] {
        let stats = CodecStats::new();
        let mut enc = FrameCodec::new(WireCodecConfig::with_mode(mode), stats.clone());
        let mut dec = FrameCodec::new(WireCodecConfig::with_mode(mode), stats);
        let mut frame_buf: Vec<u8> = Vec::new();
        for (frame_label, msg) in &frames {
            let enc_ns = b
                .bench(&format!("codec/enc/{mode_label}/{frame_label}"), || {
                    enc.encode_frame_into(msg, &mut frame_buf).expect("encode");
                    black_box(frame_buf.len());
                })
                .median_ns;
            derived.push((
                format!("codec/{frame_label}/{mode_label}_wire_bytes"),
                (frame_buf.len() - 4) as f64,
            ));
            let body = frame_buf[4..].to_vec();
            let dec_ns = b
                .bench(&format!("codec/dec/{mode_label}/{frame_label}"), || {
                    black_box(dec.decode_body(&body).expect("decode"));
                })
                .median_ns;
            med.insert((mode_label.to_string(), frame_label.clone()), (enc_ns, dec_ns));
        }
    }

    let mut violations = Vec::new();

    // Index-bytes shrink of the delta+varint packing, computed exactly
    // from the layouts (no timer noise in the gated number).
    for (label, idx) in &sparse_meta {
        let raw = (4 * idx.len()) as f64;
        let packed = index_deltas_len(idx) as f64;
        let shrink = raw / packed;
        println!(
            "# codec {label}: {} indices, raw {raw:.0} B -> delta+varint {packed:.0} B \
             ({shrink:.2}x)",
            idx.len()
        );
        derived.push((format!("codec/{label}/index_shrink"), shrink));
        if assert_codec && shrink < 2.0 {
            violations.push(format!(
                "{label}: delta+varint index bytes shrank only {shrink:.2}x (< 2x) at a \
                 ≤1% top-k rate"
            ));
        }
    }

    // Codec overhead = (enc+dec of the mode) − (enc+dec of off), against
    // the raw frame's serialization time: 8 ns/byte at 1 GbE, 0.8 at 10.
    for (frame_label, msg) in &frames {
        let raw_bytes = (wire::frame_len(msg) - 4) as f64;
        let (enc0, dec0) = med[&("off".to_string(), frame_label.clone())];
        for mode_label in ["delta", "full"] {
            let (enc1, dec1) = med[&(mode_label.to_string(), frame_label.clone())];
            let overhead_ns = ((enc1 + dec1) - (enc0 + dec0)).max(0.0);
            let o1 = overhead_ns / (raw_bytes * 8.0);
            let o10 = overhead_ns / (raw_bytes * 0.8);
            println!(
                "# codec {frame_label} {mode_label}: enc+dec overhead {:.1} us = {:.2}% \
                 of the raw frame's 1 GbE wire time ({:.2}% at 10 GbE)",
                overhead_ns / 1e3,
                o1 * 100.0,
                o10 * 100.0
            );
            derived.push((format!("codec/{frame_label}/{mode_label}_overhead_1gbe"), o1));
            derived.push((format!("codec/{frame_label}/{mode_label}_overhead_10gbe"), o10));
            if assert_codec && o1 > 0.10 {
                violations.push(format!(
                    "{frame_label} ({mode_label}): codec enc+dec overhead {:.2}% of the \
                     raw frame's 1 GbE wire time (> 10%)",
                    o1 * 100.0
                ));
            }
        }
    }
    if assert_codec && violations.is_empty() {
        println!(
            "# codec gate OK: index shrink ≥ 2x, enc+dec overhead ≤ 10% of the raw \
             frame's 1 GbE wire time"
        );
    }
    violations
}

/// Paper-style scaling curve for every scheme at n ∈ {8, 16, 64, 256}:
/// the real selection/EF code runs at scales the host cannot thread,
/// with communication charged against the uniform topology profile in
/// deterministic virtual time (`simnet`).
fn run_simnet_section(quick: bool, derived: &mut Vec<(String, f64)>) {
    let profile = TopologyProfile::uniform();
    let ns: &[usize] = if quick { &[8, 64] } else { &[8, 16, 64, 256] };
    println!(
        "# simnet = real coordination code under simulated link timing \
         (virtual ms/step, uniform profile)"
    );
    for scheme in SIM_SCHEMES {
        let mut row = format!("# simnet {scheme:<12}");
        for &n in ns {
            let cfg = SimConfig {
                workers: n,
                dim: if quick { 16_384 } else { 65_536 },
                scheme: scheme.to_string(),
                rate: 112,
                steps: 3,
                layers: 16,
                ..SimConfig::default()
            };
            let r = simnet::simulate(&cfg, &profile).expect("simnet simulate");
            let ms = r.mean_step_s() * 1e3;
            row.push_str(&format!("  n{n}={ms:.3}ms"));
            derived.push((format!("simnet/{scheme}/n{n}_step_ms"), ms));
        }
        println!("{row}");
    }
}

/// Write every bench median plus the derived metrics as JSON (the
/// `--json <path>` satellite; CI uploads it as `BENCH_allreduce.json`).
fn write_json(path: Option<&str>, b: &Bencher, derived: &[(String, f64)]) {
    let Some(path) = path else { return };
    let results: Vec<Json> = b
        .results()
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Json::Str(r.name.clone()));
            m.insert("median_ns".to_string(), Json::Num(r.median_ns));
            m.insert("p10_ns".to_string(), Json::Num(r.p10_ns));
            m.insert("p90_ns".to_string(), Json::Num(r.p90_ns));
            m.insert("iters".to_string(), Json::Num(r.iters as f64));
            Json::Obj(m)
        })
        .collect();
    let mut d = BTreeMap::new();
    for (key, val) in derived {
        d.insert(key.clone(), Json::Num(*val));
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("allreduce".to_string()));
    root.insert("results".to_string(), Json::Arr(results));
    root.insert("derived".to_string(), Json::Obj(d));
    std::fs::write(path, Json::Obj(root).to_string_pretty()).expect("write --json output");
    println!("# wrote {path}");
}

/// Hierarchical-topology section, shared between the full run and
/// `--hier`: the pooled backends' chunked CLT-k pipeline with the dense
/// ring collective on the two-level ring-of-rings (the trainer's
/// `--group-size`), flat (g0) baseline vs g=2/g=4 at each scale. The
/// step-time ratio IS the measured cost (or win) of trading the flat
/// ring's 2(n−1) chunk rounds for intra reduce + leader uplink +
/// chain broadcast; it lands in the JSON artifact as `hier/*` so the
/// bench-trend gate tracks it across PRs.
fn run_hier_section(
    b: &mut Bencher,
    backends: &[Backend],
    quick: bool,
    dim: usize,
    rate: usize,
    derived: &mut Vec<(String, f64)>,
) {
    println!(
        "# hier = chunked CLT-k pipeline with the dense ring collective on the \
         two-level ring-of-rings (g = group size, g0 = flat ring baseline)"
    );
    let ns: &[usize] = if quick { &[8] } else { &[8, 16] };
    for &backend in backends.iter().filter(|be| be.is_pooled()) {
        for &n in ns {
            let mut flat_ns = None;
            for g in [0usize, 2, 4] {
                if g != 0 && (n % g != 0 || n / g < 2) {
                    continue;
                }
                let mut coord = Coordinator::new(
                    n,
                    dim,
                    Mode::Compressed(Box::new(CltK::chunked(rate))),
                    0.5,
                    (dim / rate).max(1),
                    fabric(n, Topology::Ring),
                    0,
                )
                .with_group_size(g)
                .with_backend(backend);
                let mut rng = Rng::new(0x417 + n as u64);
                let grads = rand_grads(&mut rng, n, dim);
                let mut t = 0usize;
                let med = b
                    .bench(&format!("hier/{}/n{n}/g{g}", backend.label()), || {
                        black_box(coord.step_overlapped(t, &grads));
                        t += 1;
                    })
                    .median_ns;
                let _ = coord.finish_overlapped();
                if g == 0 {
                    flat_ns = Some(med);
                } else if let Some(flat) = flat_ns {
                    println!(
                        "# hier {} n={n} g={g}: {:.1}us vs flat {:.1}us ({:.2}x)",
                        backend.label(),
                        med / 1e3,
                        flat / 1e3,
                        med / flat
                    );
                    derived.push((
                        format!("hier/{}/n{n}_g{g}_vs_flat_ratio", backend.label()),
                        med / flat,
                    ));
                }
            }
        }
    }
}

/// Bucketed section, shared between the full run and `--bucketed`:
/// every selected backend at n = 2..16, with the n=8 measured overlap
/// efficiency reported against `perfmodel::step_time_bucketed`.
fn run_bucketed_section(
    b: &mut Bencher,
    backends: &[Backend],
    quick: bool,
    dim: usize,
    rate: usize,
    derived: &mut Vec<(String, f64)>,
) {
    let buckets = 8usize;
    println!(
        "# bucketed = layered CLT-k step driven per bucket (step_bucketed, backward order) \
         vs the monolithic layered step"
    );
    let ns: &[usize] = if quick { &[2, 8] } else { &[2, 4, 8, 16] };
    for &backend in backends {
        for &n in ns {
            let (t_mono, t_buck) = bench_bucketed(b, backend, n, dim, rate, buckets);
            if n == 8 {
                // Analytic counterpart: the same bucket count on the
                // paper's ResNet50 system point. The measured ratio is a
                // CPU-simulation proxy; the model states what the same
                // schedule buys on the paper's hardware envelope.
                let net = scalecom::models::paper::paper_net("resnet50").expect("paper net");
                let sys = perfmodel::SystemConfig {
                    workers: n,
                    ..perfmodel::SystemConfig::default()
                };
                let serial = perfmodel::step_time(&net, &sys, perfmodel::Scheme::ScaleCom);
                let bucketed_model =
                    perfmodel::step_time_bucketed(&net, &sys, perfmodel::Scheme::ScaleCom, buckets);
                println!(
                    "# bucketed {} n=8: measured efficiency {:.2}x | model serial/bucketed({buckets}) \
                     {:.2}x (ideal max(Tc,Tm) + fill bubble)",
                    backend.label(),
                    t_mono / t_buck,
                    serial.total_s / bucketed_model.total_s
                );
                derived.push((
                    format!("bucketed/{}/n8_measured_efficiency", backend.label()),
                    t_mono / t_buck,
                ));
                derived.push((
                    format!("bucketed/{}/n8_model_efficiency", backend.label()),
                    serial.total_s / bucketed_model.total_s,
                ));
            }
        }
    }
}
