//! Collective benchmarks: wall time of the three reduce paths (dense,
//! shared-index sparse, gather) vs worker count — the microbench behind
//! Fig 1(a).

use scalecom::bench::{black_box, Bencher};
use scalecom::comm::{Fabric, FabricConfig, Topology};
use scalecom::compress::SparseGrad;
use scalecom::util::rng::Rng;

fn fabric(n: usize, topo: Topology) -> Fabric {
    Fabric::new(FabricConfig {
        workers: n,
        topology: topo,
        ..FabricConfig::default()
    })
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let dim: usize = if quick { 100_000 } else { 1_000_000 };
    let rate = 112;
    let k = dim / rate;

    for n in [4usize, 16, 64] {
        let mut rng = Rng::new(n as u64);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; dim];
                rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();

        b.bench(&format!("dense_allreduce/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.dense_allreduce_avg(&grads));
        });

        // shared-index (ScaleCom) path
        let idx: Vec<u32> = (0..k as u32).map(|i| i * rate as u32).collect();
        let sparses: Vec<SparseGrad> = grads
            .iter()
            .map(|g| SparseGrad::gather_from(g, &idx))
            .collect();
        b.bench(&format!("sparse_allreduce_shared/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.sparse_allreduce_shared(&sparses, 0));
        });

        // gather (local top-k) path with mostly-disjoint per-worker sets
        let gathers: Vec<SparseGrad> = (0..n)
            .map(|w| {
                let mut ix: Vec<u32> = (0..k)
                    .map(|i| ((w + i * n) % dim) as u32)
                    .collect();
                ix.sort_unstable();
                ix.dedup();
                SparseGrad::gather_from(&grads[w], &ix)
            })
            .collect();
        b.bench(&format!("sparse_gather_avg/n{n}"), || {
            let mut f = fabric(n, Topology::ParameterServer);
            black_box(f.sparse_gather_avg(&gathers));
        });

        // ring topology variant for the shared path (Remark 3)
        b.bench(&format!("sparse_allreduce_shared_ring/n{n}"), || {
            let mut f = fabric(n, Topology::Ring);
            black_box(f.sparse_allreduce_shared(&sparses, 0));
        });
    }
}
