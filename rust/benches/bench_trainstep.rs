//! End-to-end training-step benchmark (the Fig 6-style breakdown,
//! measured on the real three-layer stack): PJRT forward/backward,
//! compression, reduce, optimizer — per model, per scheme, per backend.
//!
//! Requires `make artifacts`.
//!
//! Usage:
//!   cargo bench --bench bench_trainstep [-- --quick] [-- --backend sequential|threaded|pipelined]
//!
//! Without `--backend`, every configuration runs on all backends
//! (`Backend::ALL` via `backends_from_args`, which routes the flag
//! through `Backend::parse`). The trainer drives the pipelined pool in
//! its synchronous mode (the optimizer needs g^t before the next
//! forward/backward); the measured end-to-end overlap efficiency lives
//! in `bench_allreduce`'s overlap section, where the gradient stream is
//! independent of the updates.

use scalecom::bench::Bencher;
use scalecom::comm::Backend;
use scalecom::config::train::TrainConfig;
use scalecom::trainer::Trainer;

fn bench_model(
    b: &mut Bencher,
    model: &str,
    scheme: &str,
    workers: usize,
    backend: Backend,
) {
    let mut cfg = TrainConfig {
        model: model.to_string(),
        workers,
        steps: 1,
        backend: backend.label().to_string(),
        ..TrainConfig::default()
    };
    if let Ok(zoo) = scalecom::models::zoo_model(model) {
        cfg.batch_per_worker = zoo.batch_per_worker;
        cfg.compress.rate = zoo.default_rate;
    }
    cfg.compress.scheme = scheme.to_string();
    cfg.lr = 0.01;
    let mut trainer = match Trainer::from_config(cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("skipping {model}/{scheme}: {e:#} (run `make artifacts`?)");
            return;
        }
    };
    b.bench(
        &format!("trainstep/{model}/{scheme}/w{workers}/{}", backend.label()),
        || {
            trainer.run().expect("train step");
        },
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let backends = scalecom::comm::parallel::backends_from_args(&args);

    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    b.measure_s = if quick { 0.2 } else { 2.0 };

    for &backend in &backends {
        for model in ["mlp", "cnn", "transformer", "lstm"] {
            for scheme in ["none", "scalecom", "local-topk"] {
                bench_model(&mut b, model, scheme, 4, backend);
            }
        }
        // worker scaling on the cheapest model
        for workers in [2usize, 8, 16] {
            bench_model(&mut b, "mlp", "scalecom", workers, backend);
        }
    }
}
