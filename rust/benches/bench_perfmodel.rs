//! Performance-model sweep benchmark: evaluates the full Fig 6/A8/A9
//! grid (5 networks × schemes × worker counts × bandwidths) and times
//! the analytic model itself (it must stay trivially cheap — it runs
//! inside experiment sweeps).

use scalecom::bench::{black_box, Bencher};
use scalecom::models::paper::{paper_net, ALL_PAPER_NETS};
use scalecom::perfmodel::{step_time, Scheme, SystemConfig};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };

    let nets: Vec<_> = ALL_PAPER_NETS
        .iter()
        .map(|n| paper_net(n).unwrap())
        .collect();

    b.bench("perfmodel/full_grid", || {
        let mut acc = 0.0f64;
        for net in &nets {
            for &n in &[8usize, 32, 128] {
                for &bw in &[32.0, 64.0] {
                    for &mb in &[8usize, 32] {
                        for scheme in [Scheme::None, Scheme::LocalTopK, Scheme::ScaleCom] {
                            let sys = SystemConfig {
                                workers: n,
                                bandwidth_gbps: bw,
                                minibatch_per_worker: mb,
                                ..SystemConfig::default()
                            };
                            acc += step_time(net, &sys, scheme).total_s;
                        }
                    }
                }
            }
        }
        black_box(acc);
    });

    // print the headline numbers so `cargo bench` output doubles as a
    // quick sanity table
    let net = paper_net("resnet50").unwrap();
    for (tflops, mb) in [(100.0, 8), (100.0, 32), (300.0, 8), (300.0, 32)] {
        let sys = SystemConfig {
            workers: 128,
            peak_tflops: tflops,
            minibatch_per_worker: mb,
            ..SystemConfig::default()
        };
        let base = step_time(&net, &sys, Scheme::None).total_s;
        let sc = step_time(&net, &sys, Scheme::ScaleCom).total_s;
        println!(
            "# resnet50 @{tflops:.0}T mb={mb}: scalecom speedup {:.2}x (paper: 2x/1.23x @100T, 4.1x/1.75x @300T)",
            base / sc
        );
    }
}
