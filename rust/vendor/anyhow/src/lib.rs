//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored crate
//! provides exactly the surface the repo uses: `Error`, `Result`,
//! `anyhow!` / `bail!` / `ensure!`, and the `Context` extension trait.
//! Semantics match upstream for that subset:
//!   - any `std::error::Error + Send + Sync + 'static` converts via `?`
//!     (the source chain is captured),
//!   - `{e}` prints the outermost message, `{e:#}` the full chain
//!     separated by ": ", and `{e:?}` a "Caused by:" listing,
//!   - `Context` works on `Result<T, E>` for both std errors and
//!     `anyhow::Error` itself.
//!
//! Swap the `vendor/anyhow` path dependency for the registry crate when
//! network access is available — no call sites need to change.

use std::fmt;

/// Boxed error with a context chain. `chain[0]` is the outermost
/// (most recently attached) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion
// coherent with the std identity `From`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
        assert!(format!("{e:?}").contains("Caused by"));
        assert_eq!(e.root_cause(), "gone");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn with_context_on_anyhow_error_and_option() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner 7");
        let o: Option<u8> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_bail_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("x != 5"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
    }
}
