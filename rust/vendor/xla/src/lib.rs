//! Compile-only stub of the `xla-rs` PJRT bindings.
//!
//! The build image carries no XLA/PJRT native libraries, so this crate
//! mirrors exactly the API surface `scalecom::runtime::engine` uses and
//! fails **at runtime** with a clear message instead of failing the build.
//! Everything that does not require executing HLO (literal construction,
//! reshape bookkeeping) behaves normally; client creation and execution
//! return [`Error`].
//!
//! The trainer only reaches this code after `artifacts/manifest.json` has
//! been found, and all artifact-dependent tests skip when artifacts are
//! absent, so a bare checkout builds and tests green. To actually execute
//! artifacts, point the `xla` path dependency in `rust/Cargo.toml` at the
//! real bindings.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} is unavailable — this build vendors a compile-only \
         PJRT stub (no XLA native libraries in the image); substitute the real \
         `xla` crate in rust/Cargo.toml to execute artifacts"
    ))
}

/// Element types literals can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host literal. The stub tracks nothing — it only needs to typecheck the
/// build path; any attempt to read values back errors.
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar(_value: f32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_entry_points_error_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
