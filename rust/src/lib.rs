//! ScaleCom: Scalable Sparsified Gradient Compression for
//! Communication-Efficient Distributed Training (NeurIPS 2020, IBM Research).
//!
//! Full-system reproduction. Three layers:
//!  - L3 (this crate): distributed-training coordinator — workers, compressed
//!    collectives, error-feedback memory with low-pass filtering, the CLT-k
//!    compressor, optimizers, schedules, metrics, and an analytic performance
//!    model reproducing the paper's system-performance figures.
//!  - L2 (python/compile/model*.py): JAX forward/backward graphs for the
//!    model zoo, AOT-lowered to HLO text and executed from Rust via PJRT.
//!  - L1 (python/compile/kernels/*.py): Pallas kernels for the compression
//!    hot-spot (chunk-wise top-k selection, low-pass memory update), lowered
//!    into the same HLO artifacts.
//!
//! Python never runs on the training hot path: `make artifacts` runs once,
//! the Rust binary is self-contained afterwards.

pub mod bench;
pub mod cli;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod json;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod perfmodel;
pub mod proptest;
pub mod runtime;
pub mod serve;
pub mod simnet;
pub mod stats;
pub mod trainer;
pub mod util;
