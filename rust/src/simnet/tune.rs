//! The bucket-plan autotuner behind `scalecom tune`.
//!
//! `--bucket-bytes` has been a hand-set flag since the bucketed exchange
//! landed; the right value is a function of the compute/comm cost ratio
//! (`perfmodel::step_time_bucketed`: finer buckets shrink the pipeline
//! fill bubble but pay per-collective latency). The tuner closes the
//! loop:
//!
//! 1. **Calibrate** `Tc`: run a few *measured* real coordination steps
//!    (sequential backend, wall clock) and derive the per-element
//!    compute cost;
//! 2. **Sweep**: enumerate every achievable layer-aligned bucket plan
//!    for the workload's partition — plus the monolithic plan under the
//!    double-buffered `step_overlapped` driving mode — and simulate each
//!    through the virtual-clock engine on the chosen topology profile;
//! 3. **Pick** the plan with the smallest mean virtual step time and
//!    report it as `--bucket-bytes` (0 with `step_overlapped` when
//!    cross-step overlap wins).
//!
//! On the uniform profile the sweep's shape is validated against the
//! analytic closed form `max(Tc, Tm) + min(Tc, Tm)/B`
//! (`perfmodel::step_time_bucketed`); see the simnet properties in
//! `src/proptest/mod.rs`.

use crate::comm::BucketPlan;
use crate::simnet::engine::{self, SimConfig};
use crate::simnet::profile::TopologyProfile;

/// Tuner workload description (the knobs `scalecom tune` exposes).
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub workers: usize,
    pub dim: usize,
    pub scheme: String,
    pub rate: usize,
    pub layers: usize,
    /// Simulated steps per candidate plan.
    pub steps: usize,
    pub seed: u64,
    /// Measured real steps for the Tc calibration (plus one warmup step
    /// that is discarded).
    pub calibration_steps: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            workers: 64,
            dim: 65_536,
            scheme: "scalecom".into(),
            rate: 100,
            layers: 16,
            steps: 4,
            seed: 42,
            calibration_steps: 3,
        }
    }
}

impl TuneConfig {
    /// CLI-facing validation: the same clean errors `simulate` gives,
    /// raised before the calibration path can hit an internal assert.
    pub fn check(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "tune needs at least one worker");
        anyhow::ensure!(self.dim >= 1, "tune needs a non-empty gradient");
        anyhow::ensure!(
            self.layers >= 1 && self.layers <= self.dim,
            "--layers must be in [1, dim]"
        );
        anyhow::ensure!(self.rate >= 1, "--rate must be >= 1");
        anyhow::ensure!(self.steps >= 1, "tune needs at least one simulated step");
        anyhow::ensure!(
            self.calibration_steps >= 1,
            "need at least one calibration step"
        );
        Ok(())
    }

    fn sim_config(&self, bucket_bytes: usize, overlapped: bool, compute_per_elem_s: f64) -> SimConfig {
        SimConfig {
            workers: self.workers,
            dim: self.dim,
            scheme: self.scheme.clone(),
            rate: self.rate,
            steps: self.steps,
            warmup_steps: 0,
            beta: 1.0,
            seed: self.seed,
            layers: self.layers,
            bucket_bytes,
            compute_per_elem_s,
            overlapped,
        }
    }
}

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct PlanEval {
    /// The `--bucket-bytes` value that reproduces this plan (0 =
    /// monolithic).
    pub bucket_bytes: usize,
    pub buckets: usize,
    /// Whether this candidate drives the monolithic step through the
    /// double-buffered `step_overlapped` mode (exclusive with
    /// multi-bucket plans).
    pub overlapped: bool,
    pub mean_step_s: f64,
}

impl PlanEval {
    pub fn label(&self) -> String {
        if self.overlapped {
            "monolithic + step_overlapped".to_string()
        } else if self.buckets == 1 {
            "monolithic (sync)".to_string()
        } else {
            format!("{} buckets (step_bucketed)", self.buckets)
        }
    }
}

/// The tuner's verdict: the calibrated compute model, every candidate's
/// simulated step time, and the winner.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub compute_per_elem_s: f64,
    pub evals: Vec<PlanEval>,
    pub best: PlanEval,
}

/// Calibrate the per-element compute cost from measured real steps.
///
/// The engine models compute as **one worker's** lockstep cost
/// (`bucket_elems × compute_per_elem_s`, every worker in parallel), so
/// the calibration measures exactly that: a **single-worker**
/// sequential coordinator — one EF-gradient update + one selection over
/// `dim` elements per step, no cross-worker fan-out to mis-scale Tc by
/// the simulated worker count. The fastest observed step is taken as
/// the machine's clean-step cost (minimum, not median — scheduler noise
/// only ever adds time).
pub fn calibrate_compute_per_elem(cfg: &TuneConfig) -> anyhow::Result<f64> {
    cfg.check()?;
    // The engine itself never reads the wall clock — determinism is its
    // contract — so the measured calibration lives here: the same
    // coordinator construction, timed for real.
    let partition = engine::uniform_partition(cfg.dim, cfg.layers);
    let ks = partition.per_layer_k(cfg.rate as f64, 32, false);
    let fabric = crate::comm::Fabric::new(crate::comm::FabricConfig {
        workers: 1,
        ..crate::comm::FabricConfig::default()
    });
    let k = ((cfg.dim as f64 / cfg.rate as f64).ceil() as usize).max(1);
    let mode = if cfg.scheme == "none" {
        crate::coordinator::Mode::Dense
    } else {
        crate::coordinator::Mode::Compressed(crate::compress::make_compressor(
            &cfg.scheme,
            cfg.rate,
            cfg.seed,
        )?)
    };
    let mut coordinator =
        crate::coordinator::Coordinator::new(1, cfg.dim, mode, 1.0, k, fabric, 0);
    if cfg.scheme != "none" {
        coordinator = coordinator.with_layered(partition, ks);
    }
    let mut best_s = f64::INFINITY;
    for t in 0..cfg.calibration_steps + 1 {
        let grads = engine::synthetic_grads(cfg.seed, t, 1, cfg.dim);
        let start = std::time::Instant::now();
        let _ = coordinator.try_step(t, &grads)?;
        let elapsed = start.elapsed().as_secs_f64();
        if t > 0 {
            // step 0 warms caches/allocations; discard it
            best_s = best_s.min(elapsed);
        }
    }
    Ok(best_s / cfg.dim as f64)
}

/// Every achievable `--bucket-bytes` for the workload's uniform layer
/// partition, deduplicated by the plan it produces: caps of 1..=layers
/// layers per bucket (greedy grouping makes any other cap collapse onto
/// one of these), plus 0 for the monolithic plan.
pub fn candidate_bucket_bytes(cfg: &TuneConfig) -> Vec<usize> {
    let partition = engine::uniform_partition(cfg.dim, cfg.layers);
    let max_layer_bytes = partition
        .layers
        .iter()
        .map(|l| l.len * 4)
        .max()
        .unwrap_or(4);
    let mut out: Vec<usize> = Vec::new();
    let mut seen_bucket_counts: Vec<usize> = Vec::new();
    for m in 1..=cfg.layers {
        let cap = m * max_layer_bytes;
        let plan = BucketPlan::from_partition(&partition, cap);
        if !seen_bucket_counts.contains(&plan.num_buckets()) {
            seen_bucket_counts.push(plan.num_buckets());
            out.push(cap);
        }
    }
    if !seen_bucket_counts.contains(&1) {
        out.push(0);
    }
    out
}

/// Run the sweep with an already-known compute cost (the deterministic
/// core — tests drive this directly so no wall clock is involved).
pub fn tune_with_compute(
    cfg: &TuneConfig,
    profile: &TopologyProfile,
    compute_per_elem_s: f64,
) -> anyhow::Result<TuneOutcome> {
    cfg.check()?;
    anyhow::ensure!(
        cfg.scheme != "none",
        "tuning bucket plans needs a compressed scheme (the dense \
         baseline's exchange is monolithic)"
    );
    let partition = engine::uniform_partition(cfg.dim, cfg.layers);
    let mut evals: Vec<PlanEval> = Vec::new();
    for cap in candidate_bucket_bytes(cfg) {
        let plan = BucketPlan::from_partition(&partition, cap);
        let report = engine::simulate(&cfg.sim_config(cap, false, compute_per_elem_s), profile)?;
        evals.push(PlanEval {
            // Normalize the monolithic plan to the flag's natural
            // spelling (0), whatever cap produced it.
            bucket_bytes: if plan.is_single() { 0 } else { cap },
            buckets: plan.num_buckets(),
            overlapped: false,
            mean_step_s: report.mean_step_s(),
        });
    }
    // The cross-step double-buffered mode only composes with the
    // monolithic plan (`Coordinator::try_step_overlapped` rejects
    // multi-bucket plans), so it enters the sweep as its own candidate.
    let report = engine::simulate(&cfg.sim_config(0, true, compute_per_elem_s), profile)?;
    evals.push(PlanEval {
        bucket_bytes: 0,
        buckets: 1,
        overlapped: true,
        mean_step_s: report.mean_step_s(),
    });
    let best = evals
        .iter()
        .min_by(|a, b| a.mean_step_s.partial_cmp(&b.mean_step_s).expect("finite times"))
        .expect("at least one candidate")
        .clone();
    Ok(TuneOutcome {
        compute_per_elem_s,
        evals,
        best,
    })
}

/// The full `scalecom tune` pipeline: calibrate, then sweep.
pub fn tune(cfg: &TuneConfig, profile: &TopologyProfile) -> anyhow::Result<TuneOutcome> {
    let compute_per_elem_s = calibrate_compute_per_elem(cfg)?;
    tune_with_compute(cfg, profile, compute_per_elem_s)
}

/// `--bucket-bytes auto`: run the same sweep `scalecom tune` prints
/// (calibrated unless a compute cost is given) and resolve the winner
/// to the flag value a training run should apply — the winning cap for
/// a bucketed plan, `0` for both monolithic candidates (the trainer
/// only takes the bucketed path when the flag is positive). Returns the
/// outcome too so callers can log the sweep they acted on.
pub fn auto_bucket_bytes(
    cfg: &TuneConfig,
    profile: &TopologyProfile,
    compute_per_elem_s: Option<f64>,
) -> anyhow::Result<(TuneOutcome, usize)> {
    let outcome = match compute_per_elem_s {
        Some(c) => tune_with_compute(cfg, profile, c)?,
        None => tune(cfg, profile)?,
    };
    let resolved = if outcome.best.overlapped {
        0
    } else {
        outcome.best.bucket_bytes
    };
    Ok((outcome, resolved))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::profile::{LinkProfile, StragglerProfile};

    fn uniform_zero_latency(bw_gbps: f64) -> TopologyProfile {
        TopologyProfile {
            name: "tune-test".into(),
            link: LinkProfile::new(bw_gbps, 0.0),
            group_size: 0,
            uplink: LinkProfile::new(bw_gbps, 0.0),
            slow_workers: Vec::new(),
            slow_factor: 1.0,
            straggler: StragglerProfile::none(),
            seed: 0,
        }
    }

    fn tcfg() -> TuneConfig {
        TuneConfig {
            workers: 8,
            dim: 4096,
            scheme: "scalecom".into(),
            rate: 16,
            layers: 8,
            steps: 3,
            seed: 5,
            calibration_steps: 1,
        }
    }

    #[test]
    fn candidates_cover_every_distinct_plan_exactly_once() {
        let cfg = tcfg();
        let caps = candidate_bucket_bytes(&cfg);
        let partition = engine::uniform_partition(cfg.dim, cfg.layers);
        let mut counts: Vec<usize> = caps
            .iter()
            .map(|&c| BucketPlan::from_partition(&partition, c).num_buckets())
            .collect();
        counts.sort_unstable();
        let mut dedup = counts.clone();
        dedup.dedup();
        assert_eq!(counts, dedup, "one candidate per distinct plan");
        assert!(counts.contains(&1), "monolithic always swept");
        assert!(counts.contains(&cfg.layers), "finest plan always swept");
    }

    #[test]
    fn auto_resolves_to_the_plan_tune_prints() {
        let cfg = tcfg();
        let profile = uniform_zero_latency(10.0);
        // Deterministic compute cost so both paths sweep identically.
        let cpe = 2e-9;
        let printed = tune_with_compute(&cfg, &profile, cpe).unwrap();
        let (outcome, resolved) = auto_bucket_bytes(&cfg, &profile, Some(cpe)).unwrap();
        assert_eq!(
            outcome.best.label(),
            printed.best.label(),
            "auto acts on the same winner tune prints"
        );
        let want = if printed.best.overlapped {
            0
        } else {
            printed.best.bucket_bytes
        };
        assert_eq!(resolved, want, "resolved flag reproduces the printed plan");
        // And the resolved flag round-trips onto the same bucket count.
        let partition = engine::uniform_partition(cfg.dim, cfg.layers);
        let buckets = BucketPlan::from_partition(&partition, resolved).num_buckets();
        assert_eq!(
            buckets,
            if printed.best.overlapped { 1 } else { printed.best.buckets }
        );
    }

    #[test]
    fn tune_picks_the_exhaustive_sweep_winner_within_5pct() {
        // The acceptance gate: on the uniform profile, the tuner's pick
        // must sit within 5% of the best plan found by an *independent*
        // exhaustive sweep over every achievable cap (every multiple of
        // the layer size, not just the tuner's own candidate list) plus
        // the overlapped mode.
        let cfg = tcfg();
        let profile = uniform_zero_latency(1.0);
        let cpe = 2e-8; // comm and compute both non-trivial
        let outcome = tune_with_compute(&cfg, &profile, cpe).unwrap();
        let layer_bytes = (cfg.dim / cfg.layers) * 4;
        let mut exhaustive_best = f64::INFINITY;
        for m in 0..=cfg.layers {
            let cap = m * layer_bytes; // m = 0 → monolithic (cap 0)
            let r = engine::simulate(&cfg.sim_config(cap, false, cpe), &profile).unwrap();
            exhaustive_best = exhaustive_best.min(r.mean_step_s());
        }
        let r = engine::simulate(&cfg.sim_config(0, true, cpe), &profile).unwrap();
        exhaustive_best = exhaustive_best.min(r.mean_step_s());
        assert!(
            outcome.best.mean_step_s <= exhaustive_best * 1.05,
            "tuned {} vs exhaustive {}",
            outcome.best.mean_step_s,
            exhaustive_best
        );
    }

    #[test]
    fn comm_bound_workload_prefers_bucketing_or_overlap() {
        // Slow links + visible compute: some overlap plan must beat the
        // synchronous monolithic step.
        let cfg = tcfg();
        let profile = uniform_zero_latency(0.05);
        let outcome = tune_with_compute(&cfg, &profile, 5e-8).unwrap();
        let mono_sync = outcome
            .evals
            .iter()
            .find(|e| e.buckets == 1 && !e.overlapped)
            .expect("monolithic candidate always present");
        assert!(
            outcome.best.mean_step_s < mono_sync.mean_step_s,
            "best {} vs mono {}",
            outcome.best.mean_step_s,
            mono_sync.mean_step_s
        );
        assert!(outcome.best.buckets > 1 || outcome.best.overlapped);
    }

    #[test]
    fn latency_dominated_workload_keeps_coarse_buckets() {
        // Huge per-message latency: every extra bucket pays another
        // collective's latency chain, so the tuner must not pick the
        // finest plan.
        let cfg = tcfg();
        let mut profile = uniform_zero_latency(32.0);
        profile.link = LinkProfile::new(32.0, 500.0);
        profile.uplink = profile.link;
        let outcome = tune_with_compute(&cfg, &profile, 1e-9).unwrap();
        assert!(
            outcome.best.buckets < cfg.layers,
            "latency must punish the finest plan, got {} buckets",
            outcome.best.buckets
        );
    }

    #[test]
    fn bad_configs_error_cleanly_instead_of_panicking() {
        // The CLI path must get anyhow errors, not internal asserts.
        let mut cfg = tcfg();
        cfg.layers = 0;
        assert!(calibrate_compute_per_elem(&cfg).is_err());
        assert!(tune_with_compute(&cfg, &uniform_zero_latency(1.0), 1e-9).is_err());
        let mut cfg = tcfg();
        cfg.dim = 0;
        assert!(tune_with_compute(&cfg, &uniform_zero_latency(1.0), 1e-9).is_err());
        let mut cfg = tcfg();
        cfg.workers = 0;
        assert!(tune_with_compute(&cfg, &uniform_zero_latency(1.0), 1e-9).is_err());
        let mut cfg = tcfg();
        cfg.calibration_steps = 0;
        assert!(calibrate_compute_per_elem(&cfg).is_err());
    }

    #[test]
    fn dense_scheme_rejected() {
        let mut cfg = tcfg();
        cfg.scheme = "none".into();
        let err =
            tune_with_compute(&cfg, &uniform_zero_latency(1.0), 1e-9).unwrap_err();
        assert!(err.to_string().contains("compressed"), "{err}");
    }

    #[test]
    fn calibration_produces_a_positive_cost() {
        let mut cfg = tcfg();
        cfg.dim = 1024;
        cfg.workers = 2;
        let cpe = calibrate_compute_per_elem(&cfg).unwrap();
        assert!(cpe > 0.0 && cpe.is_finite(), "{cpe}");
    }

    #[test]
    fn outcome_labels_are_human_readable() {
        let mk = |bytes, buckets, overlapped| PlanEval {
            bucket_bytes: bytes,
            buckets,
            overlapped,
            mean_step_s: 1.0,
        };
        assert_eq!(mk(0, 1, false).label(), "monolithic (sync)");
        assert_eq!(mk(0, 1, true).label(), "monolithic + step_overlapped");
        assert_eq!(mk(4096, 4, false).label(), "4 buckets (step_bucketed)");
    }
}
