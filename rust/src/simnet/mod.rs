//! Simnet: a deterministic link-level network simulator that runs the
//! **real** coordination/compression/bucketing code against **simulated**
//! communication timing in virtual time.
//!
//! Why: the paper's headline claim is *scalability* (65–400x compression
//! with excellent scaling up to 64 learners), but in-process benches can
//! only exercise as many real threads as the host has cores — and, as
//! Agarwal et al. show ("On the Utility of Gradient Compression in
//! Distributed Training Systems"), whether compression pays off at all
//! is a function of the link/compute cost ratio, which a laptop's
//! loopback cannot represent. Simnet closes that gap:
//!
//! - [`profile`] — [`TopologyProfile`]/[`LinkProfile`]: per-link
//!   bandwidth/latency, hierarchical ring-of-rings groups, slow links,
//!   and seeded straggler/jitter distributions (TOML or built-in names);
//! - [`engine`] — the virtual-clock event engine: the real sequential
//!   `Coordinator` produces every selection and value (bit-identical to
//!   the parity reference by construction), while the ring
//!   reduce-scatter/all-gather, star-gather, and per-bucket submit/wait
//!   schedules are replayed message-for-message against the profile's
//!   links, emitting a per-step/per-bucket [`TraceEvent`] timeline with
//!   a canonical digest (same seed + profile ⇒ byte-identical);
//! - [`tune`] — the bucket-plan autotuner behind `scalecom tune`:
//!   calibrates the compute cost from a few measured real steps, sweeps
//!   bucket plans (and sync vs overlapped driving) through the
//!   simulator, and emits the best `--bucket-bytes`; validated against
//!   `perfmodel::step_time_bucketed`'s closed form in the uniform case
//!   (see `src/proptest/mod.rs`).
//!
//! Simnet sits between the analytic perf model (`perfmodel` — closed
//! forms, no code execution) and the wall-clock backends (`runtime` —
//! real threads/sockets, host-bound scale): real code, modeled time,
//! arbitrary scale.

pub mod engine;
pub mod profile;
pub mod tune;

pub use engine::{
    simulate, simulate_elastic, synthetic_grads, uniform_partition, ElasticSpec, SimConfig,
    SimReport, TraceEvent, SIM_SCHEMES,
};
pub use profile::{LinkProfile, StragglerProfile, TopologyProfile};
pub use tune::{calibrate_compute_per_elem, tune, PlanEval, TuneConfig, TuneOutcome};
