//! The virtual-clock engine: real coordination, simulated time.
//!
//! `simulate` drives the **real** `Coordinator` (sequential backend —
//! the parity reference every other backend is locked against) over a
//! deterministic synthetic gradient stream, so selections, error-feedback
//! memories, and update values are exactly what the real system produces.
//! What is simulated is *time*: every message of the collective schedules
//! is charged against a [`TopologyProfile`]'s links on a virtual clock,
//! with no OS threads and no wall-clock dependence — n = 256 learners
//! simulate in milliseconds, deterministically.
//!
//! The replayed schedules are the real ones:
//!
//! - the ring reduce-scatter/all-gather uses the same `chunk_bounds` /
//!   `reduce_scatter_round` / `all_gather_round` helpers as
//!   `ring_allreduce_generic` (`comm::parallel`), so the simulator
//!   charges exactly the messages the channel/socket meshes move
//!   (locked by `sim_schedule_matches_real_ring_messages` below);
//! - the star gather serializes per-worker uploads at the root and the
//!   union download back out, the Fig 1(a) build-up shape;
//! - the bucketed timeline follows `runtime::bucketed`'s backward-order
//!   submit/wait recurrence — bucket b's exchange starts when both its
//!   selection compute is done and the link is free from bucket b+1 —
//!   which in the uniform case closes to `perfmodel::step_time_bucketed`'s
//!   `max(Tc, Tm) + min(Tc, Tm)/B` (asserted to 1e-9 in
//!   `src/proptest/mod.rs`).
//!
//! Compute is modeled as `bucket_elems × compute_per_elem_s × f(t)`
//! where `f(t) = max_w` of the profile's seeded straggler/jitter factor
//! (synchronous SGD waits for the slowest worker); `scalecom tune`
//! calibrates `compute_per_elem_s` from measured real steps.

use crate::comm::bucket::Bucket;
use crate::comm::parallel::{all_gather_round, chunk_bounds, reduce_scatter_round};
use crate::comm::{BucketPlan, Fabric, FabricConfig};
use crate::compress::{make_compressor, LayerPartition, Selection};
use crate::compress::rate::LayerSlice;
use crate::coordinator::{Coordinator, Mode};
use crate::simnet::profile::TopologyProfile;
use crate::util::rng::Rng;

/// The five paper-scale schemes `scalecom simulate` sweeps by default.
pub const SIM_SCHEMES: [&str; 5] = [
    "local-topk",
    "scalecom",
    "gtop-k",
    "sketch-k",
    "true-topk",
];

/// One simulated workload: the real coordination step's configuration
/// plus the virtual compute model.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub workers: usize,
    pub dim: usize,
    /// Compression scheme name (`make_compressor`), or "none" for the
    /// dense baseline.
    pub scheme: String,
    pub rate: usize,
    pub steps: usize,
    pub warmup_steps: usize,
    pub beta: f32,
    /// Seed of the synthetic gradient stream (independent of the
    /// profile's straggler seed).
    pub seed: u64,
    /// Uniform layer count the gradient is split into (buckets are
    /// layer-aligned, so this bounds the finest bucket plan).
    pub layers: usize,
    /// Bucketed exchange cap in bytes (0 = monolithic).
    pub bucket_bytes: usize,
    /// Virtual selection/EF compute cost per gradient element, seconds.
    /// `scalecom tune` calibrates this from measured real steps.
    pub compute_per_elem_s: f64,
    /// Cross-step double-buffered driving mode (`step_overlapped`):
    /// step t+1's compute overlaps step t's in-flight exchange.
    /// Monolithic only — composing it with a multi-bucket plan is
    /// rejected, mirroring `Coordinator::try_step_overlapped`.
    pub overlapped: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            workers: 8,
            dim: 16_384,
            scheme: "scalecom".into(),
            rate: 100,
            steps: 4,
            warmup_steps: 0,
            beta: 1.0,
            seed: 42,
            layers: 16,
            bucket_bytes: 0,
            // Stand-in until calibrated: ~2 ns/element covers the EF add
            // + chunked scan on a current core.
            compute_per_elem_s: 2e-9,
            overlapped: false,
        }
    }
}

/// A mid-run fail-stop fault injected into the virtual timeline
/// (elastic-membership mode, `scalecom simulate --elastic-kill-step`).
///
/// Worker `kill_worker` dies right after step `kill_step`'s selection
/// compute, before its first exchange message. The fleet's heartbeat
/// latches the silence within two intervals, the replacement process
/// relaunches, every pair re-runs the Hello handshake, the resume point
/// is agreed by a pass-the-minimum ring reduce, and the aborted step
/// replays — the same recovery wave the socket runtime's
/// `--reconnect` path runs for real, charged here in virtual time.
/// Selections are untouched: the replay reproduces the exact fault-free
/// values (the rollback determinism contract), so only the trace digest
/// and the timeline move.
#[derive(Debug, Clone)]
pub struct ElasticSpec {
    /// Step whose exchange the fault aborts (replayed after recovery).
    pub kill_step: usize,
    /// Rank that dies; its restart rejoins under the same rank.
    pub kill_worker: usize,
    /// Heartbeat interval in virtual seconds (detection bound = 2×).
    pub heartbeat_s: f64,
    /// Process relaunch + snapshot reload before the replacement dials
    /// back into the rendezvous listener, virtual seconds.
    pub restart_s: f64,
}

impl Default for ElasticSpec {
    fn default() -> Self {
        ElasticSpec {
            kill_step: 1,
            kill_worker: 1,
            heartbeat_s: 0.1,
            restart_s: 1.0,
        }
    }
}

/// One timed interval of the virtual timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub step: usize,
    pub bucket: u32,
    pub op: &'static str,
    pub start_s: f64,
    pub end_s: f64,
    pub bytes: usize,
}

/// Everything one simulation run produced: real selections + virtual
/// timing.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub scheme: String,
    pub workers: usize,
    pub steps: usize,
    pub dim: usize,
    /// End of the virtual timeline.
    pub total_s: f64,
    /// Summed per-step selection/EF compute wall (virtual).
    pub compute_s: f64,
    /// Summed exchange intervals (virtual; overlap means
    /// `total_s <= compute_s + comm_s`).
    pub comm_s: f64,
    pub per_step_s: Vec<f64>,
    /// The real coordinator's per-step merged selections (None = dense).
    pub selections: Vec<Option<Selection>>,
    pub trace: Vec<TraceEvent>,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl SimReport {
    pub fn mean_step_s(&self) -> f64 {
        if self.per_step_s.is_empty() {
            0.0
        } else {
            self.per_step_s.iter().sum::<f64>() / self.per_step_s.len() as f64
        }
    }

    /// Per-op rollup of the virtual-time event trace, one line per op:
    /// event count, total virtual milliseconds, total bytes. The
    /// `--trace` printed form (the raw event list lives on in the
    /// Chrome-trace file `--trace-out` writes).
    pub fn trace_summary(&self) -> String {
        let mut per_op: std::collections::BTreeMap<&'static str, (usize, f64, usize)> =
            std::collections::BTreeMap::new();
        for e in &self.trace {
            let slot = per_op.entry(e.op).or_insert((0, 0.0, 0));
            slot.0 += 1;
            slot.1 += e.end_s - e.start_s;
            slot.2 += e.bytes;
        }
        let mut out = format!("trace summary ({} events):\n", self.trace.len());
        for (op, (count, total_s, bytes)) in &per_op {
            out.push_str(&format!(
                "  {op:<16} x{count:<6} {:>10.3} ms  {bytes} bytes\n",
                total_s * 1e3
            ));
        }
        out
    }

    /// Canonical digest of the full event trace (same seed + same
    /// profile ⇒ byte-identical). Timestamps are formatted at 12
    /// significant digits, so the digest is stable across runs and
    /// platforms with IEEE-754 doubles.
    pub fn trace_digest(&self) -> String {
        let mut h = fnv1a(
            FNV_OFFSET,
            format!("{} {} {} {}\n", self.scheme, self.workers, self.steps, self.dim).as_bytes(),
        );
        for e in &self.trace {
            h = fnv1a(
                h,
                format!(
                    "{} {} {} {:.12e} {:.12e} {}\n",
                    e.step, e.bucket, e.op, e.start_s, e.end_s, e.bytes
                )
                .as_bytes(),
            );
        }
        format!("{h:016x}")
    }

    /// Digest of the per-step selections — the values half of the
    /// determinism contract (bit-identical to the sequential backend).
    pub fn selection_digest(&self) -> String {
        let mut h = FNV_OFFSET;
        for sel in &self.selections {
            match sel {
                None => h = fnv1a(h, b"dense\n"),
                Some(Selection::Shared(idx)) => {
                    h = fnv1a(h, b"shared:");
                    for &i in idx {
                        h = fnv1a(h, &i.to_le_bytes());
                    }
                    h = fnv1a(h, b"\n");
                }
                Some(Selection::PerWorker(per)) => {
                    h = fnv1a(h, b"per-worker:");
                    for w in per {
                        for &i in w {
                            h = fnv1a(h, &i.to_le_bytes());
                        }
                        h = fnv1a(h, b";");
                    }
                    h = fnv1a(h, b"\n");
                }
            }
        }
        format!("{h:016x}")
    }
}

/// The deterministic synthetic gradient stream: worker `w`'s step-`t`
/// gradient is `normal(0, 1)` from stream `(seed + t, w)` — the same
/// construction the multi-process socket workload uses, so every driver
/// that wants to compare selections can regenerate it exactly.
pub fn synthetic_grads(seed: u64, t: usize, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|w| {
            let mut g = vec![0.0f32; dim];
            Rng::for_stream(seed.wrapping_add(t as u64), w as u64).fill_normal(&mut g, 1.0);
            g
        })
        .collect()
}

/// Uniform layer split of a `dim`-element gradient into `layers` layers
/// (the first `dim % layers` layers take the remainder element each).
pub fn uniform_partition(dim: usize, layers: usize) -> LayerPartition {
    assert!(layers >= 1 && layers <= dim, "1 <= layers <= dim");
    let base = dim / layers;
    let rem = dim % layers;
    let mut out = Vec::with_capacity(layers);
    let mut offset = 0usize;
    for i in 0..layers {
        let len = base + usize::from(i < rem);
        out.push(LayerSlice {
            name: format!("seg{i}"),
            offset,
            len,
            flops_per_sample: 0.0,
            compress: true,
        });
        offset += len;
    }
    LayerPartition::from_layers(out)
}

// ----------------------------------------------------------------------
// Link-level collective timing
// ----------------------------------------------------------------------

/// Replay the ring all-reduce schedule over the workers in `ids` (ring
/// order), returning each participant's completion time. `ready[i]` is
/// when participant `i` may start. Message sizes come from the same
/// `chunk_bounds`/round helpers the executing collective uses; each
/// round, participant `i` finishes when it has both finished the
/// previous round and received its left neighbor's chunk over the
/// `left → i` link (sends are async — writer queues — exactly like the
/// channel and socket meshes).
fn sim_ring_rounds(
    profile: &TopologyProfile,
    ids: &[usize],
    elems: usize,
    bytes_per_elem: usize,
    ready: &[f64],
) -> Vec<f64> {
    let m = ids.len();
    assert_eq!(ready.len(), m);
    if m <= 1 {
        return ready.to_vec();
    }
    let bounds = chunk_bounds(elems, m);
    let mut done = ready.to_vec();
    for phase in 0..2usize {
        for s in 0..m - 1 {
            let prev = done.clone();
            for i in 0..m {
                let left = (i + m - 1) % m;
                let (_, recv_c) = if phase == 0 {
                    reduce_scatter_round(i, m, s)
                } else {
                    all_gather_round(i, m, s)
                };
                let (lo, hi) = bounds[recv_c];
                // Zero-width chunk (elems < m): the executable skips the
                // send symmetrically on both sides, so the replay
                // charges zero bytes and adds no dependency edge —
                // round count unchanged, no message on the wire.
                if hi == lo {
                    continue;
                }
                let t = profile
                    .link_between(ids[left], ids[i])
                    .time_for((hi - lo) * bytes_per_elem);
                done[i] = prev[i].max(prev[left] + t);
            }
        }
    }
    done
}

/// Ring all-reduce of `elems` values across all `n` workers, starting at
/// `start` (barrier semantics: synchronous SGD waits for the slowest
/// participant, so the exchange begins when every worker is ready).
/// Flat profiles run one ring; hierarchical profiles run the
/// ring-of-rings — intra-group reduce, inter-group ring over the group
/// leaders on the uplink, then an intra-group broadcast back. Returns
/// the time the last worker holds the result.
fn sim_ring_allreduce(
    profile: &TopologyProfile,
    n: usize,
    elems: usize,
    bytes_per_elem: usize,
    start: f64,
) -> f64 {
    if n <= 1 {
        return start;
    }
    if !profile.hierarchical_for(n) {
        let ids: Vec<usize> = (0..n).collect();
        return sim_ring_rounds(profile, &ids, elems, bytes_per_elem, &vec![start; n])
            .into_iter()
            .fold(start, f64::max);
    }
    let g = profile.group_size;
    let ngroups = n / g;
    // Intra-group all-reduce: every member ends holding the group sum.
    let mut member_done = vec![start; n];
    for grp in 0..ngroups {
        let ids: Vec<usize> = (grp * g..(grp + 1) * g).collect();
        let done = sim_ring_rounds(profile, &ids, elems, bytes_per_elem, &vec![start; g]);
        for (j, &id) in ids.iter().enumerate() {
            member_done[id] = done[j];
        }
    }
    // Inter-group ring over the group leaders (first member of each
    // group); every leader-to-leader hop crosses the uplink.
    let leaders: Vec<usize> = (0..ngroups).map(|grp| grp * g).collect();
    let ready: Vec<f64> = leaders.iter().map(|&l| member_done[l]).collect();
    let leader_done = sim_ring_rounds(profile, &leaders, elems, bytes_per_elem, &ready);
    // Broadcast the global result back around each group ring. A
    // zero-length buffer moved no chunks above and moves no broadcast
    // either (the executable skips empty sends symmetrically).
    let payload = elems * bytes_per_elem;
    let mut end = start;
    for grp in 0..ngroups {
        let mut cum = leader_done[grp];
        end = end.max(cum);
        if payload == 0 {
            continue;
        }
        for o in 1..g {
            let from = grp * g + o - 1;
            let to = grp * g + o;
            cum += profile.link_between(from, to).time_for(payload);
            end = end.max(cum);
        }
    }
    end
}

/// Star gather at worker 0: per-worker sparse uploads serialize on the
/// root's ingress in worker order, then the reduced union is downloaded
/// back to every worker over the root's egress — the gradient build-up
/// shape (downloads grow with the union).
fn sim_star_gather(
    profile: &TopologyProfile,
    wire_bytes: &[usize],
    union_bytes: usize,
    start: f64,
) -> f64 {
    let n = wire_bytes.len();
    if n <= 1 {
        return start;
    }
    let mut t = start;
    for (w, &bytes) in wire_bytes.iter().enumerate().skip(1) {
        t += profile.egress(w).time_for(bytes);
    }
    let root = profile.egress(0);
    let mut end = t;
    for _ in 1..n {
        end += root.time_for(union_bytes);
    }
    end
}

/// Index broadcast of the shared set: binomial-tree multicast from the
/// leader — ⌈log2 n⌉ sequential hop generations (the §5 "cost of index
/// communication" is O(log n) in latency, O(1) in per-worker volume),
/// each generation gated by the slowest link it could cross (barrier).
fn sim_index_bcast(profile: &TopologyProfile, n: usize, leader: usize, idx_bytes: usize, start: f64) -> f64 {
    if n <= 1 {
        return start;
    }
    let mut worst = profile.egress(leader).time_for(idx_bytes);
    for w in 0..n {
        worst = worst.max(profile.egress(w).time_for(idx_bytes));
    }
    if profile.hierarchical_for(n) {
        worst = worst.max(profile.uplink.time_for(idx_bytes));
    }
    let depth = (usize::BITS - (n - 1).leading_zeros()) as usize;
    start + depth as f64 * worst
}

/// gTop-k's ⌈log2 n⌉ pairwise merge rounds: partner pairs exchange ~k
/// (index, value) pairs each round; rounds serialize, pairs within a
/// round run concurrently (round time = slowest pair).
fn sim_gtopk_rounds(profile: &TopologyProfile, n: usize, k: usize, start: f64) -> f64 {
    let bytes = k * 8;
    let mut t = start;
    let mut stride = 1usize;
    while stride < n {
        let mut round = 0.0f64;
        let mut i = 0usize;
        while i < n {
            let j = i + stride;
            if j < n {
                round = round.max(profile.link_between(j, i).time_for(bytes));
            }
            i += 2 * stride;
        }
        t += round;
        stride *= 2;
    }
    t
}

// ----------------------------------------------------------------------
// Per-bucket exchange shapes
// ----------------------------------------------------------------------

/// What one bucket's exchange looks like on the wire, derived from the
/// real step's merged selection.
enum ExchangeShape {
    /// Dense ring all-reduce of the whole bucket.
    Dense { elems: usize },
    /// Shared-index sparse all-reduce: index broadcast + ring reduce of
    /// `k` values. `elems` is the bucket's dense length (the sketch
    /// pre-pass sizes its table from it).
    SharedRing {
        k: usize,
        elems: usize,
        leader: usize,
    },
    /// Per-worker gather: `wire_bytes[w]` up, the union back down.
    Gather {
        wire_bytes: Vec<usize>,
        union_bytes: usize,
    },
}

/// Slice the step's merged selection down to one bucket's coordinate
/// range.
fn bucket_shape(selection: Option<&Selection>, bucket: &Bucket, leader: usize, n: usize) -> ExchangeShape {
    let (lo, hi) = (bucket.offset as u32, (bucket.offset + bucket.len) as u32);
    match selection {
        None => ExchangeShape::Dense { elems: bucket.len },
        Some(Selection::Shared(idx)) => ExchangeShape::SharedRing {
            k: idx.iter().filter(|&&i| i >= lo && i < hi).count(),
            elems: bucket.len,
            leader,
        },
        Some(Selection::PerWorker(per)) => {
            assert_eq!(per.len(), n);
            let mut union: Vec<u32> = Vec::new();
            let wire_bytes: Vec<usize> = per
                .iter()
                .map(|w| {
                    let in_range: Vec<u32> =
                        w.iter().copied().filter(|&i| i >= lo && i < hi).collect();
                    union.extend_from_slice(&in_range);
                    in_range.len() * 8
                })
                .collect();
            union.sort_unstable();
            union.dedup();
            ExchangeShape::Gather {
                wire_bytes,
                union_bytes: union.len() * 8,
            }
        }
    }
}

/// Simulate one bucket's exchange from barrier time `start`; returns its
/// end and appends the timed events. `scheme` only matters for the
/// scheme-specific pre-passes (gTop-k merge rounds, sketch all-reduce);
/// `at` is the `(step, bucket)` coordinate stamped on every event.
fn sim_exchange(
    profile: &TopologyProfile,
    n: usize,
    scheme: &str,
    shape: &ExchangeShape,
    at: (usize, u32),
    start: f64,
    trace: &mut Vec<TraceEvent>,
) -> f64 {
    let (step, bucket_id) = at;
    match shape {
        ExchangeShape::Dense { elems } => {
            let end = sim_ring_allreduce(profile, n, *elems, 4, start);
            trace.push(TraceEvent {
                step,
                bucket: bucket_id,
                op: "dense_ring",
                start_s: start,
                end_s: end,
                bytes: *elems * 4,
            });
            end
        }
        ExchangeShape::SharedRing { k, elems, leader } => {
            let mut t = start;
            if scheme.starts_with("gtop") {
                let end = sim_gtopk_rounds(profile, n, *k, t);
                trace.push(TraceEvent {
                    step,
                    bucket: bucket_id,
                    op: "gtopk_exchange",
                    start_s: t,
                    end_s: end,
                    bytes: *k * 8,
                });
                t = end;
            } else if scheme.starts_with("sketch") {
                // The sketch scheme needs the *summed* sketch before it
                // can rank: charge a ring all-reduce of the count-sketch
                // table — rows × max(width_frac·len, k, 4), exactly the
                // table `SketchK` builds for this span.
                let sk = crate::compress::sketch::SketchK::default_for(0);
                let width = ((*elems as f64 * sk.width_frac) as usize).max((*k).max(4));
                let table_elems = sk.rows * width;
                let end = sim_ring_allreduce(profile, n, table_elems, 4, t);
                trace.push(TraceEvent {
                    step,
                    bucket: bucket_id,
                    op: "sketch_allreduce",
                    start_s: t,
                    end_s: end,
                    bytes: table_elems * 4,
                });
                t = end;
            }
            let idx_bytes = *k * 4;
            if n > 1 && *k > 0 {
                let end = sim_index_bcast(profile, n, *leader, idx_bytes, t);
                trace.push(TraceEvent {
                    step,
                    bucket: bucket_id,
                    op: "index_bcast",
                    start_s: t,
                    end_s: end,
                    bytes: idx_bytes,
                });
                t = end;
            }
            let end = sim_ring_allreduce(profile, n, *k, 4, t);
            trace.push(TraceEvent {
                step,
                bucket: bucket_id,
                op: "ring_reduce",
                start_s: t,
                end_s: end,
                bytes: *k * 4,
            });
            end
        }
        ExchangeShape::Gather {
            wire_bytes,
            union_bytes,
        } => {
            let end = sim_star_gather(profile, wire_bytes, *union_bytes, start);
            trace.push(TraceEvent {
                step,
                bucket: bucket_id,
                op: "star_gather",
                start_s: start,
                end_s: end,
                bytes: wire_bytes.iter().sum::<usize>() + union_bytes,
            });
            end
        }
    }
}

// ----------------------------------------------------------------------
// The simulation driver
// ----------------------------------------------------------------------

/// Run `cfg.steps` real coordination steps under simulated time. See
/// the module docs for the model; determinism: same `(cfg, profile)` ⇒
/// byte-identical trace digest and selections.
pub fn simulate(cfg: &SimConfig, profile: &TopologyProfile) -> anyhow::Result<SimReport> {
    simulate_inner(cfg, profile, None)
}

/// `simulate` with one injected fail-stop fault (see [`ElasticSpec`]).
/// The selection digest is bit-identical to the fault-free run; the
/// recovery wave (detect, restart, re-rendezvous, resume agreement,
/// replay) shows up only in the trace and the timeline. `per_step_s`
/// still measures the replayed step alone, so `total_s` exceeds the sum
/// of steps by exactly the recovery overhead.
pub fn simulate_elastic(
    cfg: &SimConfig,
    profile: &TopologyProfile,
    elastic: &ElasticSpec,
) -> anyhow::Result<SimReport> {
    simulate_inner(cfg, profile, Some(elastic))
}

fn simulate_inner(
    cfg: &SimConfig,
    profile: &TopologyProfile,
    elastic: Option<&ElasticSpec>,
) -> anyhow::Result<SimReport> {
    anyhow::ensure!(cfg.workers >= 1, "simulate needs at least one worker");
    anyhow::ensure!(cfg.dim >= 1, "simulate needs a non-empty gradient");
    anyhow::ensure!(
        cfg.layers >= 1 && cfg.layers <= cfg.dim,
        "--layers must be in [1, dim]"
    );
    anyhow::ensure!(cfg.steps >= 1, "simulate needs at least one step");
    anyhow::ensure!(
        cfg.compute_per_elem_s >= 0.0,
        "compute_per_elem_s must be non-negative"
    );
    anyhow::ensure!(
        !(cfg.bucket_bytes > 0 && cfg.scheme == "none"),
        "--bucket-bytes only applies to compressed schemes (the dense \
         baseline's exchange is monolithic)"
    );
    if let Some(el) = elastic {
        anyhow::ensure!(
            cfg.workers >= 2,
            "elastic membership needs a survivor to detect the fault — \
             run at least two workers"
        );
        anyhow::ensure!(
            !cfg.overlapped,
            "elastic membership cannot be combined with the overlapped \
             driving mode — the recovery barrier drains the pipeline"
        );
        anyhow::ensure!(
            el.kill_step < cfg.steps,
            "--elastic-kill-step {} is past the end of a {}-step run",
            el.kill_step,
            cfg.steps
        );
        anyhow::ensure!(
            el.kill_worker < cfg.workers,
            "--elastic-kill-worker {} does not exist in a {}-worker fleet",
            el.kill_worker,
            cfg.workers
        );
        anyhow::ensure!(
            el.heartbeat_s > 0.0,
            "elastic membership needs a positive heartbeat interval \
             (silence is what detects the dead worker)"
        );
        anyhow::ensure!(el.restart_s >= 0.0, "restart time must be non-negative");
    }
    profile.check()?;
    // Loud tiling validation, shared with the executable path: an
    // untileable hierarchical profile is an error with a remedy, never
    // a silent flat fallback.
    profile.check_group_size(cfg.workers)?;

    let n = cfg.workers;
    let dim = cfg.dim;
    let partition = uniform_partition(dim, cfg.layers);
    let plan = if cfg.bucket_bytes > 0 && cfg.scheme != "none" {
        Some(BucketPlan::from_partition(&partition, cfg.bucket_bytes))
    } else {
        None
    };
    let multi_bucket = plan.as_ref().map_or(false, |p| !p.is_single());
    anyhow::ensure!(
        !(cfg.overlapped && multi_bucket),
        "--bucket-bytes cannot be combined with the overlapped driving \
         mode (see Coordinator::try_step_overlapped) — drop one of the two"
    );

    let mode = if cfg.scheme == "none" {
        Mode::Dense
    } else {
        Mode::Compressed(make_compressor(&cfg.scheme, cfg.rate, cfg.seed)?)
    };
    let k = ((dim as f64 / cfg.rate as f64).ceil() as usize).max(1);
    let fabric = Fabric::new(FabricConfig {
        workers: n,
        ..FabricConfig::default()
    });
    let mut coordinator = Coordinator::new(n, dim, mode, cfg.beta, k, fabric, cfg.warmup_steps);
    if cfg.scheme != "none" {
        let ks = partition.per_layer_k(cfg.rate as f64, 32, false);
        coordinator = coordinator.with_layered(partition.clone(), ks);
    }
    coordinator.set_bucket_plan(plan.clone());

    let mut trace: Vec<TraceEvent> = Vec::new();
    let mut selections: Vec<Option<Selection>> = Vec::with_capacity(cfg.steps);
    let mut per_step_s: Vec<f64> = Vec::with_capacity(cfg.steps);
    let mut compute_total = 0.0f64;
    let mut comm_total = 0.0f64;

    // Virtual cursors. Sync driving barriers both at every step's end;
    // the overlapped mode keeps a one-step-deep pipeline, exactly the
    // `step_overlapped` lookahead: compute of step t may start once step
    // t−2's exchange has landed (its result slot is free), and step t's
    // exchange waits for step t−1's (one link).
    let mut compute_cursor = 0.0f64;
    let mut link_free = 0.0f64;
    let mut prev_comm_done = 0.0f64;
    let mut prev_prev_comm_done = 0.0f64;
    let mut timeline_end = 0.0f64;

    for t in 0..cfg.steps {
        let grads = synthetic_grads(cfg.seed, t, n, dim);
        let result = coordinator.try_step_bucketed(t, &grads)?;

        // Synchronous SGD waits for the slowest worker's compute.
        let f_step = (0..n)
            .map(|w| profile.compute_factor(t, w))
            .fold(1.0f64, f64::max);

        // Bucket walk in the driver's backward submission order; a dense
        // step (warmup / scheme none) is one monolithic dense exchange
        // regardless of the plan, exactly like `try_step_bucketed`.
        let whole = Bucket {
            id: 0,
            offset: 0,
            len: dim,
            layers: (0, cfg.layers),
        };
        let buckets: Vec<Bucket> = if multi_bucket && !result.dense {
            let p = plan.as_ref().expect("multi_bucket implies a plan");
            crate::runtime::bucketed::backward_order(p)
                .into_iter()
                .map(|b| *p.bucket(b))
                .collect()
        } else {
            vec![whole]
        };

        // Elastic fault: the doomed attempt runs its selection compute,
        // then `kill_worker` dies before the first exchange message.
        // Charge the recovery wave, then fall through to the normal
        // bucket walk below — that IS the replay, so selections stay
        // bit-identical to the fault-free run by construction.
        if let Some(el) = elastic {
            if el.kill_step == t {
                let mut cursor = timeline_end;
                let tc_attempt = dim as f64 * cfg.compute_per_elem_s * f_step;
                trace.push(TraceEvent {
                    step: t,
                    bucket: 0,
                    op: "compute_aborted",
                    start_s: cursor,
                    end_s: cursor + tc_attempt,
                    bytes: dim * 4,
                });
                cursor += tc_attempt;
                compute_total += tc_attempt;
                // Heartbeat silence latches the dead peer within two
                // intervals (the transport's detection bound).
                let detect = 2.0 * el.heartbeat_s;
                trace.push(TraceEvent {
                    step: t,
                    bucket: 0,
                    op: "fault_detect",
                    start_s: cursor,
                    end_s: cursor + detect,
                    bytes: 0,
                });
                cursor += detect;
                trace.push(TraceEvent {
                    step: t,
                    bucket: 0,
                    op: "worker_restart",
                    start_s: cursor,
                    end_s: cursor + el.restart_s,
                    bytes: 0,
                });
                cursor += el.restart_s;
                // Re-rendezvous storm: every pair re-runs the Hello
                // handshake concurrently; the wave ends when the slowest
                // link has carried a dial and an ack.
                let hello_bytes = 64usize;
                let mut hop = 0.0f64;
                for w in 0..n {
                    hop = hop.max(profile.egress(w).time_for(hello_bytes));
                }
                if profile.hierarchical_for(n) {
                    hop = hop.max(profile.uplink.time_for(hello_bytes));
                }
                let rendezvous = 2.0 * hop;
                trace.push(TraceEvent {
                    step: t,
                    bucket: 0,
                    op: "rendezvous",
                    start_s: cursor,
                    end_s: cursor + rendezvous,
                    bytes: n * (n - 1) * hello_bytes,
                });
                cursor += rendezvous;
                // Resume agreement: pass-the-minimum around the ring,
                // n−1 rounds of one 17-byte Resume frame per hop, each
                // round gated by the slowest ring link.
                let resume_frame = 17usize;
                let mut ring_hop = 0.0f64;
                for w in 0..n {
                    ring_hop =
                        ring_hop.max(profile.link_between(w, (w + 1) % n).time_for(resume_frame));
                }
                let resume_t = (n - 1) as f64 * ring_hop;
                trace.push(TraceEvent {
                    step: t,
                    bucket: 0,
                    op: "resume_reduce",
                    start_s: cursor,
                    end_s: cursor + resume_t,
                    bytes: n * (n - 1) * resume_frame,
                });
                cursor += resume_t;
                comm_total += rendezvous + resume_t;
                timeline_end = cursor;
            }
        }

        let step_start = timeline_end;
        if cfg.overlapped {
            compute_cursor = compute_cursor.max(prev_prev_comm_done);
        } else {
            compute_cursor = step_start;
            link_free = step_start;
        }

        let mut step_compute = 0.0f64;
        let mut step_comm = 0.0f64;
        for bucket in &buckets {
            let tc = bucket.len as f64 * cfg.compute_per_elem_s * f_step;
            let c_start = compute_cursor;
            compute_cursor += tc;
            step_compute += tc;
            trace.push(TraceEvent {
                step: t,
                bucket: bucket.id as u32,
                op: "compute",
                start_s: c_start,
                end_s: compute_cursor,
                bytes: bucket.len * 4,
            });
            let shape = bucket_shape(result.selection.as_ref(), bucket, result.leader, n);
            let x_start = compute_cursor.max(link_free);
            let x_end = sim_exchange(
                profile,
                n,
                &cfg.scheme,
                &shape,
                (t, bucket.id as u32),
                x_start,
                &mut trace,
            );
            step_comm += x_end - x_start;
            link_free = x_end;
        }
        let step_end = compute_cursor.max(link_free);
        if cfg.overlapped {
            prev_prev_comm_done = prev_comm_done;
            prev_comm_done = link_free;
            // For the overlapped pipeline the per-step wall is the
            // advance of the timeline end (steady state ≈ max(Tc, Tm)).
            per_step_s.push(step_end - timeline_end);
        } else {
            per_step_s.push(step_end - step_start);
        }
        timeline_end = step_end;
        compute_total += step_compute;
        comm_total += step_comm;
        selections.push(result.selection);
    }

    Ok(SimReport {
        scheme: cfg.scheme.clone(),
        workers: n,
        steps: cfg.steps,
        dim,
        total_s: timeline_end,
        compute_s: compute_total,
        comm_s: comm_total,
        per_step_s,
        selections,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::parallel::ring_allreduce_generic;
    use crate::simnet::profile::{LinkProfile, StragglerProfile};
    use std::sync::mpsc::channel;

    fn quiet_profile(bw_gbps: f64, latency_us: f64) -> TopologyProfile {
        TopologyProfile {
            name: "test".into(),
            link: LinkProfile::new(bw_gbps, latency_us),
            group_size: 0,
            uplink: LinkProfile::new(bw_gbps, latency_us),
            slow_workers: Vec::new(),
            slow_factor: 1.0,
            straggler: StragglerProfile::none(),
            seed: 0,
        }
    }

    fn cfg(scheme: &str, n: usize) -> SimConfig {
        SimConfig {
            workers: n,
            dim: 512,
            scheme: scheme.into(),
            rate: 16,
            steps: 3,
            layers: 4,
            ..SimConfig::default()
        }
    }

    #[test]
    fn sim_schedule_matches_real_ring_messages() {
        // The lock between the simulator's charged messages and the real
        // collective: run `ring_allreduce_generic` over an in-test
        // channel mesh with instrumented send closures, and check every
        // per-round message size against the shared schedule helpers the
        // simulator charges from.
        for n in [2usize, 3, 5] {
            for len in [0usize, 7, 16] {
                let mut txs = Vec::new();
                let mut rxs = Vec::new();
                for _ in 0..n {
                    let (tx, rx) = channel::<Vec<f32>>();
                    txs.push(tx);
                    rxs.push(Some(rx));
                }
                let links: Vec<_> = (0..n)
                    .map(|id| (txs[id].clone(), rxs[(id + n - 1) % n].take().unwrap()))
                    .collect();
                let sent: Vec<Vec<usize>> = std::thread::scope(|s| {
                    let handles: Vec<_> = links
                        .into_iter()
                        .enumerate()
                        .map(|(id, (tx, rx))| {
                            s.spawn(move || {
                                let mut buf = vec![id as f32; len];
                                let mut sizes = Vec::new();
                                let mut send = |c: &[f32]| {
                                    sizes.push(c.len());
                                    tx.send(c.to_vec())
                                        .map_err(|_| anyhow::anyhow!("send"))
                                };
                                let mut recv = || {
                                    rx.recv().map_err(|_| anyhow::anyhow!("recv"))
                                };
                                ring_allreduce_generic(
                                    id, n, &mut buf, &|_| {}, &mut send, &mut recv,
                                )
                                .unwrap();
                                sizes
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                for (id, sizes) in sent.iter().enumerate() {
                    let expect = flat_ring_send_sizes(id, n, len);
                    assert_eq!(sizes, &expect, "n={n} len={len} worker {id}");
                }
            }
        }
    }

    /// Worker `id`'s per-round send sizes in the flat ring schedule —
    /// derived from the same `chunk_bounds`/round helpers the simulator
    /// charges from, with zero-width chunks skipped exactly like the
    /// executable collective (and `sim_ring_rounds`) skip them.
    fn flat_ring_send_sizes(id: usize, n: usize, len: usize) -> Vec<usize> {
        let bounds = chunk_bounds(len, n);
        let mut expect = Vec::new();
        for s in 0..n - 1 {
            let (send_c, _) = reduce_scatter_round(id, n, s);
            let w = bounds[send_c].1 - bounds[send_c].0;
            if w > 0 {
                expect.push(w);
            }
        }
        for s in 0..n - 1 {
            let (send_c, _) = all_gather_round(id, n, s);
            let w = bounds[send_c].1 - bounds[send_c].0;
            if w > 0 {
                expect.push(w);
            }
        }
        expect
    }

    #[test]
    fn sim_schedule_matches_real_hier_ring_messages() {
        // The two-level analogue of the flat lock above: drive the
        // three-phase hierarchical composition (the exact dataflow of
        // `HierRingNode` / `SocketHierRingNode`) over instrumented
        // channels and check every message against the schedule the
        // simulator's `hier` branch charges — intra rounds from
        // `chunk_bounds(len, g)`, uplink rounds from
        // `chunk_bounds(len, ngroups)`, then the group-chain broadcast,
        // with zero-width sends skipped symmetrically in both worlds.
        for (n, g) in [(4usize, 2usize), (8, 4), (6, 2)] {
            for len in [0usize, 1, 5, 16] {
                let ngroups = n / g;
                // intra fabric: one channel ring per group (link j
                // carries member j → (j+1) % g), plus the uplink ring
                // over the group leaders.
                let mut intra_txs = Vec::new();
                let mut intra_rxs: Vec<Option<_>> = Vec::new();
                for _ in 0..n {
                    let (tx, rx) = channel::<Vec<f32>>();
                    intra_txs.push(tx);
                    intra_rxs.push(Some(rx));
                }
                let mut up_txs = Vec::new();
                let mut up_rxs: Vec<Option<_>> = Vec::new();
                for _ in 0..ngroups {
                    let (tx, rx) = channel::<Vec<f32>>();
                    up_txs.push(tx);
                    up_rxs.push(Some(rx));
                }
                let links: Vec<_> = (0..n)
                    .map(|w| {
                        let (grp, member) = (w / g, w % g);
                        let intra_tx = intra_txs[w].clone();
                        let intra_rx = intra_rxs[grp * g + (member + g - 1) % g]
                            .take()
                            .unwrap();
                        let up = (member == 0).then(|| {
                            (
                                up_txs[grp].clone(),
                                up_rxs[(grp + ngroups - 1) % ngroups].take().unwrap(),
                            )
                        });
                        (intra_tx, intra_rx, up)
                    })
                    .collect();
                let sent: Vec<(Vec<usize>, Vec<f32>)> = std::thread::scope(|s| {
                    let handles: Vec<_> = links
                        .into_iter()
                        .enumerate()
                        .map(|(w, (intra_tx, intra_rx, up))| {
                            s.spawn(move || {
                                let (_, member) = (w / g, w % g);
                                let mut buf = vec![(w + 1) as f32; len];
                                let mut sizes = Vec::new();
                                // phase 1: intra-group sum
                                {
                                    let mut send = |c: &[f32]| {
                                        sizes.push(c.len());
                                        intra_tx
                                            .send(c.to_vec())
                                            .map_err(|_| anyhow::anyhow!("send"))
                                    };
                                    let mut recv = || {
                                        intra_rx.recv().map_err(|_| anyhow::anyhow!("recv"))
                                    };
                                    ring_allreduce_generic(
                                        member, g, &mut buf, &|_| {}, &mut send, &mut recv,
                                    )
                                    .unwrap();
                                }
                                // phase 2: leader ring with the global finish
                                if let Some((up_tx, up_rx)) = &up {
                                    let inv = 1.0 / n as f32;
                                    let mut send = |c: &[f32]| {
                                        sizes.push(c.len());
                                        up_tx
                                            .send(c.to_vec())
                                            .map_err(|_| anyhow::anyhow!("send"))
                                    };
                                    let mut recv = || {
                                        up_rx.recv().map_err(|_| anyhow::anyhow!("recv"))
                                    };
                                    let grp_id = w / g;
                                    ring_allreduce_generic(
                                        grp_id,
                                        ngroups,
                                        &mut buf,
                                        &|c: &mut [f32]| {
                                            c.iter_mut().for_each(|v| *v *= inv)
                                        },
                                        &mut send,
                                        &mut recv,
                                    )
                                    .unwrap();
                                }
                                // phase 3: chain broadcast down the group
                                if !buf.is_empty() {
                                    if up.is_some() {
                                        sizes.push(buf.len());
                                        intra_tx.send(buf.clone()).unwrap();
                                    } else {
                                        let incoming = intra_rx.recv().unwrap();
                                        buf.copy_from_slice(&incoming);
                                        if member + 1 < g {
                                            sizes.push(incoming.len());
                                            intra_tx.send(incoming).unwrap();
                                        }
                                    }
                                }
                                (sizes, buf)
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                // expected per-worker message list: exactly what the
                // simulator's hier branch charges, in order
                let expect_avg: f32 =
                    (1..=n).map(|v| v as f32).sum::<f32>() / n as f32;
                for (w, (sizes, buf)) in sent.iter().enumerate() {
                    let (grp, member) = (w / g, w % g);
                    let mut expect = flat_ring_send_sizes(member, g, len);
                    if member == 0 {
                        expect.extend(flat_ring_send_sizes(grp, ngroups, len));
                    }
                    if len > 0 && member + 1 < g {
                        // leader opens the chain; every member but the
                        // last forwards the full payload
                        expect.push(len);
                    }
                    assert_eq!(
                        sizes, &expect,
                        "n={n} g={g} len={len} worker {w} message sizes"
                    );
                    assert!(
                        buf.iter().all(|&v| (v - expect_avg).abs() < 1e-4),
                        "n={n} g={g} len={len} worker {w}: {buf:?}"
                    );
                }
                // and the simulator charges nothing at all for an empty
                // buffer — no messages moved, no latency billed
                if len == 0 {
                    let mut p = quiet_profile(1.0, 3.0);
                    p.group_size = g;
                    let end = sim_ring_allreduce(&p, n, 0, 4, 5.0);
                    assert_eq!(end, 5.0, "n={n} g={g}: empty buffers are free");
                }
            }
        }
    }

    #[test]
    fn uniform_ring_time_is_rounds_times_chunks() {
        // n divides elems, zero latency: every round moves elems/n values
        // and the ring takes exactly 2(n-1) rounds.
        let p = quiet_profile(1.0, 0.0); // 1e9 B/s
        let n = 4;
        let elems = 400;
        let end = sim_ring_allreduce(&p, n, elems, 4, 1.0);
        let chunk_t = (elems / n * 4) as f64 / 1e9;
        let expect = 1.0 + 2.0 * (n - 1) as f64 * chunk_t;
        assert!((end - expect).abs() < 1e-12, "{end} vs {expect}");
        // single worker: free
        assert_eq!(sim_ring_allreduce(&p, 1, elems, 4, 2.0), 2.0);
    }

    #[test]
    fn slow_link_drags_the_whole_ring() {
        let mut p = quiet_profile(32.0, 1.0);
        let base = sim_ring_allreduce(&p, 8, 8000, 4, 0.0);
        p.slow_workers = vec![3];
        p.slow_factor = 4.0;
        let slowed = sim_ring_allreduce(&p, 8, 8000, 4, 0.0);
        assert!(slowed > base, "{slowed} vs {base}");
    }

    #[test]
    fn hierarchical_uplink_costs_more_than_flat() {
        let flat = quiet_profile(32.0, 1.0);
        let mut hier = quiet_profile(32.0, 1.0);
        hier.group_size = 4;
        hier.uplink = LinkProfile::new(4.0, 5.0);
        assert!(hier.hierarchical_for(16));
        let t_flat = sim_ring_allreduce(&flat, 16, 16_000, 4, 0.0);
        let t_hier = sim_ring_allreduce(&hier, 16, 16_000, 4, 0.0);
        assert!(t_hier > t_flat, "{t_hier} vs {t_flat}");
    }

    #[test]
    fn star_gather_union_growth_shows_in_time() {
        let p = quiet_profile(32.0, 1.0);
        // same per-worker upload, union grows 4x → download time grows
        let small = sim_star_gather(&p, &[800; 8], 800, 0.0);
        let big = sim_star_gather(&p, &[800; 8], 3200, 0.0);
        assert!(big > small);
        assert_eq!(sim_star_gather(&p, &[800], 800, 3.0), 3.0, "n=1 is local");
    }

    #[test]
    fn simulate_runs_all_five_schemes_and_is_deterministic() {
        let p = TopologyProfile::named("straggler").unwrap();
        for scheme in SIM_SCHEMES {
            let c = cfg(scheme, 4);
            let a = simulate(&c, &p).unwrap();
            let b = simulate(&c, &p).unwrap();
            assert_eq!(a.trace_digest(), b.trace_digest(), "{scheme}");
            assert_eq!(a.selection_digest(), b.selection_digest(), "{scheme}");
            assert_eq!(a.per_step_s.len(), c.steps);
            assert!(a.total_s > 0.0);
            assert!(a.mean_step_s() > 0.0);
            // different seed → different selections (energy moves)
            let mut c2 = cfg(scheme, 4);
            c2.seed = 777;
            let d = simulate(&c2, &p).unwrap();
            assert_ne!(
                a.selection_digest(),
                d.selection_digest(),
                "{scheme}: seed must steer the stream"
            );
        }
    }

    #[test]
    fn simulate_selections_match_raw_sequential_coordinator() {
        // The engine drives the real sequential coordinator; an
        // independently-built coordinator over the same synthetic stream
        // must produce the identical selections.
        let c = cfg("scalecom", 3);
        let p = TopologyProfile::uniform();
        let report = simulate(&c, &p).unwrap();
        let partition = uniform_partition(c.dim, c.layers);
        let ks = partition.per_layer_k(c.rate as f64, 32, false);
        let fabric = Fabric::new(FabricConfig {
            workers: c.workers,
            ..FabricConfig::default()
        });
        let mut reference = Coordinator::new(
            c.workers,
            c.dim,
            Mode::Compressed(make_compressor(&c.scheme, c.rate, c.seed).unwrap()),
            c.beta,
            ((c.dim as f64 / c.rate as f64).ceil() as usize).max(1),
            fabric,
            c.warmup_steps,
        )
        .with_layered(partition, ks);
        for t in 0..c.steps {
            let grads = synthetic_grads(c.seed, t, c.workers, c.dim);
            let r = reference.step(t, &grads);
            assert_eq!(r.selection, report.selections[t], "t={t}");
        }
    }

    #[test]
    fn bucketed_run_keeps_selections_and_overlaps_time() {
        let p = quiet_profile(0.5, 0.0); // slow links → comm-bound
        let mut mono = cfg("scalecom", 4);
        mono.dim = 4096;
        mono.layers = 8;
        mono.compute_per_elem_s = 1e-7; // make compute visible
        let mut bucketed = mono.clone();
        bucketed.bucket_bytes = (mono.dim / mono.layers) * 4;
        let a = simulate(&mono, &p).unwrap();
        let b = simulate(&bucketed, &p).unwrap();
        assert_eq!(
            a.selection_digest(),
            b.selection_digest(),
            "bucketing must not change selections"
        );
        assert!(
            b.total_s < a.total_s,
            "bucketed overlap must beat monolithic when both sides are \
             non-trivial: {} vs {}",
            b.total_s,
            a.total_s
        );
    }

    #[test]
    fn overlapped_mode_beats_sync_and_rejects_buckets() {
        let p = quiet_profile(0.5, 0.0);
        let mut sync = cfg("scalecom", 4);
        sync.dim = 4096;
        sync.steps = 8;
        sync.compute_per_elem_s = 1e-7;
        let mut over = sync.clone();
        over.overlapped = true;
        let a = simulate(&sync, &p).unwrap();
        let b = simulate(&over, &p).unwrap();
        assert!(b.total_s < a.total_s, "{} vs {}", b.total_s, a.total_s);
        let mut bad = over.clone();
        bad.bucket_bytes = (bad.dim / bad.layers) * 4;
        let err = simulate(&bad, &p).unwrap_err();
        assert!(err.to_string().contains("bucket-bytes"), "{err}");
    }

    #[test]
    fn straggler_profile_slows_steps_down() {
        let quiet = TopologyProfile::uniform();
        let mut noisy = TopologyProfile::named("straggler").unwrap();
        noisy.straggler.prob = 1.0; // every worker straggles every step
        noisy.straggler.slowdown = 5.0;
        let mut c = cfg("scalecom", 4);
        c.compute_per_elem_s = 1e-6;
        let a = simulate(&c, &quiet).unwrap();
        let b = simulate(&c, &noisy).unwrap();
        assert!(b.total_s > 2.0 * a.total_s, "{} vs {}", b.total_s, a.total_s);
        // stragglers change timing, never values
        assert_eq!(a.selection_digest(), b.selection_digest());
    }

    #[test]
    fn dense_baseline_and_warmup_go_dense() {
        let p = TopologyProfile::uniform();
        let mut c = cfg("none", 2);
        c.bucket_bytes = 0;
        let r = simulate(&c, &p).unwrap();
        assert!(r.selections.iter().all(|s| s.is_none()));
        assert!(r.trace.iter().any(|e| e.op == "dense_ring"));
        let mut w = cfg("scalecom", 2);
        w.warmup_steps = 2;
        let r = simulate(&w, &p).unwrap();
        assert!(r.selections[0].is_none() && r.selections[1].is_none());
        assert!(r.selections[2].is_some());
    }

    #[test]
    fn local_topk_gather_grows_with_workers_but_scalecom_does_not() {
        // The paper's core scaling story, reproduced in virtual time:
        // local top-k's per-step comm grows with n (build-up), CLT-k's
        // stays ~flat. Zero-latency profile so the comparison is about
        // volume (the paper's axis), not per-hop latency.
        let p = quiet_profile(32.0, 0.0);
        let step_comm = |scheme: &str, n: usize| {
            let mut c = cfg(scheme, n);
            c.dim = 2048;
            c.rate = 32;
            c.steps = 2;
            let r = simulate(&c, &p).unwrap();
            r.comm_s / r.steps as f64
        };
        let topk_8 = step_comm("local-topk", 8);
        let topk_32 = step_comm("local-topk", 32);
        let clt_8 = step_comm("scalecom", 8);
        let clt_32 = step_comm("scalecom", 32);
        assert!(topk_32 > topk_8 * 2.0, "{topk_8} → {topk_32}");
        assert!(clt_32 < clt_8 * 2.0, "{clt_8} → {clt_32}");
    }

    #[test]
    fn trace_digest_is_sensitive_to_profile() {
        let c = cfg("scalecom", 4);
        let a = simulate(&c, &TopologyProfile::uniform()).unwrap();
        let b = simulate(&c, &TopologyProfile::named("hetero").unwrap()).unwrap();
        assert_ne!(a.trace_digest(), b.trace_digest());
        assert_eq!(a.selection_digest(), b.selection_digest());
    }

    #[test]
    fn elastic_fault_charges_recovery_but_keeps_selections() {
        let p = quiet_profile(10.0, 5.0);
        let c = cfg("scalecom", 4);
        let base = simulate(&c, &p).unwrap();
        let el = ElasticSpec {
            kill_step: 1,
            kill_worker: 2,
            heartbeat_s: 0.05,
            restart_s: 0.5,
        };
        let faulted = simulate_elastic(&c, &p, &el).unwrap();
        // The determinism contract: the kill+rejoin run's selections are
        // bit-identical to the fault-free run's.
        assert_eq!(faulted.selection_digest(), base.selection_digest());
        assert_eq!(faulted.steps, base.steps);
        // The recovery wave is charged on the wall: detection alone is
        // 2× the heartbeat, plus the restart.
        assert!(
            faulted.total_s >= base.total_s + 2.0 * el.heartbeat_s + el.restart_s,
            "{} vs {}",
            faulted.total_s,
            base.total_s
        );
        assert_ne!(faulted.trace_digest(), base.trace_digest());
        // Every recovery op appears exactly once, in order, at the kill
        // step.
        let ops = ["compute_aborted", "fault_detect", "worker_restart", "rendezvous", "resume_reduce"];
        for op in ops {
            let hits: Vec<&TraceEvent> =
                faulted.trace.iter().filter(|e| e.op == op).collect();
            assert_eq!(hits.len(), 1, "{op}");
            assert_eq!(hits[0].step, el.kill_step, "{op}");
        }
        // Same spec ⇒ byte-identical timeline.
        let again = simulate_elastic(&c, &p, &el).unwrap();
        assert_eq!(again.trace_digest(), faulted.trace_digest());
        // per_step_s measures the replayed step alone; the overhead only
        // widens total_s.
        let steps_sum: f64 = faulted.per_step_s.iter().sum();
        assert!(faulted.total_s > steps_sum, "{} vs {steps_sum}", faulted.total_s);
    }

    #[test]
    fn elastic_mode_rejects_bad_specs() {
        let p = TopologyProfile::uniform();
        let c = cfg("scalecom", 4);
        let el = ElasticSpec::default();
        let past = ElasticSpec { kill_step: c.steps, ..el.clone() };
        assert!(simulate_elastic(&c, &p, &past).unwrap_err().to_string().contains("kill-step"));
        let ghost = ElasticSpec { kill_worker: c.workers, ..el.clone() };
        assert!(simulate_elastic(&c, &p, &ghost).unwrap_err().to_string().contains("kill-worker"));
        let deaf = ElasticSpec { heartbeat_s: 0.0, ..el.clone() };
        assert!(simulate_elastic(&c, &p, &deaf).unwrap_err().to_string().contains("heartbeat"));
        let solo = cfg("scalecom", 1);
        assert!(simulate_elastic(&solo, &p, &el).unwrap_err().to_string().contains("survivor"));
        let mut over = c.clone();
        over.overlapped = true;
        assert!(simulate_elastic(&over, &p, &el).unwrap_err().to_string().contains("overlapped"));
    }

    #[test]
    fn uniform_partition_tiles_with_remainder() {
        let p = uniform_partition(10, 3);
        let lens: Vec<usize> = p.layers.iter().map(|l| l.len).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        assert_eq!(p.total_len(), 10);
        p.check().unwrap();
    }
}
