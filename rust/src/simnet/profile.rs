//! Topology profiles: the link/compute shape of the simulated cluster.
//!
//! A [`TopologyProfile`] describes everything the virtual-clock engine
//! charges for: per-link bandwidth/latency, hierarchical grouping
//! (ring-of-rings — intra-group links plus a slower inter-group uplink),
//! per-worker slow links, and a seeded straggler/jitter model for the
//! compute side. Profiles come from three places, all producing the same
//! struct: the built-in named profiles ([`TopologyProfile::named`]), a
//! TOML file ([`TopologyProfile::load`] — see `examples/profiles/`), or
//! code (the tests). Everything is plain data: two simulations with the
//! same profile and seed produce byte-identical traces.

use crate::config::toml::TomlDoc;
use crate::util::rng::Rng;

/// One directed link: the simulator charges
/// `latency + bytes / bandwidth` per message crossing it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkProfile {
    pub bandwidth_gbps: f64,
    pub latency_us: f64,
}

impl LinkProfile {
    pub fn new(bandwidth_gbps: f64, latency_us: f64) -> LinkProfile {
        LinkProfile {
            bandwidth_gbps,
            latency_us,
        }
    }

    /// Wall time for one `bytes`-sized message on this link.
    pub fn time_for(&self, bytes: usize) -> f64 {
        self.latency_us * 1e-6 + bytes as f64 / (self.bandwidth_gbps * 1e9)
    }

    /// The same link slowed down by `factor` (bandwidth divided,
    /// latency multiplied).
    pub fn slowed(&self, factor: f64) -> LinkProfile {
        LinkProfile {
            bandwidth_gbps: self.bandwidth_gbps / factor,
            latency_us: self.latency_us * factor,
        }
    }
}

/// Seeded per-step, per-worker compute perturbations. `jitter` is a
/// uniform fractional slowdown applied every step; with probability
/// `prob` a worker additionally straggles by `slowdown`x that step.
/// All draws are pure functions of `(profile seed, step, worker)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerProfile {
    pub prob: f64,
    pub slowdown: f64,
    pub jitter: f64,
}

impl StragglerProfile {
    pub fn none() -> StragglerProfile {
        StragglerProfile {
            prob: 0.0,
            slowdown: 1.0,
            jitter: 0.0,
        }
    }
}

/// The simulated cluster's shape. Flat profiles (`group_size == 0`) are
/// one ring over every worker; hierarchical profiles partition workers
/// into consecutive groups of `group_size` and run a ring-of-rings —
/// intra-group reduction on the member links, an inter-group ring over
/// the group leaders on `uplink`, then an intra-group broadcast back.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyProfile {
    pub name: String,
    /// Default egress link of every worker.
    pub link: LinkProfile,
    /// `0` = flat ring; otherwise the ring-of-rings group size.
    pub group_size: usize,
    /// Inter-group link (only charged when `group_size > 0`).
    pub uplink: LinkProfile,
    /// Workers whose egress link is `slow_factor`x slower.
    pub slow_workers: Vec<usize>,
    pub slow_factor: f64,
    pub straggler: StragglerProfile,
    /// Seed for the straggler/jitter draws (independent of the workload
    /// seed, so the same gradient stream can be replayed under
    /// different network weather).
    pub seed: u64,
}

impl TopologyProfile {
    /// The reference profile: uniform 32 GBps / 1 us links, flat ring,
    /// no stragglers — the paper's clean-testbed assumption.
    pub fn uniform() -> TopologyProfile {
        TopologyProfile {
            name: "uniform".into(),
            link: LinkProfile::new(32.0, 1.0),
            group_size: 0,
            uplink: LinkProfile::new(32.0, 1.0),
            slow_workers: Vec::new(),
            slow_factor: 1.0,
            straggler: StragglerProfile::none(),
            seed: 0,
        }
    }

    /// Built-in named profiles (`scalecom simulate --profile <name>`).
    pub fn named(name: &str) -> anyhow::Result<TopologyProfile> {
        let mut p = TopologyProfile::uniform();
        match name {
            "uniform" => {}
            // one in eight workers sits behind a 4x slower link
            "hetero" => {
                p.name = "hetero".into();
                p.slow_workers = vec![3];
                p.slow_factor = 4.0;
            }
            // ring-of-rings: groups of 8 on fast links, 8 GBps uplink
            "hier" => {
                p.name = "hier".into();
                p.group_size = 8;
                p.uplink = LinkProfile::new(8.0, 5.0);
            }
            // 5% straggle 3x, everyone jitters up to 10%
            "straggler" => {
                p.name = "straggler".into();
                p.straggler = StragglerProfile {
                    prob: 0.05,
                    slowdown: 3.0,
                    jitter: 0.1,
                };
                p.seed = 7;
            }
            other => anyhow::bail!(
                "unknown topology profile '{other}' (expected \
                 uniform|hetero|hier|straggler, or a path to a profile .toml)"
            ),
        }
        Ok(p)
    }

    /// Parse a profile from a `[profile]` TOML section. Unset keys fall
    /// back to the uniform profile's values.
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<TopologyProfile> {
        let d = TopologyProfile::uniform();
        let slow_workers = match doc.get("profile.slow_workers") {
            None => Vec::new(),
            Some(v) => {
                let arr = v.as_arr().ok_or_else(|| {
                    anyhow::anyhow!("profile.slow_workers must be an array of worker ids")
                })?;
                let mut ids = Vec::with_capacity(arr.len());
                for item in arr {
                    ids.push(item.as_usize().ok_or_else(|| {
                        anyhow::anyhow!(
                            "profile.slow_workers entries must be non-negative integers"
                        )
                    })?);
                }
                ids
            }
        };
        let p = TopologyProfile {
            name: doc.str_or("profile.name", "custom").to_string(),
            link: LinkProfile::new(
                doc.f64_or("profile.bandwidth_gbps", d.link.bandwidth_gbps),
                doc.f64_or("profile.latency_us", d.link.latency_us),
            ),
            group_size: doc.usize_or("profile.group_size", 0),
            uplink: LinkProfile::new(
                doc.f64_or("profile.uplink_bandwidth_gbps", d.uplink.bandwidth_gbps),
                doc.f64_or("profile.uplink_latency_us", d.uplink.latency_us),
            ),
            slow_workers,
            slow_factor: doc.f64_or("profile.slow_factor", 1.0),
            straggler: StragglerProfile {
                prob: doc.f64_or("profile.straggler_prob", 0.0),
                slowdown: doc.f64_or("profile.straggler_slowdown", 1.0),
                jitter: doc.f64_or("profile.jitter", 0.0),
            },
            seed: doc.usize_or("profile.seed", 0) as u64,
        };
        p.check()?;
        Ok(p)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<TopologyProfile> {
        Self::from_toml(&TomlDoc::load(path)?)
    }

    /// CLI entry point: a built-in name, or a path to a profile TOML
    /// (anything containing a path separator or ending in `.toml`).
    pub fn resolve(arg: &str) -> anyhow::Result<TopologyProfile> {
        if arg.contains('/') || arg.contains('\\') || arg.ends_with(".toml") {
            Self::load(std::path::Path::new(arg))
        } else {
            Self::named(arg)
        }
    }

    pub fn check(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.link.bandwidth_gbps > 0.0 && self.uplink.bandwidth_gbps > 0.0,
            "profile bandwidths must be positive"
        );
        anyhow::ensure!(
            self.link.latency_us >= 0.0 && self.uplink.latency_us >= 0.0,
            "profile latencies must be non-negative"
        );
        anyhow::ensure!(self.slow_factor >= 1.0, "slow_factor must be >= 1");
        anyhow::ensure!(
            self.straggler.slowdown >= 1.0,
            "straggler_slowdown must be >= 1"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.straggler.prob),
            "straggler_prob must be in [0, 1]"
        );
        anyhow::ensure!(self.straggler.jitter >= 0.0, "jitter must be >= 0");
        Ok(())
    }

    /// Worker `w`'s egress link (its slow-link override applied).
    pub fn egress(&self, w: usize) -> LinkProfile {
        if self.slow_workers.contains(&w) {
            self.link.slowed(self.slow_factor)
        } else {
            self.link
        }
    }

    /// The link a message `from → to` crosses: the sender's egress, or
    /// the uplink when a hierarchical profile places them in different
    /// groups.
    pub fn link_between(&self, from: usize, to: usize) -> LinkProfile {
        if self.group_size > 0 && from / self.group_size != to / self.group_size {
            self.uplink
        } else {
            self.egress(from)
        }
    }

    /// Whether the ring-of-rings schedule applies for `n` workers: the
    /// group size must tile the ring with at least two full groups.
    /// This is the *schedule predicate* — callers must have validated
    /// the pairing with [`TopologyProfile::check_group_size`] first, so
    /// an impossible tiling is a loud error upstream, never a silent
    /// flat fallback here.
    pub fn hierarchical_for(&self, n: usize) -> bool {
        self.group_size > 1 && n % self.group_size == 0 && n / self.group_size >= 2
    }

    /// Validate this profile's group size against a concrete worker
    /// count, through the same `comm::parallel::validate_group_size`
    /// the executable backends use — simulation and execution accept
    /// exactly the same tilings and reject the rest with the same
    /// remedy, instead of the simulator silently downgrading an
    /// untileable hierarchy to the flat ring.
    pub fn check_group_size(&self, n: usize) -> anyhow::Result<()> {
        crate::comm::parallel::validate_group_size(n, self.group_size)
            .map_err(|e| anyhow::anyhow!("profile '{}': {e}", self.name))
    }

    /// Deterministic compute slowdown factor (>= 1) for `(step, worker)`.
    pub fn compute_factor(&self, step: usize, worker: usize) -> f64 {
        if self.straggler.prob == 0.0 && self.straggler.jitter == 0.0 {
            return 1.0;
        }
        let mut rng = Rng::for_stream(
            self.seed ^ 0x5349_4d4e_4554, // "SIMNET"
            ((step as u64) << 24) | worker as u64,
        );
        let mut f = 1.0 + self.straggler.jitter * rng.next_f64();
        if rng.next_f64() < self.straggler.prob {
            f *= self.straggler.slowdown;
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_is_latency_plus_bandwidth() {
        let l = LinkProfile::new(1.0, 100.0); // 1e9 B/s, 100 us
        let t = l.time_for(1_000_000_000);
        assert!((t - 1.0001).abs() < 1e-9, "{t}");
        let s = l.slowed(2.0);
        assert!((s.time_for(1_000_000_000) - 2.0002).abs() < 1e-9);
    }

    #[test]
    fn named_profiles_resolve_and_check() {
        for name in ["uniform", "hetero", "hier", "straggler"] {
            let p = TopologyProfile::named(name).unwrap();
            p.check().unwrap();
            assert_eq!(p.name, name);
        }
        assert!(TopologyProfile::named("mesh").is_err());
    }

    #[test]
    fn toml_roundtrip_with_overrides() {
        let doc = TomlDoc::parse(
            "[profile]\n\
             name = \"lab\"\n\
             bandwidth_gbps = 16.0\n\
             latency_us = 2.5\n\
             group_size = 4\n\
             uplink_bandwidth_gbps = 4.0\n\
             uplink_latency_us = 10.0\n\
             slow_workers = [1, 5]\n\
             slow_factor = 3.0\n\
             straggler_prob = 0.1\n\
             straggler_slowdown = 2.0\n\
             jitter = 0.05\n\
             seed = 11\n",
        )
        .unwrap();
        let p = TopologyProfile::from_toml(&doc).unwrap();
        assert_eq!(p.name, "lab");
        assert_eq!(p.link, LinkProfile::new(16.0, 2.5));
        assert_eq!(p.group_size, 4);
        assert_eq!(p.uplink, LinkProfile::new(4.0, 10.0));
        assert_eq!(p.slow_workers, vec![1, 5]);
        // slow worker: 3x slower egress
        assert!(p.egress(1).bandwidth_gbps < p.egress(0).bandwidth_gbps);
        // cross-group hop rides the uplink
        assert_eq!(p.link_between(3, 4), p.uplink);
        assert_eq!(p.link_between(0, 1), p.egress(0));
        assert_eq!(p.seed, 11);
    }

    #[test]
    fn bad_profiles_rejected() {
        let doc = TomlDoc::parse("[profile]\nbandwidth_gbps = 0.0\n").unwrap();
        assert!(TopologyProfile::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[profile]\nstraggler_prob = 2.0\n").unwrap();
        assert!(TopologyProfile::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[profile]\nslow_factor = 0.5\n").unwrap();
        assert!(TopologyProfile::from_toml(&doc).is_err());
    }

    #[test]
    fn compute_factor_is_deterministic_and_unit_without_noise() {
        let p = TopologyProfile::uniform();
        assert_eq!(p.compute_factor(3, 1), 1.0);
        let s = TopologyProfile::named("straggler").unwrap();
        let a = s.compute_factor(5, 2);
        let b = s.compute_factor(5, 2);
        assert_eq!(a, b, "same (seed, step, worker) => same factor");
        assert!(a >= 1.0);
        // over many draws at 5% prob / 3x, some step-worker pair straggles
        let mut any = false;
        for t in 0..40 {
            for w in 0..8 {
                if s.compute_factor(t, w) >= s.straggler.slowdown {
                    any = true;
                }
            }
        }
        assert!(any, "straggler profile never straggled in 320 draws");
    }

    #[test]
    fn hierarchical_applicability() {
        let h = TopologyProfile::named("hier").unwrap();
        assert!(h.hierarchical_for(64));
        assert!(h.hierarchical_for(16));
        assert!(!TopologyProfile::uniform().hierarchical_for(64));
    }

    #[test]
    fn untileable_group_sizes_are_rejected_loudly_not_downgraded() {
        // The shared validator (comm::parallel::validate_group_size)
        // rejects what the executable path rejects — the simulator must
        // never silently fall back to the flat ring.
        let h = TopologyProfile::named("hier").unwrap(); // groups of 8
        h.check_group_size(64).unwrap();
        h.check_group_size(16).unwrap();
        let single = h.check_group_size(8).unwrap_err();
        assert!(
            format!("{single:#}").contains("at least 2 groups"),
            "{single:#}"
        );
        let uneven = h.check_group_size(12).unwrap_err();
        let msg = format!("{uneven:#}");
        assert!(msg.contains("does not divide"), "{msg}");
        assert!(msg.contains("flat ring"), "remedy named: {msg}");
        // flat profiles pair with any worker count
        TopologyProfile::uniform().check_group_size(7).unwrap();
    }
}
