//! Analytic end-to-end performance model (§5 / Appendix F).
//!
//! The paper estimates system performance with the bandwidth-centric
//! framework of Venkataramani et al. [35]: given a system configuration
//! (per-worker peak TFLOPs, accelerator↔parameter-server bandwidth,
//! worker count, minibatch/worker) and a network's per-layer FLOPs/param
//! table, step time decomposes into
//!
//!   t_step = t_compute + t_comm,
//!   t_compute = train_FLOPs(minibatch) / (peak · efficiency),
//!   t_comm    = gradient/weight exchange time per scheme.
//!
//! Schemes (Appendix F.1): `none` (dense reduce on the server — constant
//! per-worker traffic), `local top-k` (compressed upload, but the reduced
//! union grows with n → download ≈ n·k — the gradient build-up), and
//! `ScaleCom` (constant k both ways + the O(1) index broadcast).
//! Compute/communication overlap: the framework's software pipelining is
//! modeled with an overlap factor (fraction of comm hidden under compute).

use crate::models::paper::PaperNet;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    None,
    LocalTopK,
    ScaleCom,
}

impl Scheme {
    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        match s {
            "none" | "baseline" => Ok(Scheme::None),
            "local-topk" | "topk" => Ok(Scheme::LocalTopK),
            "scalecom" | "clt-k" => Ok(Scheme::ScaleCom),
            other => anyhow::bail!("unknown perf scheme '{other}'"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Scheme::None => "no-compression",
            Scheme::LocalTopK => "local-topk",
            Scheme::ScaleCom => "scalecom",
        }
    }
}

/// System configuration (Figure 6 / A8 / A9 axes).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub workers: usize,
    /// per-worker peak compute, TFLOPs (paper: 100 and 300)
    pub peak_tflops: f64,
    /// achieved fraction of peak on DNN kernels. 0.2 calibrates the
    /// model to the paper's Fig 6(a): ResNet50 @100 TFLOPs, mb/worker=8,
    /// 32 GBps → communication ≈56% of step time (small per-core batches
    /// under-utilize the systolic arrays).
    pub compute_efficiency: f64,
    /// accelerator ↔ parameter-server bandwidth, GB/s (paper: 32, 64)
    pub bandwidth_gbps: f64,
    /// minibatch per worker (paper: 8 and 32)
    pub minibatch_per_worker: usize,
    /// gradient compression ratio for the compressed schemes (~100×)
    pub compression: f64,
    /// fraction of communication hidden under compute (software
    /// pipelining in [35]); 0 = fully exposed
    pub overlap: f64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            workers: 8,
            peak_tflops: 100.0,
            compute_efficiency: 0.2,
            bandwidth_gbps: 32.0,
            minibatch_per_worker: 8,
            compression: 112.0,
            overlap: 0.0,
        }
    }
}

/// Step-time breakdown in seconds (per training step).
#[derive(Debug, Clone)]
pub struct StepBreakdown {
    pub scheme: Scheme,
    pub compute_s: f64,
    /// gradient upload (worker → server)
    pub grad_up_s: f64,
    /// reduced gradient / weight download (server → worker)
    pub grad_down_s: f64,
    /// index broadcast (ScaleCom only)
    pub index_s: f64,
    pub exposed_comm_s: f64,
    pub total_s: f64,
}

impl StepBreakdown {
    pub fn comm_fraction(&self) -> f64 {
        self.exposed_comm_s / self.total_s
    }
}

/// Model one training step.
pub fn step_time(net: &PaperNet, sys: &SystemConfig, scheme: Scheme) -> StepBreakdown {
    let flops = net.train_flops_per_sample() * sys.minibatch_per_worker as f64;
    let effective = sys.peak_tflops * 1e12 * sys.compute_efficiency;
    let compute_s = flops / effective;

    let grad_bytes = net.gradient_bytes() as f64;
    let bw = sys.bandwidth_gbps * 1e9;
    let n = sys.workers as f64;

    // Sparse payloads carry (index, value) pairs: 8 bytes per kept
    // element vs 4 dense, i.e. wire size = 2·grad_bytes/compression.
    let sparse_bytes = 2.0 * grad_bytes / sys.compression;

    // Each worker has its own `bw` link to the parameter server (PCIe in
    // the paper's testbed); the server reduces in place, so the dense
    // baseline's per-worker traffic is constant in n — Appendix F.1:
    // "the conventional uncompressed scheme scales quite well ... the
    // accelerator to parameter server communication cost remains
    // constant". What does NOT stay constant is the *reduced result
    // size* under local top-k (the gradient build-up).
    let (up, down, index) = match scheme {
        // Dense: full gradient up, reduced gradient (same size) down.
        Scheme::None => (grad_bytes / bw, grad_bytes / bw, 0.0),
        // Local top-k: compressed upload, but the reduced union has
        // ~n·k entries (capped at the dense pair size) → downloads grow
        // linearly with the worker count.
        Scheme::LocalTopK => {
            let union_bytes = (n * sparse_bytes).min(2.0 * grad_bytes);
            (sparse_bytes / bw, union_bytes / bw, 0.0)
        }
        // ScaleCom: shared indices reduce on the server; k pairs each
        // way per worker plus the O(1) index broadcast (§5: ≈0.5% of
        // baseline communication).
        Scheme::ScaleCom => {
            let idx_bytes = grad_bytes / sys.compression;
            (sparse_bytes / bw, sparse_bytes / bw, idx_bytes / bw)
        }
    };
    let comm = up + down + index;
    let exposed = (comm - sys.overlap * comm.min(compute_s)).max(0.0);
    StepBreakdown {
        scheme,
        compute_s,
        grad_up_s: up,
        grad_down_s: down,
        index_s: index,
        exposed_comm_s: exposed,
        total_s: compute_s + exposed,
    }
}

/// Step time under the fully-pipelined (double-buffered) engine: the
/// next step's gradient/selection compute runs while the current step's
/// exchange is in flight, so instead of the serial sum the step costs
///
///   t_step = max(t_compute, t_comm)
///
/// (`overlap = 1` in [`step_time`]'s exposed-communication formula; with
/// comm ≤ compute the exchange is completely hidden). This is the
/// analytic model for the `pipelined` execution backend
/// (`runtime::pipelined`); `bench_allreduce` compares its measured
/// overlap efficiency against this prediction.
pub fn step_time_overlapped(
    net: &PaperNet,
    sys: &SystemConfig,
    scheme: Scheme,
) -> StepBreakdown {
    let mut s = sys.clone();
    s.overlap = 1.0;
    step_time(net, &s, scheme)
}

/// Wall-clock of a pipelined per-bucket timeline: `intervals[b]` is
/// bucket b's `(compute, comm)` pair in **production order** (backward
/// over the layers — the order backprop finishes them). Compute is
/// serial on the accelerator; each bucket's exchange starts as soon as
/// both its compute is done and the link is free (collectives serialize
/// on one link), so
///
///   compute_done_b = Σ_{i ≤ b} tc_i,
///   comm_free_b    = max(comm_free_{b−1}, compute_done_b) + tm_b,
///   t_step         = max(comm_free_B, compute_done_B).
///
/// With uniform buckets this closes to `max(Tc, Tm) + min(Tc, Tm)/B` —
/// the ideal overlapped `max(Tc, Tm)` plus the pipeline-fill bubble,
/// which shrinks as buckets get finer.
pub fn bucketed_pipeline_total(intervals: &[(f64, f64)]) -> f64 {
    let mut compute_done = 0.0f64;
    let mut comm_free = 0.0f64;
    for &(tc, tm) in intervals {
        compute_done += tc;
        comm_free = comm_free.max(compute_done) + tm;
    }
    comm_free.max(compute_done)
}

/// Step time under the bucketed exchange (`Coordinator::step_bucketed`):
/// the gradient is split into `buckets` uniform layer-aligned buckets,
/// bucket b's collective overlaps bucket b−1's selection compute, and
/// the step ends when the last bucket's exchange lands.
///
/// `sys.overlap` is **ignored**: that knob is [`step_time`]'s coarse
/// "fraction of comm hidden" stand-in for software pipelining, and the
/// per-bucket timeline *is* the mechanistic model of that same
/// pipelining — applying both would double-count the hiding. So
/// `buckets == 1` recovers the fully-exposed serial step
/// (`step_time` with `overlap = 0`), and `buckets → ∞` approaches the
/// fully-overlapped [`step_time_overlapped`] `max(Tc, Tm)`; a
/// `SystemConfig` with `overlap > 0` sits between those bounds and
/// should be compared against the bucketed model, not combined with it.
pub fn step_time_bucketed(
    net: &PaperNet,
    sys: &SystemConfig,
    scheme: Scheme,
    buckets: usize,
) -> StepBreakdown {
    assert!(buckets >= 1, "at least one bucket");
    // Decompose against the fully-exposed serial step so Tc/Tm are the
    // raw compute and comm totals (see the doc: sys.overlap is
    // deliberately not applied on top of the bucket timeline).
    let mut exposed = sys.clone();
    exposed.overlap = 0.0;
    let serial = step_time(net, &exposed, scheme);
    let comm = serial.grad_up_s + serial.grad_down_s + serial.index_s;
    let b = buckets as f64;
    let intervals = vec![(serial.compute_s / b, comm / b); buckets];
    let total = bucketed_pipeline_total(&intervals);
    StepBreakdown {
        scheme,
        compute_s: serial.compute_s,
        grad_up_s: serial.grad_up_s,
        grad_down_s: serial.grad_down_s,
        index_s: serial.index_s,
        exposed_comm_s: (total - serial.compute_s).max(0.0),
        total_s: total,
    }
}

/// Speedup of `scheme` relative to `baseline` on the same system.
pub fn speedup(net: &PaperNet, sys: &SystemConfig, scheme: Scheme, baseline: Scheme) -> f64 {
    step_time(net, sys, baseline).total_s / step_time(net, sys, scheme).total_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::paper::paper_net;

    fn sys(workers: usize, minibatch: usize, tflops: f64) -> SystemConfig {
        SystemConfig {
            workers,
            minibatch_per_worker: minibatch,
            peak_tflops: tflops,
            ..SystemConfig::default()
        }
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("scalecom").unwrap(), Scheme::ScaleCom);
        assert_eq!(Scheme::parse("none").unwrap(), Scheme::None);
        assert!(Scheme::parse("x").is_err());
    }

    #[test]
    fn fig1b_comm_fraction_grows_for_topk_not_scalecom() {
        // Fig 1(b): ResNet50, 32 GBps, ~112x — as workers grow, local
        // top-k communication dominates; ScaleCom stays flat.
        let net = paper_net("resnet50").unwrap();
        let mut topk_frac = Vec::new();
        let mut scalecom_frac = Vec::new();
        for n in [8usize, 32, 128] {
            let s = sys(n, 8, 100.0);
            topk_frac.push(step_time(&net, &s, Scheme::LocalTopK).comm_fraction());
            scalecom_frac.push(step_time(&net, &s, Scheme::ScaleCom).comm_fraction());
        }
        assert!(topk_frac[2] > topk_frac[0] * 2.0, "{topk_frac:?}");
        assert!((scalecom_frac[2] - scalecom_frac[0]).abs() < 0.02, "{scalecom_frac:?}");
    }

    #[test]
    fn paper_section5_speedup_shape() {
        // §5: with 100 TFLOPs/worker, ScaleCom speedup ≈2× at mb=8 and
        // ≈1.23× at mb=32; with 300 TFLOPs, 4.1× → 1.75×. We assert the
        // ordering and rough magnitudes (±40%) — the shape, not the
        // authors' exact constants.
        let net = paper_net("resnet50").unwrap();
        let s_100_8 = speedup(&net, &sys(128, 8, 100.0), Scheme::ScaleCom, Scheme::None);
        let s_100_32 = speedup(&net, &sys(128, 32, 100.0), Scheme::ScaleCom, Scheme::None);
        let s_300_8 = speedup(&net, &sys(128, 8, 300.0), Scheme::ScaleCom, Scheme::None);
        let s_300_32 = speedup(&net, &sys(128, 32, 300.0), Scheme::ScaleCom, Scheme::None);
        assert!(s_100_8 > s_100_32, "more comm-bound at smaller minibatch");
        assert!(s_300_8 > s_100_8, "more comm-bound at higher TFLOPs");
        assert!((1.6..3.0).contains(&s_100_8), "s_100_8={s_100_8}");
        assert!((1.0..1.8).contains(&s_100_32), "s_100_32={s_100_32}");
        assert!((3.2..6.0).contains(&s_300_8), "s_300_8={s_300_8}");
        assert!((1.5..2.6).contains(&s_300_32), "s_300_32={s_300_32}");
    }

    #[test]
    fn scalecom_comm_constant_in_workers() {
        let net = paper_net("resnet50").unwrap();
        let t8 = step_time(&net, &sys(8, 8, 100.0), Scheme::ScaleCom);
        let t128 = step_time(&net, &sys(128, 8, 100.0), Scheme::ScaleCom);
        // per-worker comm time is independent of the worker count
        let r8 = t8.exposed_comm_s;
        let r128 = t128.exposed_comm_s;
        assert!((r8 - r128).abs() / r8 < 1e-9, "{r8} vs {r128}");
    }

    #[test]
    fn scalecom_comm_under_3pct_at_128_workers_mb8() {
        // §5: "< 3% of total training time even with 128 workers and
        // minibatch/worker = 8".
        let net = paper_net("resnet50").unwrap();
        let t = step_time(&net, &sys(128, 8, 100.0), Scheme::ScaleCom);
        assert!(t.comm_fraction() < 0.03, "frac={}", t.comm_fraction());
    }

    #[test]
    fn fig_a8_local_topk_gains_shrink_with_n() {
        // A8: local top-k speedup 1.92x @8 workers decaying toward 1.2x
        // @128; ScaleCom ≈2x flat.
        let net = paper_net("resnet50").unwrap();
        let tk8 = speedup(&net, &sys(8, 8, 100.0), Scheme::LocalTopK, Scheme::None);
        let tk128 = speedup(&net, &sys(128, 8, 100.0), Scheme::LocalTopK, Scheme::None);
        let sc8 = speedup(&net, &sys(8, 8, 100.0), Scheme::ScaleCom, Scheme::None);
        let sc128 = speedup(&net, &sys(128, 8, 100.0), Scheme::ScaleCom, Scheme::None);
        assert!(tk8 > 1.5, "tk8={tk8}");
        assert!(tk128 < tk8 * 0.75, "tk128={tk128} tk8={tk8}");
        assert!((sc128 - sc8).abs() / sc8 < 0.05, "scalecom flat");
        assert!(sc128 > tk128, "scalecom beats top-k at scale");
    }

    #[test]
    fn bandwidth_doubling_helps_dense_baseline() {
        let net = paper_net("resnet50").unwrap();
        let s32 = sys(64, 8, 100.0);
        let mut s64 = s32.clone();
        s64.bandwidth_gbps = 64.0;
        let t32 = step_time(&net, &s32, Scheme::None).total_s;
        let t64 = step_time(&net, &s64, Scheme::None).total_s;
        // §F.1: ~1.35x improvement from 32→64 GBps
        let gain = t32 / t64;
        assert!(gain > 1.2 && gain < 2.0, "gain={gain}");
    }

    #[test]
    fn overlap_hides_communication() {
        let net = paper_net("resnet50").unwrap();
        let mut s = sys(8, 32, 100.0);
        let exposed = step_time(&net, &s, Scheme::None).exposed_comm_s;
        s.overlap = 0.5;
        let hidden = step_time(&net, &s, Scheme::None).exposed_comm_s;
        assert!(hidden < exposed);
    }

    #[test]
    fn bucketed_step_interpolates_serial_to_overlapped() {
        let net = paper_net("resnet50").unwrap();
        for (n, mb) in [(8usize, 8usize), (64, 32), (128, 8)] {
            for scheme in [Scheme::None, Scheme::LocalTopK, Scheme::ScaleCom] {
                let s = sys(n, mb, 100.0);
                let serial = step_time(&net, &s, scheme);
                let over = step_time_overlapped(&net, &s, scheme);
                let comm = serial.grad_up_s + serial.grad_down_s + serial.index_s;
                // one bucket == the serial step
                let b1 = step_time_bucketed(&net, &s, scheme, 1);
                assert!((b1.total_s - serial.total_s).abs() < 1e-12, "B=1 is serial");
                // sys.overlap is ignored (the bucket timeline IS the
                // overlap model): an overlap-0.5 system yields the same
                // bucketed totals as the overlap-0 system
                let mut half = s.clone();
                half.overlap = 0.5;
                for buckets in [1usize, 4] {
                    assert!(
                        (step_time_bucketed(&net, &half, scheme, buckets).total_s
                            - step_time_bucketed(&net, &s, scheme, buckets).total_s)
                            .abs()
                            < 1e-12,
                        "bucketed model must ignore sys.overlap"
                    );
                }
                // uniform closed form: max + min/B
                for buckets in [2usize, 4, 16, 64] {
                    let bt = step_time_bucketed(&net, &s, scheme, buckets);
                    let expect = serial.compute_s.max(comm)
                        + serial.compute_s.min(comm) / buckets as f64;
                    assert!(
                        (bt.total_s - expect).abs() < 1e-12,
                        "B={buckets}: {} vs {expect}",
                        bt.total_s
                    );
                    // monotone: more buckets never slower, bounded by
                    // serial above and ideal overlap below
                    assert!(bt.total_s <= serial.total_s + 1e-12);
                    assert!(bt.total_s >= over.total_s - 1e-12);
                }
                // fine buckets approach the ideal max(Tc, Tm)
                let b1k = step_time_bucketed(&net, &s, scheme, 1000);
                assert!(
                    (b1k.total_s - over.total_s) / over.total_s < 0.01,
                    "1000 buckets within 1% of max(Tc, Tm)"
                );
            }
        }
    }

    #[test]
    fn bucketed_pipeline_handles_nonuniform_intervals() {
        // comm-bound tail: the link stays busy after compute finishes
        let t = bucketed_pipeline_total(&[(1.0, 0.5), (1.0, 3.0)]);
        // compute_done: 1, 2; comm_free: max(0,1)+0.5=1.5, max(1.5,2)+3=5
        assert!((t - 5.0).abs() < 1e-12, "{t}");
        // compute-bound: comm hides entirely after the first bucket
        let t = bucketed_pipeline_total(&[(2.0, 0.5), (2.0, 0.5)]);
        // comm_free: 2.5, 4.5; compute_done: 4 → 4.5
        assert!((t - 4.5).abs() < 1e-12, "{t}");
    }

    #[test]
    fn overlapped_step_is_max_of_compute_and_comm_not_sum() {
        let net = paper_net("resnet50").unwrap();
        for (n, mb) in [(8usize, 8usize), (64, 8), (64, 32), (128, 8)] {
            for scheme in [Scheme::None, Scheme::LocalTopK, Scheme::ScaleCom] {
                let s = sys(n, mb, 100.0);
                let serial = step_time(&net, &s, scheme);
                let over = step_time_overlapped(&net, &s, scheme);
                let comm = serial.grad_up_s + serial.grad_down_s + serial.index_s;
                assert!(
                    (serial.total_s - (serial.compute_s + comm)).abs() < 1e-12,
                    "serial model is the sum"
                );
                assert!(
                    (over.total_s - serial.compute_s.max(comm)).abs() < 1e-12,
                    "overlapped model is max(compute, comm): {} vs {}",
                    over.total_s,
                    serial.compute_s.max(comm)
                );
                assert!(over.total_s <= serial.total_s);
            }
        }
    }
}
