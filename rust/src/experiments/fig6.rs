//! Figures 6 / A8 / A9 — end-to-end system performance (analytic model).
//!
//! 6(a)/A9(a): step-time stacked bars vs minibatch/worker (8, 32) at 100
//! and 300 TFLOPs; 6(b)/A9(b): vs worker count (8→128); A8: speedup over
//! the uncompressed baseline vs workers at 32 and 64 GBps for the three
//! schemes. All on the ResNet50 table at ~100× compression, like the
//! paper.

use crate::experiments::common::{self, fmt3};
use crate::metrics::{RunLog, Table};
use crate::models::paper::paper_net;
use crate::perfmodel::{speedup, step_time, Scheme, SystemConfig};

pub fn run_fig6() -> anyhow::Result<()> {
    let net = paper_net("resnet50")?;

    println!("\n=== Fig 6(a)/A9(a): step breakdown vs minibatch & TFLOPs ===\n");
    let mut table = Table::new(&[
        "tflops",
        "mb/worker",
        "scheme",
        "compute ms",
        "comm ms",
        "comm frac",
        "speedup vs dense",
    ]);
    let mut log = RunLog::new(
        "fig6a_minibatch",
        &["tflops", "mb", "scheme_id", "compute_ms", "comm_ms", "speedup"],
    );
    for &tflops in &[100.0, 300.0] {
        for &mb in &[8usize, 32] {
            for scheme in [Scheme::None, Scheme::LocalTopK, Scheme::ScaleCom] {
                let sys = SystemConfig {
                    workers: 128,
                    peak_tflops: tflops,
                    minibatch_per_worker: mb,
                    ..SystemConfig::default()
                };
                let t = step_time(&net, &sys, scheme);
                let sp = speedup(&net, &sys, scheme, Scheme::None);
                table.row(vec![
                    format!("{tflops:.0}"),
                    mb.to_string(),
                    t.scheme.label().to_string(),
                    fmt3(t.compute_s * 1e3),
                    fmt3(t.exposed_comm_s * 1e3),
                    format!("{:.0}%", t.comm_fraction() * 100.0),
                    format!("{sp:.2}x"),
                ]);
                log.push(vec![
                    tflops,
                    mb as f64,
                    scheme as usize as f64,
                    t.compute_s * 1e3,
                    t.exposed_comm_s * 1e3,
                    sp,
                ]);
            }
        }
    }
    println!("{}", table.render());
    log.save_csv(&common::results_dir())?;
    println!(
        "paper §5: @100T speedup 2x (mb=8) → 1.23x (mb=32); @300T 4.1x → 1.75x.\n"
    );

    println!("=== Fig 6(b)/A9(b): step breakdown vs worker count ===\n");
    let mut table = Table::new(&[
        "workers",
        "scheme",
        "compute ms",
        "comm ms",
        "comm frac",
    ]);
    let mut logb = RunLog::new(
        "fig6b_workers",
        &["workers", "scheme_id", "compute_ms", "comm_ms", "frac"],
    );
    for &n in &[8usize, 32, 128] {
        for scheme in [Scheme::None, Scheme::LocalTopK, Scheme::ScaleCom] {
            let sys = SystemConfig {
                workers: n,
                minibatch_per_worker: 8,
                ..SystemConfig::default()
            };
            let t = step_time(&net, &sys, scheme);
            table.row(vec![
                n.to_string(),
                t.scheme.label().to_string(),
                fmt3(t.compute_s * 1e3),
                fmt3(t.exposed_comm_s * 1e3),
                format!("{:.1}%", t.comm_fraction() * 100.0),
            ]);
            logb.push(vec![
                n as f64,
                scheme as usize as f64,
                t.compute_s * 1e3,
                t.exposed_comm_s * 1e3,
                t.comm_fraction(),
            ]);
        }
    }
    println!("{}", table.render());
    logb.save_csv(&common::results_dir())?;
    println!(
        "paper: local top-k comm grows linearly with workers; ScaleCom \
         constant, <3% of step time at 128 workers.\n"
    );
    Ok(())
}

pub fn run_fig_a8() -> anyhow::Result<()> {
    let net = paper_net("resnet50")?;
    println!("\n=== Fig A8: end-to-end speedup vs workers (strong scaling) ===");
    println!("(ResNet50, minibatch/worker=8, 112x compression)\n");
    let mut table = Table::new(&[
        "bandwidth",
        "workers",
        "none",
        "local-topk",
        "scalecom",
    ]);
    let mut log = RunLog::new(
        "figA8_speedup",
        &["bw_gbps", "workers", "none", "topk", "scalecom"],
    );
    // Normalized as the paper does: relative to no-compression @8
    // workers @32 GBps.
    let ref_sys = SystemConfig {
        workers: 8,
        minibatch_per_worker: 8,
        ..SystemConfig::default()
    };
    let ref_time = step_time(&net, &ref_sys, Scheme::None).total_s;
    for &bw in &[32.0, 64.0] {
        for &n in &[8usize, 16, 32, 64, 128] {
            let sys = SystemConfig {
                workers: n,
                minibatch_per_worker: 8,
                bandwidth_gbps: bw,
                ..SystemConfig::default()
            };
            let rel = |s: Scheme| ref_time / step_time(&net, &sys, s).total_s;
            table.row(vec![
                format!("{bw:.0} GBps"),
                n.to_string(),
                format!("{:.2}x", rel(Scheme::None)),
                format!("{:.2}x", rel(Scheme::LocalTopK)),
                format!("{:.2}x", rel(Scheme::ScaleCom)),
            ]);
            log.push(vec![
                bw,
                n as f64,
                rel(Scheme::None),
                rel(Scheme::LocalTopK),
                rel(Scheme::ScaleCom),
            ]);
        }
    }
    println!("{}", table.render());
    log.save_csv(&common::results_dir())?;
    println!(
        "paper A8: top-k's advantage decays from 1.92x (8 workers) toward \
         1.2x (128); ScaleCom holds ~2x independent of n; 64 GBps lifts \
         the dense baseline ~1.35x.\n"
    );
    Ok(())
}
