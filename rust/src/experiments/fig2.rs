//! Figure 2 — local-memory similarity analysis (the paper's key insight).
//!
//! On the vision workload (cnn, the ResNet18/CIFAR10 stand-in), with
//! local top-k error feedback:
//!   (a) pairwise cosine distance between workers' memories over
//!       iterations — drops fast and stays low; agnostic to n.
//!   (b) log-histogram overlap between worker0's local top-k EF-gradient
//!       magnitudes and the true top-k of the all-reduced EF gradient.
//!   (c) scaled LR (×100) destroys similarity; β=0.1 low-pass restores it.
//!   (d) histogram overlap with scaled LR + β=0.1 — still high.

use crate::experiments::common::{self, train_cfg};
use crate::metrics::{RunLog, Table};
use crate::stats::{mean_pairwise_cosine_distance, LogHistogram};
use crate::trainer::Trainer;
use crate::util::select::top_k_indices_by_magnitude;

struct SimilarityProbe {
    /// (step, mean pairwise cosine distance of memories)
    cosine: Vec<(usize, f64)>,
    /// histogram overlap local-top-k(w0) vs true-top-k at the last probe
    final_overlap: f64,
    /// the run hit a non-finite loss (paper Fig 1c behaviour)
    diverged: bool,
}

/// Train `steps` with the given scheme/LR/β and measure memory
/// similarity via the trainer hook. A diverging run (the paper's very
/// point about scaled LRs) is tolerated: statistics collected up to the
/// divergence are returned.
fn probe(
    model: &str,
    workers: usize,
    steps: usize,
    lr: f64,
    beta: f32,
    rate: usize,
) -> anyhow::Result<SimilarityProbe> {
    let mut cfg = train_cfg(model, "local-topk", workers, steps);
    cfg.lr = lr;
    cfg.compress.beta = beta;
    cfg.compress.rate = rate;

    use std::cell::RefCell;
    let cosine = RefCell::new(Vec::new());
    let final_overlap = RefCell::new(0.0f64);

    const K_FRAC: f64 = 0.02; // top-2% as in Fig 2(b) footnote
    let mut trainer = Trainer::from_config(cfg)?;
    trainer.set_hook(Box::new(|snap| {
        if snap.t % 5 == 4 || snap.t == 0 {
            let mems: Vec<Vec<f32>> = snap
                .memories
                .iter()
                .map(|m| m.memory().to_vec())
                .collect();
            cosine
                .borrow_mut()
                .push((snap.t, mean_pairwise_cosine_distance(&mems)));
        }
        let dim = snap.ef_grads[0].len();
        let k = ((dim as f64) * K_FRAC) as usize;
        if !snap.ef_grads.is_empty() && snap.t % 30 == 29 {
            // all-reduced EF gradient
            let n = snap.ef_grads.len();
            let mut avg = vec![0.0f32; dim];
            for ef in snap.ef_grads {
                for (a, &v) in avg.iter_mut().zip(ef) {
                    *a += v / n as f32;
                }
            }
            let true_idx = top_k_indices_by_magnitude(&avg, k);
            let local_idx = top_k_indices_by_magnitude(&snap.ef_grads[0], k);
            let mut h_true = LogHistogram::new(-8, 2, 4);
            let mut h_local = LogHistogram::new(-8, 2, 4);
            for &i in &true_idx {
                h_true.add(avg[i as usize]);
            }
            for &i in &local_idx {
                h_local.add(snap.ef_grads[0][i as usize]);
            }
            *final_overlap.borrow_mut() = h_true.overlap(&h_local);
        }
    }));
    let diverged = trainer.run().is_err(); // non-finite loss aborts the run
    drop(trainer); // release the hook's borrows of the probes
    Ok(SimilarityProbe {
        cosine: cosine.into_inner(),
        final_overlap: final_overlap.into_inner(),
        diverged,
    })
}

pub fn run(quick: bool) -> anyhow::Result<()> {
    let model = "cnn";
    let steps = if quick { 60 } else { 120 };
    println!("\n=== Fig 2: local memory similarity (cnn / vision stand-in) ===\n");

    // (a) cosine distance over iterations, standard LR, n ∈ {4, 8}
    println!("--- (a) pairwise cosine distance of memories over iterations ---");
    let mut log_a = RunLog::new("fig2a_cosine", &["step", "n4", "n8"]);
    let p4 = probe(model, 4, steps, 0.01, 1.0, 1000)?;
    let p8 = probe(model, 8, steps, 0.01, 1.0, 1000)?;
    let mut table = Table::new(&["step", "cos-dist n=4", "cos-dist n=8"]);
    for (i, &(t, d4)) in p4.cosine.iter().enumerate() {
        let d8 = p8.cosine.get(i).map(|&(_, d)| d).unwrap_or(f64::NAN);
        if i % 3 == 0 {
            table.row(vec![t.to_string(), common::fmt3(d4), common::fmt3(d8)]);
        }
        log_a.push(vec![t as f64, d4, d8]);
    }
    println!("{}", table.render());
    log_a.save_csv(&common::results_dir())?;
    let early4 = p4.cosine.first().unwrap().1;
    let late4 = p4.cosine.last().unwrap().1;
    println!(
        "early={early4:.3} late={late4:.3} — paper: distance drops quickly and \
         stays low; similar across worker counts.\n"
    );

    // (b)+(c)+(d): LR scaling and the low-pass filter
    println!("--- (c) scaled LR destroys similarity; low-pass filter restores ---");
    // paper Fig 2(c): lr 0.01 → 1 (x100), β sweep
    let cases = [
        ("lr 0.01, beta=1.0", 0.01, 1.0f32),
        ("lr 1.0,  beta=1.0", 1.0, 1.0),
        ("lr 1.0,  beta=0.3", 1.0, 0.3),
        ("lr 1.0,  beta=0.1", 1.0, 0.1),
    ];
    let mut table = Table::new(&[
        "setting",
        "final cos-dist",
        "hist overlap vs true top-k",
        "diverged",
    ]);
    let mut log_c = RunLog::new("fig2c_lr_beta", &["lr", "beta", "cosine", "overlap"]);
    for (label, lr, beta) in cases {
        let p = probe(model, 4, steps, lr, beta, 1000)?;
        let last = p.cosine.last().map(|&(_, d)| d).unwrap_or(f64::NAN);
        table.row(vec![
            label.to_string(),
            common::fmt3(last),
            common::fmt3(p.final_overlap),
            p.diverged.to_string(),
        ]);
        log_c.push(vec![lr, beta as f64, last, p.final_overlap]);
    }
    println!("{}", table.render());
    log_c.save_csv(&common::results_dir())?;
    println!(
        "paper Fig 2(c)/(d): lr x100 raises cosine distance sharply; \
         beta=0.1 brings it back down and keeps the top-k histograms \
         overlapping (>70%).\n"
    );
    Ok(())
}
