//! Figure 1 — the two scalability challenges.
//!
//! (a) gradient build-up: gathered sparse gradients cannot be reduced,
//!     so the aggregated nnz (and per-worker download) grows O(n) while
//!     ScaleCom's stays constant. Measured on the fabric.
//! (b) communication fraction of step time vs worker count for the
//!     ResNet50/ImageNet perf model (32 GBps, 112×) — server bottleneck.
//! (c) local top-k divergence in large-batch training: with a scaled
//!     learning rate, naive local top-k degrades while ScaleCom (β=0.1)
//!     tracks the uncompressed baseline (transformer workload).

use crate::comm::{Fabric, FabricConfig, Topology};
use crate::compress::{schemes::make_compressor, sparsify, Selection, SparseGrad};
use crate::experiments::common::{self, run_with_warmup, scaled_lr, train_cfg};
use crate::metrics::Table;
use crate::models::paper::paper_net;
use crate::perfmodel::{step_time, Scheme, SystemConfig};
use crate::util::rng::Rng;

pub fn run_fig1a(quick: bool) -> anyhow::Result<()> {
    println!("\n=== Fig 1(a): gradient build-up — gather vs reduce ===");
    let dim = if quick { 100_000 } else { 1_000_000 };
    let rate = 112;
    let k = dim / rate;
    let mut table = Table::new(&[
        "workers",
        "localtopk union nnz",
        "localtopk down B/worker",
        "scalecom nnz",
        "scalecom down B/worker",
    ]);
    let mut rows = crate::metrics::RunLog::new(
        "fig1a_buildup",
        &["workers", "topk_union_nnz", "topk_down", "scalecom_down"],
    );
    for n in [2usize, 4, 8, 16, 32, 64] {
        let mut rng = Rng::new(3);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();

        let mut topk = make_compressor("local-topk", rate, 1)?;
        let per = match topk.select(0, &views, k) {
            Selection::PerWorker(p) => p,
            _ => unreachable!(),
        };
        let sparses: Vec<SparseGrad> = grads
            .iter()
            .zip(&per)
            .map(|(g, idx)| sparsify(g, idx))
            .collect();
        let mut fabric = Fabric::new(FabricConfig {
            workers: n,
            topology: Topology::ParameterServer,
            ..FabricConfig::default()
        });
        let _ = fabric.sparse_gather_avg(&sparses);
        let topk_down = fabric.stats().last_cost().bytes_down_per_worker;
        let union_nnz = topk_down / 8;

        let mut clt = make_compressor("scalecom", rate, 1)?;
        let idx = match clt.select(0, &views, k) {
            Selection::Shared(ix) => ix,
            _ => unreachable!(),
        };
        let sparses: Vec<SparseGrad> = grads.iter().map(|g| sparsify(g, &idx)).collect();
        let mut fabric2 = Fabric::new(FabricConfig {
            workers: n,
            topology: Topology::ParameterServer,
            ..FabricConfig::default()
        });
        let _ = fabric2.sparse_allreduce_shared(&sparses, 0);
        let sc_down = fabric2.stats().last_cost().bytes_down_per_worker;

        table.row(vec![
            n.to_string(),
            union_nnz.to_string(),
            topk_down.to_string(),
            idx.len().to_string(),
            sc_down.to_string(),
        ]);
        rows.push(vec![
            n as f64,
            union_nnz as f64,
            topk_down as f64,
            sc_down as f64,
        ]);
    }
    println!("{}", table.render());
    rows.save_csv(&common::results_dir())?;
    println!("paper: gather grows O(n) (red curve in Fig 1a); ScaleCom constant.\n");
    Ok(())
}

pub fn run_fig1b() -> anyhow::Result<()> {
    println!("\n=== Fig 1(b): comm bottleneck vs workers (ResNet50 perf model) ===");
    println!("bandwidth=32 GBps, compression 112x, minibatch/worker=8\n");
    let net = paper_net("resnet50")?;
    let mut table = Table::new(&[
        "workers",
        "compute ms",
        "topk comm ms",
        "topk comm frac",
        "scalecom comm ms",
        "scalecom comm frac",
    ]);
    let mut rows = crate::metrics::RunLog::new(
        "fig1b_comm_fraction",
        &["workers", "compute_ms", "topk_ms", "topk_frac", "scalecom_ms", "scalecom_frac"],
    );
    for n in [4usize, 8, 16, 32, 64, 128] {
        let sys = SystemConfig {
            workers: n,
            ..SystemConfig::default()
        };
        let tk = step_time(&net, &sys, Scheme::LocalTopK);
        let sc = step_time(&net, &sys, Scheme::ScaleCom);
        table.row(vec![
            n.to_string(),
            common::fmt3(tk.compute_s * 1e3),
            common::fmt3(tk.exposed_comm_s * 1e3),
            format!("{:.0}%", tk.comm_fraction() * 100.0),
            common::fmt3(sc.exposed_comm_s * 1e3),
            format!("{:.0}%", sc.comm_fraction() * 100.0),
        ]);
        rows.push(vec![
            n as f64,
            tk.compute_s * 1e3,
            tk.exposed_comm_s * 1e3,
            tk.comm_fraction(),
            sc.exposed_comm_s * 1e3,
            sc.comm_fraction(),
        ]);
    }
    println!("{}", table.render());
    rows.save_csv(&common::results_dir())?;
    println!(
        "paper: as workers increase, PS→worker communication dominates for \
         gathered top-k; ScaleCom stays flat.\n"
    );
    Ok(())
}

pub fn run_fig1c(quick: bool) -> anyhow::Result<()> {
    println!("\n=== Fig 1(c): large-batch instability of unfiltered compression ===");
    println!("(bi-LSTM speech stand-in; 4x workers with 4x-scaled SGD LR + warmup)\n");
    // The paper's mechanism: error-feedback noise grows as α³ [28], so
    // the scaled LR of large-batch SGD destabilizes naive (unfiltered,
    // β=1) sparsified compression — Fig 1(c)'s divergence and the gray
    // curves of Fig 5. The low-pass filter (β=0.1) restores convergence.
    let model = "lstm";
    let base_workers = 4;
    let workers = if quick { 8 } else { 16 };
    let steps = if quick { 60 } else { 200 };
    let peak = scaled_lr(model, base_workers, workers); // 0.5 → 2.0
    let base = common::default_lr(model);
    let warmup = steps / 10;

    let mut results = Vec::new();
    for (label, scheme, beta) in [
        ("baseline (dense)", "none", 1.0f32),
        ("local top-k (unfiltered)", "local-topk", 1.0),
        ("ScaleCom beta=1 (unfiltered)", "scalecom", 1.0),
        ("ScaleCom beta=0.1 (low-pass)", "scalecom", 0.1),
    ] {
        let mut cfg = train_cfg(model, scheme, workers, steps);
        cfg.compress.beta = beta;
        cfg.compress.warmup_steps = if scheme == "none" { 0 } else { warmup };
        let loss = match run_with_warmup(cfg, base, peak, warmup) {
            Ok(mut log) => {
                log.name = format!(
                    "fig1c_{}_b{}",
                    scheme.replace('-', ""),
                    (beta * 10.0) as u32
                );
                log.save_csv(&common::results_dir())?;
                common::final_loss(&log)
            }
            Err(_) => f64::INFINITY, // hard divergence (non-finite loss)
        };
        results.push((label, loss));
    }
    let baseline = results[0].1;
    let mut table = Table::new(&["scheme", "final train loss", "vs baseline"]);
    for (label, loss) in &results {
        let status = if !loss.is_finite() || *loss > 10.0 * baseline.max(0.1) {
            "DIVERGED".to_string()
        } else {
            format!("{:+.3}", loss - baseline)
        };
        table.row(vec![
            label.to_string(),
            if loss.is_finite() {
                common::fmt3(*loss)
            } else {
                "inf".into()
            },
            status,
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper: naive compression diverges at 288k batch (Fig 1c) and the \
         unfiltered gray curves of Fig 5 degrade; the β=0.1 low-pass \
         filter restores baseline-tracking convergence.\n"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig1a_quick() {
        super::run_fig1a(true).unwrap();
    }

    #[test]
    fn fig1b_runs() {
        super::run_fig1b().unwrap();
    }
}
