//! Tables 2 & 3 + Figures 4 & 5 — accuracy parity under compression.
//!
//! Table 2 (standard batch): baseline vs ScaleCom (β=1) across the four
//! domain stand-ins at their paper-guided compression rates.
//! Table 3 (large batch): 4× the workers with linearly-scaled LR +
//! warmup; ScaleCom needs β≈0.1 (the β=1 column shows the degradation
//! the low-pass filter fixes — the gray curves of Fig 5).
//!
//! Training curves for every run are saved to results/ (Figures 4/5 and
//! A3–A7 are these CSVs).

use crate::experiments::common::{
    self, final_loss, run_with_warmup, scaled_lr, train_cfg,
};
use crate::metrics::Table;

/// (model, standard workers, steps)
const SUITE: &[(&str, usize, usize)] = &[
    ("mlp", 4, 200),
    ("cnn", 8, 400),
    ("transformer", 8, 800),
    ("lstm", 4, 400),
];

pub fn run_table2(quick: bool) -> anyhow::Result<()> {
    println!("\n=== Table 2: standard batch size — baseline vs ScaleCom ===\n");
    let mut table = Table::new(&[
        "model (stands in for)",
        "workers",
        "BSZ",
        "rate",
        "baseline loss",
        "scalecom loss",
        "baseline acc",
        "scalecom acc",
    ]);
    for &(model, workers, steps) in SUITE {
        let steps = if quick { steps / 4 } else { steps };
        let zoo = crate::models::zoo_model(model)?;

        let mut base_cfg = train_cfg(model, "none", workers, steps);
        base_cfg.eval_every = (steps / 4).max(1);
        let mut base_log = common::run(base_cfg)?;
        base_log.name = format!("table2_{model}_baseline");
        base_log.save_csv(&common::results_dir())?;

        let mut comp_cfg = train_cfg(model, "scalecom", workers, steps);
        comp_cfg.compress.warmup_steps = steps / 20; // <10% warmup, as §4
        comp_cfg.eval_every = (steps / 4).max(1);
        let mut comp_log = common::run(comp_cfg)?;
        comp_log.name = format!("table2_{model}_scalecom");
        comp_log.save_csv(&common::results_dir())?;

        table.row(vec![
            format!("{model} ({})", zoo.stands_in_for),
            workers.to_string(),
            (workers * zoo.batch_per_worker).to_string(),
            format!("{}x", zoo.default_rate),
            common::fmt3(final_loss(&base_log)),
            common::fmt3(final_loss(&comp_log)),
            fmt_acc(base_log.last("eval_acc")),
            fmt_acc(comp_log.last("eval_acc")),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper Table 2: compression matches baseline within noise at \
         65-400x across vision/language/speech.\n"
    );
    Ok(())
}

pub fn run_table3(quick: bool) -> anyhow::Result<()> {
    println!("\n=== Table 3: large batch (scaled LR) — the low-pass filter matters ===\n");
    let mut table = Table::new(&[
        "model",
        "workers",
        "BSZ",
        "baseline",
        "scalecom b=1",
        "scalecom b=0.1",
        "gap b=1",
        "gap b=0.1",
    ]);
    for &(model, base_workers, steps) in SUITE {
        // half the standard-batch horizon: 4x the workers see 2x the
        // samples overall, and three runs per model must stay tractable
        let steps = if quick { steps / 8 } else { steps / 2 };
        let workers = base_workers * 4; // 4x scale-out (paper: 8x-12x)
        let zoo = crate::models::zoo_model(model)?;
        let base_lr = common::default_lr(model);
        let peak = scaled_lr(model, base_workers, workers);
        let warmup = (steps / 10).max(1);

        // A diverged run (non-finite loss) is reported as such — the
        // instability of unfiltered compression at scaled LRs is the
        // paper's Fig 1(c)/Fig 5 finding, not an error.
        let run_one = |scheme: &str, beta: f32, tag: &str| -> anyhow::Result<f64> {
            let mut cfg = train_cfg(model, scheme, workers, steps);
            cfg.compress.beta = beta;
            cfg.compress.warmup_steps = if scheme == "none" { 0 } else { warmup };
            match run_with_warmup(cfg, base_lr, peak, warmup) {
                Ok(mut log) => {
                    log.name = format!("table3_{model}_{tag}");
                    log.save_csv(&common::results_dir())?;
                    Ok(final_loss(&log))
                }
                Err(_) => Ok(f64::INFINITY), // diverged
            }
        };

        let baseline = run_one("none", 1.0, "baseline")?;
        let beta1 = run_one("scalecom", 1.0, "beta1")?;
        let beta01 = run_one("scalecom", 0.1, "beta01")?;
        let fmt = |v: f64| {
            if v.is_finite() {
                common::fmt3(v)
            } else {
                "diverged".to_string()
            }
        };
        table.row(vec![
            model.to_string(),
            workers.to_string(),
            (workers * zoo.batch_per_worker).to_string(),
            fmt(baseline),
            fmt(beta1),
            fmt(beta01),
            format!("{:+.3}", beta1 - baseline),
            format!("{:+.3}", beta01 - baseline),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper Table 3 / Fig 5: without filtering (beta=1) large datasets \
         degrade under scaled LR; beta=0.1 restores baseline parity.\n"
    );
    Ok(())
}

fn fmt_acc(v: Option<f64>) -> String {
    match v {
        Some(a) if a.is_finite() => format!("{:.1}%", a * 100.0),
        _ => "-".to_string(),
    }
}
