//! Figure 3 — normalized Hamming distance between true top-k and CLT-k.
//!
//! Measured during training (cnn stand-in, CLT-k at 400×): at each probed
//! step, compare the cyclic leader's local top-k index set against the
//! true top-k of the all-reduced error-feedback gradient. The paper
//! observes d/k in 0.6–0.8 (i.e., 20–40% overlap) — enough overlap for
//! the Lemma-1 contraction to hold — and that the distance stays below
//! 1.0 even at per-worker batch 32 with many workers.

use crate::experiments::common::{self, train_cfg};
use crate::metrics::{RunLog, Table};
use crate::stats::normalized_hamming;
use crate::trainer::Trainer;
use crate::util::select::top_k_indices_by_magnitude;
use std::cell::RefCell;

fn probe(workers: usize, steps: usize, rate: usize) -> anyhow::Result<Vec<(usize, f64)>> {
    let mut cfg = train_cfg("cnn", "scalecom-exact", workers, steps);
    cfg.compress.rate = rate;
    let series = RefCell::new(Vec::new());
    let mut trainer = Trainer::from_config(cfg)?;
    trainer.set_hook(Box::new(|snap| {
        if snap.t % 5 != 4 {
            return;
        }
        let dim = snap.ef_grads[0].len();
        let k = (dim / rate).max(1);
        let n = snap.ef_grads.len();
        let mut avg = vec![0.0f32; dim];
        for ef in snap.ef_grads {
            for (a, &v) in avg.iter_mut().zip(ef) {
                *a += v / n as f32;
            }
        }
        let true_idx = top_k_indices_by_magnitude(&avg, k);
        // leader's local top-k (what CLT-k broadcasts)
        let leader = snap.result.leader;
        let clt_idx = top_k_indices_by_magnitude(&snap.ef_grads[leader], k);
        series
            .borrow_mut()
            .push((snap.t, normalized_hamming(&true_idx, &clt_idx)));
    }));
    trainer.run()?;
    drop(trainer);
    Ok(series.into_inner())
}

pub fn run(quick: bool) -> anyhow::Result<()> {
    println!("\n=== Fig 3: normalized Hamming distance true-top-k vs CLT-k ===");
    println!("(cnn stand-in, compression 400x as in the paper's figure)\n");
    let steps = if quick { 40 } else { 100 };
    let worker_counts: &[usize] = if quick { &[4, 8] } else { &[4, 8, 16] };

    let mut table = Table::new(&["workers", "d/k early", "d/k late", "d/k mean"]);
    let mut log = RunLog::new("fig3_hamming", &["workers", "step", "dk"]);
    for &n in worker_counts {
        let series = probe(n, steps, 400)?;
        for &(t, dk) in &series {
            log.push(vec![n as f64, t as f64, dk]);
        }
        let early = series.first().map(|&(_, d)| d).unwrap_or(f64::NAN);
        let late = series.last().map(|&(_, d)| d).unwrap_or(f64::NAN);
        let mean =
            series.iter().map(|&(_, d)| d).sum::<f64>() / series.len().max(1) as f64;
        table.row(vec![
            n.to_string(),
            common::fmt3(early),
            common::fmt3(late),
            common::fmt3(mean),
        ]);
    }
    println!("{}", table.render());
    log.save_csv(&common::results_dir())?;
    println!(
        "paper: d/k ∈ [0.6, 0.8] at 400x — CLT-k's index set keeps 20-40% \
         overlap with the true top-k, giving γ < 1 (Lemma 1) and stays \
         < 1.0 even at small per-worker batches (§3 'Large datasets and \
         small batch size').\n"
    );
    Ok(())
}
