//! Figure A1 — Q-Q similarity analysis of local memories.
//!
//! Reproduces the three panels as statistics: after ~100 iterations of
//! local top-k error feedback (top 0.1%, standard LR),
//!   (a) worker1-vs-worker2 *memory* magnitude quantiles: R² ≈ 0.99,
//!   (b) worker1-vs-worker2 *computed gradient* quantiles: visibly lower
//!       R² (the accumulation is what creates the similarity),
//!   (c) worker1 EF-gradient vs all-reduced EF-gradient quantiles:
//!       R² ≈ 0.99, Spearman ρ ≈ 0.66.

use crate::experiments::common::{self, train_cfg};
use crate::metrics::Table;
use crate::stats::{linear_fit_r2, magnitude_quantiles, spearman_correlation};
use crate::trainer::Trainer;
use std::cell::RefCell;

pub fn run(quick: bool) -> anyhow::Result<()> {
    println!("\n=== Fig A1: Q-Q similarity of memories / gradients ===\n");
    let steps = if quick { 50 } else { 100 };
    let mut cfg = train_cfg("cnn", "local-topk", 8, steps);
    cfg.compress.rate = 1000; // top-0.1% as in the figure

    struct Probes {
        mem_r2: f64,
        grad_r2: f64,
        ef_r2: f64,
        ef_spearman: f64,
    }
    let probes = RefCell::new(Probes {
        mem_r2: f64::NAN,
        grad_r2: f64::NAN,
        ef_r2: f64::NAN,
        ef_spearman: f64::NAN,
    });

    let last_step = steps - 1;
    let mut trainer = Trainer::from_config(cfg)?;
    trainer.set_hook(Box::new(|snap| {
        if snap.t != last_step {
            return;
        }
        let q = 101;
        let m1 = snap.memories[1].memory();
        let m2 = snap.memories[2].memory();
        let (_, _, mem_r2) =
            linear_fit_r2(&magnitude_quantiles(m1, q), &magnitude_quantiles(m2, q));
        let (_, _, grad_r2) = linear_fit_r2(
            &magnitude_quantiles(&snap.grads[1], q),
            &magnitude_quantiles(&snap.grads[2], q),
        );
        // all-reduced EF gradient
        let dim = snap.ef_grads[0].len();
        let n = snap.ef_grads.len();
        let mut avg = vec![0.0f32; dim];
        for ef in snap.ef_grads {
            for (a, &v) in avg.iter_mut().zip(ef) {
                *a += v / n as f32;
            }
        }
        let (_, _, ef_r2) = linear_fit_r2(
            &magnitude_quantiles(&snap.ef_grads[1], q),
            &magnitude_quantiles(&avg, q),
        );
        let rho = spearman_correlation(&snap.ef_grads[1], &avg);
        *probes.borrow_mut() = Probes {
            mem_r2,
            grad_r2,
            ef_r2,
            ef_spearman: rho,
        };
    }));
    trainer.run()?;
    drop(trainer);
    let p = probes.into_inner();

    let mut table = Table::new(&["panel", "quantity", "R2 (here)", "paper"]);
    table.row(vec![
        "(a)".into(),
        "memory w1 vs w2".into(),
        common::fmt3(p.mem_r2),
        "0.99".into(),
    ]);
    table.row(vec![
        "(b)".into(),
        "computed grads w1 vs w2".into(),
        common::fmt3(p.grad_r2),
        "0.89 (lower than (a))".into(),
    ]);
    table.row(vec![
        "(c)".into(),
        "EF grad w1 vs all-reduced".into(),
        common::fmt3(p.ef_r2),
        "0.99".into(),
    ]);
    println!("{}", table.render());
    println!(
        "Spearman rho (EF w1 vs all-reduced) = {:.3}  (paper: 0.657)\n",
        p.ef_spearman
    );
    anyhow::ensure!(p.mem_r2.is_finite());
    Ok(())
}
