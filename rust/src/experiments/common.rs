//! Shared helpers for the experiment drivers.

use crate::config::train::{CompressConfig, OptimizerKind, ScheduleKind, TrainConfig};
use crate::metrics::RunLog;
use crate::trainer::{LrSchedule, Trainer};
use std::path::PathBuf;

/// Where experiment CSVs land.
pub fn results_dir() -> PathBuf {
    let d = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Base config for a (model, scheme, workers) training run; experiment
/// drivers tweak the rest.
pub fn train_cfg(model: &str, scheme: &str, workers: usize, steps: usize) -> TrainConfig {
    let zoo = crate::models::zoo_model(model).expect("zoo model");
    TrainConfig {
        model: model.to_string(),
        workers,
        steps,
        batch_per_worker: zoo.batch_per_worker,
        lr: default_lr(model),
        momentum: 0.9,
        weight_decay: 0.0,
        optimizer: default_optimizer(model),
        schedule: ScheduleKind::Constant,
        seed: 42,
        compress: CompressConfig {
            scheme: scheme.to_string(),
            rate: zoo.default_rate,
            beta: 1.0,
            warmup_steps: 0,
            // conv nets need the paper's per-layer rate rule: flat
            // chunking starves the small high-gradient conv layers
            use_flops_rule: model == "cnn",
        },
        fabric_topology: "ps".into(),
        fabric_bandwidth_gbps: 32.0,
        backend: "sequential".into(),
        bucket_bytes: 0,
        eval_every: 0,
        artifacts_dir: "artifacts".into(),
    }
}

pub fn default_lr(model: &str) -> f64 {
    match model {
        "transformer" | "transformer-med" => 0.01, // adam
        "lstm" => 0.5,
        "cnn" => 0.05,
        _ => 0.1,
    }
}

pub fn default_optimizer(model: &str) -> OptimizerKind {
    match model {
        "transformer" | "transformer-med" => OptimizerKind::Adam,
        _ => OptimizerKind::SgdMomentum,
    }
}

/// Adam needs a gentler LR: scale large-batch LRs with sqrt for adam,
/// linear for SGD (Goyal et al. [7]).
pub fn scaled_lr(model: &str, base_workers: usize, workers: usize) -> f64 {
    let base = default_lr(model);
    let ratio = workers as f64 / base_workers as f64;
    match default_optimizer(model) {
        OptimizerKind::Adam => base * ratio.sqrt(),
        _ => base * ratio,
    }
}

/// Run a config to completion and return its log (convenience).
pub fn run(cfg: TrainConfig) -> anyhow::Result<RunLog> {
    let mut t = Trainer::from_config(cfg)?;
    t.run()
}

/// Run with a large-batch warmup schedule (linear base→peak over the
/// first `warmup` steps).
pub fn run_with_warmup(
    mut cfg: TrainConfig,
    base_lr: f64,
    peak_lr: f64,
    warmup: usize,
) -> anyhow::Result<RunLog> {
    cfg.lr = peak_lr;
    let mut t = Trainer::from_config(cfg)?;
    t.schedule = LrSchedule::warmup_linear(base_lr, peak_lr, warmup);
    t.run()
}

/// Smoothed final training loss (mean of last 20 steps).
pub fn final_loss(log: &RunLog) -> f64 {
    log.tail_mean("loss", 20).unwrap_or(f64::NAN)
}

pub fn fmt3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn fmt1(v: f64) -> String {
    format!("{v:.1}")
}
