//! Table 1 — comparing compressors for error-feedback SGD.
//!
//! Reproduces the paper's comparison columns with *measured* quantities:
//! scalability (per-worker download bytes vs n), selection overhead
//! (FLOPs/element model + measured ns/element), achieved compression
//! rate, and commutativity (Definition 1, checked numerically).

use crate::bench::{black_box, Bencher};
use crate::comm::{Fabric, FabricConfig, Topology};
use crate::compress::{schemes::make_compressor, sparsify, Selection, SparseGrad};
use crate::metrics::Table;
use crate::util::rng::Rng;

pub fn run(quick: bool) -> anyhow::Result<()> {
    let dim: usize = if quick { 100_000 } else { 1_000_000 };
    let rate = 100usize;
    let k = dim / rate;
    let schemes = [
        "local-topk",
        "scalecom",
        "true-topk",
        "random-k",
        "gtop-k",
        "sketch-k",
    ];

    println!("\n=== Table 1: comparing compressors for error-feedback SGD ===");
    println!("(dim={dim}, target rate={rate}x; scalability measured as per-worker");
    println!(" download bytes at n=4 vs n=32 — O(1) means the ratio stays ~1)\n");

    let mut rng = Rng::new(7);
    let grads4: Vec<Vec<f32>> = (0..4)
        .map(|_| {
            let mut v = vec![0.0f32; dim];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();

    let mut table = Table::new(&[
        "compressor",
        "scalability",
        "down(n=4)",
        "down(n=32)",
        "overhead FLOPs/elem",
        "ns/elem (measured)",
        "rate",
        "commutative",
    ]);

    let mut bencher = if quick {
        Bencher::quick()
    } else {
        Bencher::new()
    };

    for scheme in schemes {
        let mut down = Vec::new();
        for n in [4usize, 32] {
            let mut rng = Rng::new(7);
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0.0f32; dim];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let mut c = make_compressor(scheme, rate, 1)?;
            let sel = c.select(0, &views, k);
            let mut fabric = Fabric::new(FabricConfig {
                workers: n,
                topology: Topology::ParameterServer,
                ..FabricConfig::default()
            });
            match &sel {
                Selection::Shared(idx) => {
                    let sparses: Vec<SparseGrad> =
                        grads.iter().map(|g| sparsify(g, idx)).collect();
                    let _ = fabric.sparse_allreduce_shared(&sparses, 0);
                }
                Selection::PerWorker(per) => {
                    let sparses: Vec<SparseGrad> = grads
                        .iter()
                        .zip(per)
                        .map(|(g, idx)| sparsify(g, idx))
                        .collect();
                    let _ = fabric.sparse_gather_avg(&sparses);
                }
            }
            down.push(fabric.stats().last_cost().bytes_down_per_worker);
        }
        let scaling = down[1] as f64 / down[0] as f64;
        let scal_label = if scaling < 1.5 {
            "O(1) constant".to_string()
        } else if scaling < 6.0 {
            "O(log n)".to_string()
        } else {
            "O(n) build-up".to_string()
        };

        // selection overhead on the n=4 fixture
        let views: Vec<&[f32]> = grads4.iter().map(|g| g.as_slice()).collect();
        let mut c = make_compressor(scheme, rate, 1)?;
        // sketch-k is O(dim·rows) per estimate pass — quick mode only
        // benches it on a slice to keep the run short.
        let bench_views: Vec<&[f32]> = if scheme == "sketch-k" {
            views.iter().map(|v| &v[..dim.min(50_000)]).collect()
        } else {
            views.clone()
        };
        let bench_k = k.min(bench_views[0].len() / rate);
        let mut step = 0usize;
        let r = bencher.bench(&format!("table1/select/{scheme}"), || {
            let s = c.select(step, &bench_views, bench_k.max(1));
            step += 1;
            black_box(s);
        });
        let ns_per_elem = r.median_ns / bench_views[0].len() as f64;

        let sel = c.select(0, &views, k);
        let sent = match &sel {
            Selection::Shared(idx) => idx.len(),
            Selection::PerWorker(per) => per[0].len(),
        };
        let achieved_rate = dim as f64 / sent.max(1) as f64;

        table.row(vec![
            scheme.to_string(),
            scal_label,
            format!("{}", down[0]),
            format!("{}", down[1]),
            format!("{:.1}", c.overhead_flops_per_element(dim, k)),
            format!("{ns_per_elem:.2}"),
            format!("{achieved_rate:.0}x"),
            format!("{}", c.is_commutative()),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper Table 1: ScaleCom = constant scalability, ~3 FLOPs/elem \
         (chunk-wise sort), 65-400x, guaranteed convergence; top-k = O(n) \
         gather with O(log p) sort overhead.\n"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn table1_quick_runs() {
        super::run(true).unwrap();
    }
}
