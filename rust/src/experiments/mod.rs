//! Experiment drivers — one per paper table/figure (DESIGN.md §5).
//!
//! `scalecom experiment <id> [--quick]` regenerates the corresponding
//! result, printing the paper-comparable rows/series and saving the raw
//! data as CSV under `results/`.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig6;
pub mod figa1;
pub mod table1;
pub mod table23;

pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("table1", "compressor comparison: scalability/overhead/rate (Table 1)"),
    ("fig1a", "gradient build-up: gather vs reduce volume (Fig 1a)"),
    ("fig1b", "comm fraction vs workers, ResNet50 perf model (Fig 1b)"),
    ("fig1c", "large-batch divergence of naive local top-k (Fig 1c)"),
    ("fig2", "local memory similarity + low-pass filter (Fig 2a-d)"),
    ("fig3", "normalized Hamming distance CLT-k vs true top-k (Fig 3)"),
    ("table2", "standard-batch accuracy parity suite (Table 2, Figs 4/A3-A7)"),
    ("table3", "large-batch parity: beta ablation (Table 3, Fig 5)"),
    ("fig6", "system perf vs minibatch & workers (Fig 6, A9)"),
    ("figA8", "end-to-end speedup vs workers at 32/64 GBps (Fig A8)"),
    ("figA1", "Q-Q memory similarity statistics (Fig A1)"),
];

/// Run one experiment (or `all`).
pub fn run(id: &str, quick: bool) -> anyhow::Result<()> {
    match id {
        "table1" => table1::run(quick),
        "fig1a" => fig1::run_fig1a(quick),
        "fig1b" => fig1::run_fig1b(),
        "fig1c" => fig1::run_fig1c(quick),
        "fig2" => fig2::run(quick),
        "fig3" => fig3::run(quick),
        "table2" => table23::run_table2(quick),
        "table3" => table23::run_table3(quick),
        "fig6" => fig6::run_fig6(),
        "figA8" | "figa8" => fig6::run_fig_a8(),
        "figA1" | "figa1" => figa1::run(quick),
        "all" => {
            for (id, _) in EXPERIMENTS {
                run(id, quick)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}'; available: {}",
            EXPERIMENTS
                .iter()
                .map(|(i, _)| *i)
                .collect::<Vec<_>>()
                .join(", ")
        ),
    }
}

pub fn list() -> &'static [(&'static str, &'static str)] {
    EXPERIMENTS
}
