//! Bench-trend gate: compare two `bench_allreduce --json` artifacts and
//! flag median regressions past a budget.
//!
//! CI downloads the previous run's `BENCH_allreduce.json` (falling back
//! to the committed baseline) and runs
//! `scalecom bench-trend --baseline old.json --current new.json`; the
//! command exits non-zero when any benchmark whose name matches one of
//! the section prefixes (default `allreduce,codec/`) slows down by more
//! than `--max-regress` (default 15%). Benchmarks present in only one
//! file are reported but never fail the gate — sections come and go as
//! the suite grows, and a trend gate that blocks adding benches would
//! teach people to stop adding them.

use crate::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One benchmark present in both artifacts.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub name: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
}

impl Comparison {
    /// Fractional change vs baseline: +0.20 = 20% slower.
    pub fn delta(&self) -> f64 {
        if self.baseline_ns > 0.0 {
            (self.current_ns - self.baseline_ns) / self.baseline_ns
        } else {
            0.0
        }
    }
}

/// The full comparison: what matched, what regressed, what only one
/// side had.
#[derive(Debug, Clone)]
pub struct TrendReport {
    pub compared: Vec<Comparison>,
    pub regressions: Vec<Comparison>,
    pub baseline_only: Vec<String>,
    pub current_only: Vec<String>,
    pub max_regress: f64,
}

impl TrendReport {
    /// Human-readable per-benchmark lines; regressions are marked.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.compared {
            let d = c.delta();
            let mark = if d > self.max_regress {
                "  << REGRESSION"
            } else {
                ""
            };
            out.push_str(&format!(
                "trend {:<56} {:>12.1} -> {:>12.1} ns  ({:+.1}%){mark}\n",
                c.name,
                c.baseline_ns,
                c.current_ns,
                d * 100.0
            ));
        }
        for name in &self.baseline_only {
            out.push_str(&format!("trend {name:<56} dropped (baseline only)\n"));
        }
        for name in &self.current_only {
            out.push_str(&format!("trend {name:<56} new (no baseline)\n"));
        }
        out
    }
}

/// Pull `name -> median_ns` out of a `bench_allreduce --json` document.
pub fn medians_from_json(doc: &Json) -> Result<BTreeMap<String, f64>> {
    let results = doc
        .req("results")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("json field 'results' is not an array"))?;
    let mut out = BTreeMap::new();
    for (i, r) in results.iter().enumerate() {
        let name = r
            .req("name")
            .and_then(|n| {
                n.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("'name' is not a string"))
            })
            .with_context(|| format!("results[{i}]"))?;
        let median = r
            .req("median_ns")
            .and_then(|m| {
                m.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("'median_ns' is not a number"))
            })
            .with_context(|| format!("results[{i}] ({name})"))?;
        out.insert(name, median);
    }
    Ok(out)
}

fn matches(name: &str, prefixes: &[String]) -> bool {
    prefixes.is_empty() || prefixes.iter().any(|p| name.starts_with(p.as_str()))
}

/// Compare two parsed artifacts over the named section prefixes.
pub fn compare(
    baseline: &Json,
    current: &Json,
    prefixes: &[String],
    max_regress: f64,
) -> Result<TrendReport> {
    anyhow::ensure!(
        max_regress >= 0.0,
        "--max-regress must be non-negative, got {max_regress}"
    );
    let base = medians_from_json(baseline).context("baseline artifact")?;
    let cur = medians_from_json(current).context("current artifact")?;
    let mut report = TrendReport {
        compared: Vec::new(),
        regressions: Vec::new(),
        baseline_only: Vec::new(),
        current_only: Vec::new(),
        max_regress,
    };
    for (name, &b_ns) in &base {
        if !matches(name, prefixes) {
            continue;
        }
        match cur.get(name) {
            Some(&c_ns) => {
                let c = Comparison {
                    name: name.clone(),
                    baseline_ns: b_ns,
                    current_ns: c_ns,
                };
                if c.delta() > max_regress {
                    report.regressions.push(c.clone());
                }
                report.compared.push(c);
            }
            None => report.baseline_only.push(name.clone()),
        }
    }
    for name in cur.keys() {
        if matches(name, prefixes) && !base.contains_key(name) {
            report.current_only.push(name.clone());
        }
    }
    Ok(report)
}

/// Load both artifacts from disk and compare.
pub fn compare_files(
    baseline: &Path,
    current: &Path,
    prefixes: &[String],
    max_regress: f64,
) -> Result<TrendReport> {
    let load = |path: &Path| -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    };
    compare(&load(baseline)?, &load(current)?, prefixes, max_regress)
}

/// CI-friendly wrapper: a missing or empty baseline artifact skips the
/// gate (`Ok(None)`) instead of failing — the first run on a branch has
/// no previous artifact to diff against, and a gate that fails on "no
/// history yet" teaches people to delete the gate. A baseline that
/// exists with content but does not parse is still a hard error
/// (corruption must stay loud), as is an unreadable current artifact.
pub fn compare_files_with_optional_baseline(
    baseline: &Path,
    current: &Path,
    prefixes: &[String],
    max_regress: f64,
) -> Result<Option<TrendReport>> {
    let text = match std::fs::read_to_string(baseline) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(e).with_context(|| format!("reading {}", baseline.display()))
        }
    };
    // Whitespace-only counts as absent too: CI caches materialize
    // `touch`-style placeholder files.
    if text.trim().is_empty() {
        return Ok(None);
    }
    let base = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", baseline.display()))?;
    let cur_text = std::fs::read_to_string(current)
        .with_context(|| format!("reading {}", current.display()))?;
    let cur = Json::parse(&cur_text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", current.display()))?;
    compare(&base, &cur, prefixes, max_regress).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(entries: &[(&str, f64)]) -> Json {
        let results: Vec<Json> = entries
            .iter()
            .map(|(name, median)| {
                crate::json::obj(vec![
                    ("name", Json::from(*name)),
                    ("median_ns", Json::from(*median)),
                ])
            })
            .collect();
        crate::json::obj(vec![
            ("bench", Json::from("allreduce")),
            ("results", Json::Arr(results)),
        ])
    }

    fn prefixes(ps: &[&str]) -> Vec<String> {
        ps.iter().map(|p| p.to_string()).collect()
    }

    #[test]
    fn flags_regressions_past_the_budget() {
        let base = artifact(&[("allreduce/a", 100.0), ("allreduce/b", 100.0)]);
        let cur = artifact(&[("allreduce/a", 110.0), ("allreduce/b", 120.0)]);
        let r = compare(&base, &cur, &prefixes(&["allreduce"]), 0.15).unwrap();
        assert_eq!(r.compared.len(), 2);
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].name, "allreduce/b");
        assert!(r.render().contains("REGRESSION"));
    }

    #[test]
    fn improvements_and_small_drifts_pass() {
        let base = artifact(&[("codec/enc", 200.0)]);
        let cur = artifact(&[("codec/enc", 150.0)]);
        let r = compare(&base, &cur, &prefixes(&["codec/"]), 0.15).unwrap();
        assert!(r.regressions.is_empty());
        assert!((r.compared[0].delta() + 0.25).abs() < 1e-9);
    }

    #[test]
    fn prefix_filter_scopes_the_gate() {
        let base = artifact(&[("codec/enc", 100.0), ("simnet/x", 100.0)]);
        let cur = artifact(&[("codec/enc", 100.0), ("simnet/x", 900.0)]);
        // simnet regressed 9x but is outside the gated sections.
        let r = compare(&base, &cur, &prefixes(&["allreduce", "codec/"]), 0.15).unwrap();
        assert!(r.regressions.is_empty());
        assert_eq!(r.compared.len(), 1);
        // An empty prefix list means "gate everything".
        let all = compare(&base, &cur, &[], 0.15).unwrap();
        assert_eq!(all.regressions.len(), 1);
        assert_eq!(all.regressions[0].name, "simnet/x");
    }

    #[test]
    fn one_sided_benchmarks_never_fail_the_gate() {
        let base = artifact(&[("allreduce/old", 100.0)]);
        let cur = artifact(&[("allreduce/new", 100.0)]);
        let r = compare(&base, &cur, &prefixes(&["allreduce"]), 0.15).unwrap();
        assert!(r.compared.is_empty() && r.regressions.is_empty());
        assert_eq!(r.baseline_only, vec!["allreduce/old".to_string()]);
        assert_eq!(r.current_only, vec!["allreduce/new".to_string()]);
        let rendered = r.render();
        assert!(rendered.contains("dropped") && rendered.contains("new (no baseline)"));
    }

    #[test]
    fn zero_baseline_median_cannot_divide_by_zero() {
        let base = artifact(&[("allreduce/z", 0.0)]);
        let cur = artifact(&[("allreduce/z", 50.0)]);
        let r = compare(&base, &cur, &prefixes(&["allreduce"]), 0.15).unwrap();
        assert!(r.regressions.is_empty());
        assert_eq!(r.compared[0].delta(), 0.0);
    }

    #[test]
    fn schema_drift_is_a_hard_error() {
        let no_results = crate::json::obj(vec![("bench", Json::from("allreduce"))]);
        let ok = artifact(&[("a", 1.0)]);
        assert!(compare(&no_results, &ok, &[], 0.15).is_err());
        let bad_median = crate::json::obj(vec![(
            "results",
            Json::Arr(vec![crate::json::obj(vec![
                ("name", Json::from("a")),
                ("median_ns", Json::from("fast")),
            ])]),
        )]);
        assert!(compare(&ok, &bad_median, &[], 0.15).is_err());
        assert!(compare(&ok, &ok, &[], -0.1).is_err());
    }

    #[test]
    fn compare_files_round_trips_through_disk() {
        let dir = std::env::temp_dir().join("scalecom_trend_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("base.json");
        let cp = dir.join("cur.json");
        std::fs::write(&bp, artifact(&[("allreduce/a", 100.0)]).to_string_pretty()).unwrap();
        std::fs::write(&cp, artifact(&[("allreduce/a", 130.0)]).to_string_pretty()).unwrap();
        let r = compare_files(&bp, &cp, &prefixes(&["allreduce"]), 0.15).unwrap();
        assert_eq!(r.regressions.len(), 1);
        assert!(compare_files(Path::new("/nonexistent.json"), &cp, &[], 0.15).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_or_empty_baseline_skips_the_gate() {
        let dir = std::env::temp_dir().join("scalecom_trend_optional_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cp = dir.join("cur.json");
        std::fs::write(&cp, artifact(&[("allreduce/a", 130.0)]).to_string_pretty()).unwrap();
        // Missing baseline: skipped, not an error.
        let missing = dir.join("never_written.json");
        assert!(compare_files_with_optional_baseline(&missing, &cp, &[], 0.15)
            .unwrap()
            .is_none());
        // Empty (and whitespace-only) baseline: also skipped.
        let empty = dir.join("empty.json");
        std::fs::write(&empty, "").unwrap();
        assert!(compare_files_with_optional_baseline(&empty, &cp, &[], 0.15)
            .unwrap()
            .is_none());
        std::fs::write(&empty, "  \n").unwrap();
        assert!(compare_files_with_optional_baseline(&empty, &cp, &[], 0.15)
            .unwrap()
            .is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn present_baseline_still_gates_and_corruption_stays_loud() {
        let dir = std::env::temp_dir().join("scalecom_trend_present_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("base.json");
        let cp = dir.join("cur.json");
        std::fs::write(&bp, artifact(&[("allreduce/a", 100.0)]).to_string_pretty()).unwrap();
        std::fs::write(&cp, artifact(&[("allreduce/a", 130.0)]).to_string_pretty()).unwrap();
        let r = compare_files_with_optional_baseline(&bp, &cp, &prefixes(&["allreduce"]), 0.15)
            .unwrap()
            .expect("present baseline gates");
        assert_eq!(r.regressions.len(), 1);
        // A baseline with *content* that fails to parse is a hard error,
        // not a silent skip.
        std::fs::write(&bp, "{ not json").unwrap();
        assert!(compare_files_with_optional_baseline(&bp, &cp, &[], 0.15).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
