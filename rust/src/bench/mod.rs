//! Timing harness for the `cargo bench` targets (`harness = false`).
//!
//! criterion is unavailable offline, so this provides the essentials:
//! warmup, fixed-duration sampling, median/p10/p90 reporting, and a
//! black-box to defeat dead-code elimination. Output format is one line
//! per benchmark:
//!
//! `bench <name> ... median 1.234 us/iter  (p10 1.1, p90 1.4, n=431)`

pub mod trend;

use std::time::Instant;

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn per_elem(&self, elems: usize) -> f64 {
        self.median_ns / elems as f64
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.1} ns")
    }
}

/// Benchmark runner with a total time budget per benchmark.
pub struct Bencher {
    pub warmup_s: f64,
    pub measure_s: f64,
    pub min_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_s: 0.2,
            measure_s: 1.0,
            min_iters: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode bencher for CI/tests.
    pub fn quick() -> Self {
        Bencher {
            warmup_s: 0.02,
            measure_s: 0.1,
            min_iters: 3,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; each call is one sample.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w = Instant::now();
        while w.elapsed().as_secs_f64() < self.warmup_s {
            f();
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let m = Instant::now();
        while m.elapsed().as_secs_f64() < self.measure_s || samples_ns.len() < self.min_iters {
            let t = Instant::now();
            f();
            samples_ns.push(t.elapsed().as_nanos() as f64);
            if samples_ns.len() > 5_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = ((samples_ns.len() - 1) as f64 * p).round() as usize;
            samples_ns[idx]
        };
        let res = BenchResult {
            name: name.to_string(),
            median_ns: q(0.5),
            p10_ns: q(0.1),
            p90_ns: q(0.9),
            iters: samples_ns.len(),
        };
        println!(
            "bench {:<56} median {:>12}/iter  (p10 {}, p90 {}, n={})",
            res.name,
            fmt_ns(res.median_ns),
            fmt_ns(res.p10_ns),
            fmt_ns(res.p90_ns),
            res.iters
        );
        self.results.push(res.clone());
        res
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::quick();
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 3);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn per_elem_scales() {
        let r = BenchResult {
            name: "x".into(),
            median_ns: 1000.0,
            p10_ns: 900.0,
            p90_ns: 1100.0,
            iters: 10,
        };
        assert_eq!(r.per_elem(100), 10.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("us"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with(" s"));
    }
}
