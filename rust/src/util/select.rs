//! Magnitude-based selection primitives used by the compressors.
//!
//! The compression hot path needs "indices of the k largest |x_i|" without
//! a full sort. We provide:
//!  - `kth_largest_magnitude`: quickselect threshold (O(n) expected)
//!  - `top_k_indices_by_magnitude`: exact top-k index set
//!  - `top_k_via_heap`: bounded binary-heap variant (better for tiny k)
//!
//! All routines treat NaN as magnitude 0 so a corrupted gradient cannot
//! poison the ordering (sync-SGD asserts catch NaNs separately).

#[inline]
fn mag(x: f32) -> f32 {
    let a = x.abs();
    if a.is_nan() {
        0.0
    } else {
        a
    }
}

/// Magnitude of the k-th largest |x| (1-indexed: k=1 → max).
/// Expected O(n) via quickselect over a scratch copy of magnitudes.
pub fn kth_largest_magnitude(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len(), "k={k} out of range n={}", xs.len());
    let mut m: Vec<f32> = xs.iter().map(|&x| mag(x)).collect();
    // select_nth_unstable_by puts the element with the given order index
    // in place; index k-1 in descending order.
    let idx = k - 1;
    let (_, kth, _) =
        m.select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
    *kth
}

/// Exact indices of the k largest-magnitude entries.
///
/// Ties at the threshold are broken by lowest index so the result is a
/// deterministic function of the input — important because CLT-k
/// broadcasts this set to every worker, and workers must agree.
pub fn top_k_indices_by_magnitude(xs: &[f32], k: usize) -> Vec<u32> {
    let n = xs.len();
    assert!(k <= n, "k={k} > n={n}");
    if k == 0 {
        return Vec::new();
    }
    if k == n {
        return (0..n as u32).collect();
    }
    let thresh = kth_largest_magnitude(xs, k);
    // First take everything strictly above the threshold, then fill the
    // remainder with ties (== thresh) in index order.
    let mut out = Vec::with_capacity(k);
    let mut ties = Vec::new();
    for (i, &x) in xs.iter().enumerate() {
        let m = mag(x);
        if m > thresh {
            out.push(i as u32);
        } else if m == thresh {
            ties.push(i as u32);
        }
    }
    for &t in &ties {
        if out.len() == k {
            break;
        }
        out.push(t);
    }
    debug_assert_eq!(out.len(), k);
    out.sort_unstable();
    out
}

/// Heap-based exact top-k; O(n log k). Faster than quickselect when
/// k ≪ n because it avoids the O(n) scratch copy. Same tie-breaking
/// contract (lowest index wins among equal magnitudes).
pub fn top_k_via_heap(xs: &[f32], k: usize) -> Vec<u32> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    // Min-heap of (magnitude, Reverse(index)): the root is the *weakest*
    // kept element. An incoming element replaces the root if it has a
    // strictly larger magnitude, or an equal magnitude with smaller index.
    #[derive(PartialEq)]
    struct Entry {
        m: f32,
        i: u32,
    }
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> Ordering {
            // BinaryHeap is a max-heap; invert so the weakest is on top.
            // weaker = smaller magnitude, or equal magnitude w/ larger idx.
            o.m.partial_cmp(&self.m)
                .unwrap()
                .then_with(|| self.i.cmp(&o.i))
        }
    }

    let n = xs.len();
    assert!(k <= n, "k={k} > n={n}");
    if k == 0 {
        return Vec::new();
    }
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &x) in xs.iter().enumerate() {
        let e = Entry {
            m: mag(x),
            i: i as u32,
        };
        if heap.len() < k {
            heap.push(e);
        } else {
            let weakest = heap.peek().unwrap();
            let stronger = e.m > weakest.m || (e.m == weakest.m && e.i < weakest.i);
            if stronger {
                heap.pop();
                heap.push(e);
            }
        }
    }
    let mut out: Vec<u32> = heap.into_iter().map(|e| e.i).collect();
    out.sort_unstable();
    out
}

/// Multi-threaded exact top-k with output identical to
/// `top_k_indices_by_magnitude`.
///
/// Each of `threads` spans computes its local top-k (any global top-k
/// member beats at most k−1 elements overall, hence at most k−1 within
/// its own span, so it survives the span-local cut); the ≤ threads·k
/// candidates are then ranked with the global rule — magnitude
/// descending, lowest index wins ties — which is exactly the sequential
/// selection criterion, so the merged result matches bit-for-bit.
pub fn top_k_indices_by_magnitude_parallel(
    xs: &[f32],
    k: usize,
    threads: usize,
) -> Vec<u32> {
    let n = xs.len();
    assert!(k <= n, "k={k} > n={n}");
    // Fall back when the candidate pool (≈ threads·k) would approach n:
    // every span would return most of its contents and the merge sort
    // would cost more than the sequential O(n) quickselect.
    if threads <= 1 || k == 0 || n < (1 << 14) || k.saturating_mul(threads) >= n {
        return top_k_indices_by_magnitude(xs, k);
    }
    let span = n.div_ceil(threads);
    let mut candidates: Vec<u32> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                s.spawn(move || {
                    let lo = (t * span).min(n);
                    let hi = ((t + 1) * span).min(n);
                    if lo >= hi {
                        return Vec::new();
                    }
                    let local_k = k.min(hi - lo);
                    let mut ix = top_k_via_heap(&xs[lo..hi], local_k);
                    for i in &mut ix {
                        *i += lo as u32;
                    }
                    ix
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("top-k span thread panicked"))
            .collect()
    });
    candidates.sort_unstable_by(|&a, &b| {
        mag(xs[b as usize])
            .partial_cmp(&mag(xs[a as usize]))
            .unwrap()
            .then_with(|| a.cmp(&b))
    });
    candidates.truncate(k);
    candidates.sort_unstable();
    candidates
}

/// Oracle used by tests: full sort (stable w.r.t. index on ties).
pub fn top_k_by_full_sort(xs: &[f32], k: usize) -> Vec<u32> {
    let mut order: Vec<u32> = (0..xs.len() as u32).collect();
    order.sort_by(|&a, &b| {
        mag(xs[b as usize])
            .partial_cmp(&mag(xs[a as usize]))
            .unwrap()
            .then_with(|| a.cmp(&b))
    });
    let mut out = order[..k].to_vec();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kth_magnitude_simple() {
        let xs = [1.0, -5.0, 3.0, -2.0];
        assert_eq!(kth_largest_magnitude(&xs, 1), 5.0);
        assert_eq!(kth_largest_magnitude(&xs, 2), 3.0);
        assert_eq!(kth_largest_magnitude(&xs, 4), 1.0);
    }

    #[test]
    fn top_k_matches_sort_oracle_random() {
        let mut r = Rng::new(101);
        for n in [1usize, 2, 7, 64, 999] {
            let xs: Vec<f32> = (0..n).map(|_| r.next_normal_f32(0.0, 1.0)).collect();
            for k in [0, 1, n / 3, n] {
                assert_eq!(
                    top_k_indices_by_magnitude(&xs, k),
                    top_k_by_full_sort(&xs, k),
                    "n={n} k={k}"
                );
                assert_eq!(
                    top_k_via_heap(&xs, k),
                    top_k_by_full_sort(&xs, k),
                    "heap n={n} k={k}"
                );
            }
        }
    }

    #[test]
    fn ties_broken_by_lowest_index() {
        let xs = [2.0f32, -2.0, 2.0, 1.0];
        assert_eq!(top_k_indices_by_magnitude(&xs, 2), vec![0, 1]);
        assert_eq!(top_k_via_heap(&xs, 2), vec![0, 1]);
    }

    #[test]
    fn nan_treated_as_zero() {
        let xs = [f32::NAN, 1.0, -3.0];
        assert_eq!(top_k_indices_by_magnitude(&xs, 2), vec![1, 2]);
        assert_eq!(top_k_via_heap(&xs, 2), vec![1, 2]);
    }

    #[test]
    fn k_zero_and_k_full() {
        let xs = [1.0f32, 2.0];
        assert!(top_k_indices_by_magnitude(&xs, 0).is_empty());
        assert_eq!(top_k_indices_by_magnitude(&xs, 2), vec![0, 1]);
    }

    #[test]
    fn parallel_topk_bit_identical_to_sequential() {
        let mut r = Rng::new(77);
        for n in [1usize, 100, 16_384, 60_001] {
            let xs: Vec<f32> = (0..n).map(|_| r.next_normal_f32(0.0, 1.0)).collect();
            for k in [0usize, 1, 7, n / 10, n] {
                for threads in [1usize, 2, 4, 9] {
                    assert_eq!(
                        top_k_indices_by_magnitude_parallel(&xs, k, threads),
                        top_k_indices_by_magnitude(&xs, k),
                        "n={n} k={k} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_topk_ties_and_nans_match_sequential() {
        // adversarial: many exact ties crossing span boundaries + NaNs
        let mut xs = vec![1.0f32; 40_000];
        xs[33] = f32::NAN;
        xs[20_000] = 5.0;
        for k in [1usize, 100, 39_000] {
            assert_eq!(
                top_k_indices_by_magnitude_parallel(&xs, k, 4),
                top_k_indices_by_magnitude(&xs, k),
                "k={k}"
            );
        }
    }
}
