//! Wall-clock timing helper for coarse phase accounting in the trainer
//! and the bench harness.

use std::time::Instant;

/// Simple stopwatch accumulating named phase durations.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Seconds since construction or last `reset`.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ns(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Accumulates durations per named phase; used for the trainer's
/// compute/compress/communicate breakdown.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, f64)>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &str, seconds: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == phase) {
            e.1 += seconds;
        } else {
            self.entries.push((phase.to_string(), seconds));
        }
    }

    pub fn get(&self, phase: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    pub fn merge(&mut self, other: &PhaseTimes) {
        for (n, s) in &other.entries {
            self.add(n, *s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::new();
        let a = t.elapsed_ns();
        let b = t.elapsed_ns();
        assert!(b >= a);
    }

    #[test]
    fn phase_times_accumulate_and_merge() {
        let mut p = PhaseTimes::new();
        p.add("compute", 1.0);
        p.add("compute", 0.5);
        p.add("comm", 2.0);
        assert_eq!(p.get("compute"), 1.5);
        assert_eq!(p.get("missing"), 0.0);
        assert_eq!(p.total(), 3.5);

        let mut q = PhaseTimes::new();
        q.add("comm", 1.0);
        q.merge(&p);
        assert_eq!(q.get("comm"), 3.0);
    }
}
