//! xoshiro256** PRNG with splitmix64 seeding.
//!
//! Deterministic, fast, and stream-splittable: every worker in the
//! simulated cluster gets an independent stream derived from
//! `(seed, worker_id)`, which makes whole training runs reproducible
//! bit-for-bit regardless of thread scheduling.

/// xoshiro256** 1.0 generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid: the state is
    /// expanded through splitmix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Independent stream for a given worker/purpose. Streams derived from
    /// different `stream_id`s are statistically independent.
    pub fn for_stream(seed: u64, stream_id: u64) -> Self {
        // Mix the stream id through splitmix before expansion so that
        // (seed, 0) and (seed, 1) share no state structure.
        let mut sm = seed ^ stream_id.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        // Simple unbiased rejection sampling on the top bits.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; selection paths dominate runtime, not sampling).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with given mean/std as f32.
    pub fn next_normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.next_normal() as f32) * std + mean
    }

    /// Fill a slice with N(0, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal_f32(0.0, std);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm),
    /// returned sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u64) as usize;
            if !chosen.insert(t as u32) {
                chosen.insert(j as u32);
            }
        }
        let mut out: Vec<u32> = chosen.into_iter().collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Rng::for_stream(7, 0);
        let mut b = Rng::for_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let v = r.next_normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted_in_range() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let ks = r.sample_indices(100, 17);
            assert_eq!(ks.len(), 17);
            assert!(ks.windows(2).all(|w| w[0] < w[1]));
            assert!(ks.iter().all(|&i| (i as usize) < 100));
        }
    }

    #[test]
    fn sample_indices_full_set() {
        let mut r = Rng::new(17);
        let ks = r.sample_indices(8, 8);
        assert_eq!(ks, (0..8).collect::<Vec<u32>>());
    }
}
