//! Float comparison and vector helpers shared by tests and stats.

/// Relative-plus-absolute tolerance comparison, mirroring
/// `numpy.allclose` semantics with rtol=1e-5, atol=1e-6.
pub fn approx_eq(a: f32, b: f32) -> bool {
    approx_eq_eps(a, b, 1e-5, 1e-6)
}

pub fn approx_eq_eps(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    if a == b {
        return true;
    }
    if a.is_nan() || b.is_nan() {
        return false;
    }
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Elementwise allclose over slices; returns the first failing index.
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), usize> {
    assert_eq!(a.len(), b.len(), "allclose: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        if !approx_eq_eps(x, y, rtol, atol) {
            return Err(i);
        }
    }
    Ok(())
}

/// L2 norm with f64 accumulation (gradients can have 1e7+ elements;
/// f32 accumulation loses several digits there).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Dot product with f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Mean of a slice (f64 accumulation).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_basic() {
        assert!(approx_eq(1.0, 1.0));
        assert!(approx_eq(1.0, 1.0 + 1e-7));
        assert!(!approx_eq(1.0, 1.01));
        assert!(!approx_eq(f32::NAN, f32::NAN));
        assert!(approx_eq(0.0, 1e-7));
    }

    #[test]
    fn allclose_reports_first_bad_index() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.5, 3.0];
        assert_eq!(allclose(&a, &b, 1e-5, 1e-6), Err(1));
        assert_eq!(allclose(&a, &a, 1e-5, 1e-6), Ok(()));
    }

    #[test]
    fn l2_norm_known() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn dot_known() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn mean_known() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
