//! Small shared utilities: deterministic RNG, selection algorithms,
//! float helpers and wall-clock timers.
//!
//! Everything here is dependency-free on purpose: the image has no
//! crates.io access beyond the vendored set, so `rand`/`ordered-float`
//! equivalents are implemented (and tested) in-repo.

pub mod floats;
pub mod rng;
pub mod select;
pub mod signal;
pub mod timer;

pub use floats::{approx_eq, approx_eq_eps, l2_norm};
pub use rng::Rng;
pub use select::{kth_largest_magnitude, top_k_indices_by_magnitude};
pub use timer::Timer;
