//! Process-wide SIGINT/SIGTERM handling without a libc crate: the
//! handler flips one global `AtomicBool`, and long-running drivers
//! (`scalecom node`, `scalecom serve`) poll it between steps to drain
//! in-flight work, flush snapshots, and close mesh links cleanly (EOF,
//! not RST) before exiting 0.
//!
//! Only the CLI entry points install the handler; library callers and
//! in-process tests observe the flag solely through
//! [`shutdown_requested`] (false unless someone called
//! [`request_shutdown`]), so embedding the runtime never hijacks the
//! host process's signal disposition.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

// std already links the platform C runtime; `signal(2)` is all we need,
// so declare it directly instead of gating a libc dependency. Handlers
// installed via `signal` are async-signal-safe here because the handler
// body is a single atomic store.
extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
}

extern "C" fn on_signal(_signum: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install the SIGINT/SIGTERM handler that latches the shutdown flag.
/// Idempotent; call once from the CLI entry point before the run loop.
pub fn install_shutdown_handler() {
    let handler = on_signal as extern "C" fn(i32);
    unsafe {
        signal(SIGINT, handler as usize);
        signal(SIGTERM, handler as usize);
    }
}

/// Has a shutdown been requested (signal received, or
/// [`request_shutdown`] called)? Step loops poll this at their
/// boundaries and drain instead of starting new work.
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Latch the shutdown flag programmatically — the daemon uses it to
/// cascade a client-requested stop through the same drain path a signal
/// takes, and tests use it instead of delivering real signals.
pub fn request_shutdown() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Clear the latch (tests only: the flag is process-global, so a test
/// that set it must clear it to avoid draining later runs).
pub fn clear_shutdown() {
    SHUTDOWN.store(false, Ordering::SeqCst);
}

/// Serialize tests that latch/clear the process-global flag — without
/// this, two such tests on different harness threads would drain or
/// un-drain each other mid-assertion.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_round_trips_and_install_is_idempotent() {
        // `test_guard` serializes every test that touches the
        // process-global flag. No real signal delivery here — the
        // handler body is a one-line store, and the signal path proper
        // is exercised by the serve-smoke CI job (SIGTERM to a live
        // daemon).
        let _guard = test_guard();
        assert!(!shutdown_requested(), "no shutdown pending at entry");
        install_shutdown_handler();
        install_shutdown_handler();
        assert!(!shutdown_requested(), "installing must not latch the flag");
        request_shutdown();
        assert!(shutdown_requested());
        clear_shutdown();
        assert!(!shutdown_requested());
    }
}
