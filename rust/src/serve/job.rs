//! The served job runner: the one-shot coordination workload, step by
//! step, with a hook per step — progress streaming, cancellation, and
//! the shared-lane exchange plug in through the hook without touching
//! the math.
//!
//! Digest parity is by construction: [`run_steps`] is the *same*
//! computation as `runtime::socket::sequential_digest` (same
//! `Coordinator`, same gradient stream, same step records), so a served
//! job's digest is bit-identical to a one-shot run of the same spec —
//! the acceptance criterion — because they share this code, not because
//! two copies happen to agree. Each step additionally drives one
//! job-tagged collective on the daemon's shared lanes and verifies the
//! echoed tag, bucket and values, so cross-tenant corruption on the
//! multiplexed mesh is caught at the step where it happens.

use crate::comm::parallel::{CollectiveResult, CommJob};
use crate::comm::{Fabric, FabricConfig};
use crate::compress::{schemes::make_compressor, Selection};
use crate::coordinator::{Coordinator, Mode};
use crate::runtime::socket::{step_grads, NodeDigest, NodeWorkload, StepDigest, StepKind};
use crate::obs::{self, Histogram};
use crate::serve::lanes::LaneHandle;
use crate::util::floats::allclose;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What the per-step hook tells the loop to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepVerdict {
    Continue,
    /// Stop *before* executing this step (cancellation / drain); the
    /// digest covers the steps that completed.
    Stop,
}

/// Run the workload for `n` workers, calling `per_step` at every step
/// boundary with the step index, that step's gradient stream, and the
/// step record just produced (`None` on the boundary check before the
/// step runs). Returns the digest of the completed steps; the run
/// completed fully iff `digest.steps.len() == wl.steps`.
pub fn run_steps(
    wl: &NodeWorkload,
    n: usize,
    mut per_step: impl FnMut(usize, &[Vec<f32>], &StepDigest) -> anyhow::Result<StepVerdict>,
    mut before_step: impl FnMut(usize) -> StepVerdict,
) -> anyhow::Result<NodeDigest> {
    wl.validate()?;
    anyhow::ensure!(n >= 1, "need at least one worker");
    let fabric = Fabric::new(FabricConfig {
        workers: n,
        topology: wl.topology,
        ..FabricConfig::default()
    });
    let mode = if wl.scheme == "none" {
        Mode::Dense
    } else {
        Mode::Compressed(make_compressor(&wl.scheme, wl.rate, wl.seed)?)
    };
    let mut coord = Coordinator::new(n, wl.dim, mode, wl.beta, wl.k(), fabric, wl.warmup);
    let mut rng = Rng::for_stream(wl.seed, n as u64);
    let mut steps = Vec::with_capacity(wl.steps);
    for t in 0..wl.steps {
        if before_step(t) == StepVerdict::Stop {
            break;
        }
        let grads = step_grads(&mut rng, n, wl.dim);
        let r = coord.step(t, &grads);
        let (kind, values) = if r.dense {
            (StepKind::Dense, r.update.clone())
        } else {
            match r.selection.as_ref().expect("compressed step has a selection") {
                Selection::Shared(ix) => (
                    StepKind::Shared(ix.clone()),
                    ix.iter().map(|&i| r.update[i as usize]).collect(),
                ),
                Selection::PerWorker(per) => {
                    let mut union: Vec<u32> = per.iter().flatten().copied().collect();
                    union.sort_unstable();
                    union.dedup();
                    (
                        StepKind::Gather(per.clone()),
                        union.iter().map(|&i| r.update[i as usize]).collect(),
                    )
                }
            }
        };
        let step = StepDigest {
            t,
            leader: r.leader,
            kind,
            values,
            comm: r.comm.clone(),
        };
        if per_step(t, &grads, &step)? == StepVerdict::Stop {
            steps.push(step);
            break;
        }
        steps.push(step);
        if wl.step_delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(wl.step_delay_ms));
        }
    }
    Ok(NodeDigest {
        workers: n,
        steps,
        final_memory_rank0: coord.memory_snapshot()[0].memory().to_vec(),
    })
}

/// Shared latency histograms a served job records into as it runs.
/// `None` fields skip recording, so unit tests and one-off callers pass
/// `JobObs::default()`.
#[derive(Default, Clone)]
pub struct JobObs {
    /// Wall seconds per completed step (compute + shared-lane exchange).
    pub step_latency: Option<Arc<Histogram>>,
    /// Wall seconds blocked inside `lanes.collective` per step.
    pub collective_wait: Option<Arc<Histogram>>,
}

/// A finished (or stopped) served job.
#[derive(Debug)]
pub struct JobReport {
    pub digest: NodeDigest,
    /// Wall seconds per completed step (compute + shared-lane exchange).
    pub step_seconds: Vec<f64>,
    /// False when the job stopped at a cancel signal.
    pub completed: bool,
}

/// Run job `id` on the daemon's shared lanes. Per step: one job-tagged
/// dense ring average of the step's gradient stream crosses the shared
/// mesh and is verified against the locally computed mean (ring f32
/// tolerance), then `progress(done, total, step_secs)` streams the
/// advance with that step's wall seconds. The `cancel` flag is polled
/// at every step boundary.
pub fn run_job(
    id: u32,
    wl: &NodeWorkload,
    lanes: &LaneHandle,
    cancel: &AtomicBool,
    hobs: &JobObs,
    mut progress: impl FnMut(usize, usize, f64),
) -> anyhow::Result<JobReport> {
    anyhow::ensure!(id != 0, "job id 0 is the legacy lane tag, never a served job");
    let n = lanes.workers();
    let mut step_seconds = Vec::with_capacity(wl.steps);
    let mut clock = std::time::Instant::now();
    let digest = run_steps(
        wl,
        n,
        |t, grads, _step| {
            let _step_sp = obs::span(obs::Category::JobStep).job(id).step(t as u32);
            let mut expect = vec![0.0f32; wl.dim];
            for g in grads {
                for (a, b) in expect.iter_mut().zip(g) {
                    *a += *b;
                }
            }
            for v in &mut expect {
                *v /= n as f32;
            }
            let jobs: Vec<CommJob> = grads
                .iter()
                .map(|g| CommJob::RingAvg {
                    job: id,
                    bucket: t as u32,
                    buf: g.clone(),
                })
                .collect();
            let coll_clock = std::time::Instant::now();
            let result = {
                let _sp = obs::span(obs::Category::Collective).job(id).step(t as u32);
                lanes.collective(id, jobs)?
            };
            if let Some(h) = &hobs.collective_wait {
                h.record_ns(coll_clock.elapsed().as_nanos() as u64);
            }
            match result {
                CollectiveResult::Reduced { job, bucket, vals } => {
                    anyhow::ensure!(
                        (job, bucket) == (id, t as u32),
                        "job {id} step {t}: lane echoed (job {job}, bucket {bucket})"
                    );
                    if let Err(i) = allclose(&vals, &expect, 1e-5, 1e-6) {
                        anyhow::bail!(
                            "job {id} step {t}: shared-lane average diverged at {i}: \
                             {} vs {} (cross-job corruption?)",
                            vals[i],
                            expect[i]
                        );
                    }
                }
                other => anyhow::bail!("job {id} step {t}: unexpected lane result {other:?}"),
            }
            let secs = clock.elapsed().as_secs_f64();
            if let Some(h) = &hobs.step_latency {
                h.record_secs(secs);
            }
            step_seconds.push(secs);
            clock = std::time::Instant::now();
            progress(t + 1, wl.steps, secs);
            Ok(StepVerdict::Continue)
        },
        |_t| {
            if cancel.load(Ordering::SeqCst) {
                StepVerdict::Stop
            } else {
                StepVerdict::Continue
            }
        },
    )?;
    let completed = digest.steps.len() == wl.steps;
    Ok(JobReport {
        digest,
        step_seconds,
        completed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::parallel::LaneTransport;
    use crate::runtime::socket::{compare_digests, sequential_digest};
    use crate::serve::lanes::SharedLanes;

    #[test]
    fn run_steps_matches_sequential_digest_exactly() {
        for scheme in ["scalecom", "local-topk", "none"] {
            let wl = NodeWorkload {
                scheme: scheme.into(),
                steps: 12,
                warmup: 2,
                ..NodeWorkload::default()
            };
            let got = run_steps(&wl, 3, |_, _, _| Ok(StepVerdict::Continue), |_| {
                StepVerdict::Continue
            })
            .unwrap();
            let want = sequential_digest(&wl, 3).unwrap();
            // Shared code path: must be exact, not just within tolerance.
            compare_digests(&got, &want, 0.0, 0.0)
                .unwrap_or_else(|e| panic!("{scheme}: {e:#}"));
        }
    }

    #[test]
    fn run_job_digest_is_bit_identical_to_one_shot() {
        let lanes = SharedLanes::start(2, LaneTransport::Channel, 0).unwrap();
        let wl = NodeWorkload {
            steps: 6,
            ..NodeWorkload::default()
        };
        let mut seen = Vec::new();
        let hobs = JobObs {
            step_latency: Some(Arc::new(Histogram::default())),
            collective_wait: Some(Arc::new(Histogram::default())),
        };
        let report = run_job(
            5,
            &wl,
            &lanes.handle(),
            &AtomicBool::new(false),
            &hobs,
            |done, total, secs| {
                assert!(secs >= 0.0);
                seen.push((done, total));
            },
        )
        .unwrap();
        assert!(report.completed);
        assert_eq!(seen, (1..=6).map(|d| (d, 6)).collect::<Vec<_>>());
        assert_eq!(report.step_seconds.len(), 6);
        let snap = hobs.step_latency.as_ref().unwrap().snapshot();
        assert_eq!(snap.count, 6, "one step-latency sample per step");
        let coll = hobs.collective_wait.as_ref().unwrap().snapshot();
        assert_eq!(coll.count, 6, "one collective-wait sample per step");
        let want = sequential_digest(&wl, 2).unwrap();
        compare_digests(&report.digest, &want, 0.0, 0.0).unwrap();
        assert!(lanes.fault().is_none());
    }

    #[test]
    fn cancel_stops_at_a_step_boundary_with_partial_digest() {
        let lanes = SharedLanes::start(2, LaneTransport::Channel, 0).unwrap();
        let wl = NodeWorkload {
            steps: 50,
            ..NodeWorkload::default()
        };
        let cancel = AtomicBool::new(false);
        let report = run_job(7, &wl, &lanes.handle(), &cancel, &JobObs::default(), |done, _, _| {
            if done == 3 {
                cancel.store(true, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert!(!report.completed);
        assert_eq!(report.digest.steps.len(), 3, "stopped at the boundary after step 3");
    }
}
