//! The `scalecom serve` daemon: one process owning a persistent shared
//! comm-lane mesh, a bounded FIFO job queue, a framed control plane for
//! clients (`SubmitJob`/`QueryStats`/`CancelJob` over the v5 wire
//! codec), and a Prometheus-style `/metrics` endpoint over plain TCP.
//!
//! Threading model:
//! - one accept thread per listener (control + metrics), non-blocking
//!   accept polled against the shutdown flag so both join promptly;
//! - one detached thread per client connection, doing *blocking* framed
//!   reads (a read timeout could desync mid-frame, and the process
//!   exits regardless when `main` returns) and writing replies through
//!   an `Arc<Mutex<TcpStream>>` clone so progress frames from job
//!   threads interleave whole-frame with request replies; writes carry
//!   [`WRITE_TIMEOUT`] and always happen outside the daemon's locks, so
//!   a stalled client can cost a dropped frame but never a held lock;
//! - one thread per running job (joined at shutdown), dispatched FIFO
//!   by [`JobQueue`] under the concurrency cap;
//! - the lane owner thread inside [`SharedLanes`], dropped last so the
//!   mesh tears down with clean EOFs after every job thread is gone.

use crate::comm::parallel::LaneTransport;
use crate::comm::wire::{self, Purpose, WireMsg, WIRE_CODEC_VERSION};
use crate::obs::{self, Histogram};
use crate::runtime::socket::{render_digest, NodeWorkload};
use crate::serve::job::{run_job, JobObs};
use crate::serve::lanes::{LaneHandle, SharedLanes};
use crate::serve::metrics::{self, JobMetrics, ServeMetrics};
use crate::serve::protocol;
use crate::serve::queue::{CancelOutcome, JobQueue, RejectReason, Submission};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Upper bound on any single client-socket write (progress frames,
/// replies, terminal frames): a client that stops reading eats timeouts
/// and eventually loses its stream, but never wedges a daemon lock or a
/// job's concurrency slot.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// `--bind` default / override (flag wins over env, env over default).
pub const ENV_SERVE_ADDR: &str = "SCALECOM_SERVE_ADDR";
/// `--max-queue` default / override.
pub const ENV_SERVE_MAX_QUEUE: &str = "SCALECOM_SERVE_MAX_QUEUE";

/// Read [`ENV_SERVE_ADDR`]; `Ok(None)` when unset, loud when set but
/// empty (mirrors `runtime::socket::env_heartbeat_ms`).
pub fn env_serve_addr() -> anyhow::Result<Option<String>> {
    match std::env::var(ENV_SERVE_ADDR) {
        Ok(s) => {
            let s = s.trim().to_string();
            anyhow::ensure!(!s.is_empty(), "{ENV_SERVE_ADDR} is set but empty");
            Ok(Some(s))
        }
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(e) => Err(anyhow::anyhow!("{ENV_SERVE_ADDR}: {e}")),
    }
}

/// Read [`ENV_SERVE_MAX_QUEUE`]; `Ok(None)` when unset.
pub fn env_serve_max_queue() -> anyhow::Result<Option<usize>> {
    match std::env::var(ENV_SERVE_MAX_QUEUE) {
        Ok(s) => s.trim().parse::<usize>().map(Some).map_err(|_| {
            anyhow::anyhow!("{ENV_SERVE_MAX_QUEUE}={s}: expects a whole number of jobs")
        }),
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(e) => Err(anyhow::anyhow!("{ENV_SERVE_MAX_QUEUE}: {e}")),
    }
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Control-plane bind address (framed wire protocol). Port 0 picks
    /// a free port; read it back from [`DaemonHandle::control_addr`].
    pub bind: String,
    /// `/metrics` bind address (plain-text HTTP/1.0).
    pub metrics_bind: String,
    /// Lane-mesh width: every served job runs with this many workers.
    pub workers: usize,
    /// Hierarchical ring group size (0 = flat ring).
    pub group_size: usize,
    pub transport: LaneTransport,
    pub max_queue: usize,
    pub max_concurrent: usize,
    /// How many *finished* jobs keep their per-job `/metrics` series
    /// (`--metrics-job-retention`). Queued/running jobs never count
    /// against the cap; older finished jobs are pruned so scrape
    /// cardinality stays bounded on a long-lived daemon.
    pub metrics_job_retention: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            bind: "127.0.0.1:7070".into(),
            metrics_bind: "127.0.0.1:7071".into(),
            workers: 2,
            group_size: 0,
            transport: LaneTransport::Channel,
            max_queue: 8,
            max_concurrent: 2,
            metrics_job_retention: 64,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobStatus {
    fn label(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }
}

/// Per-job bookkeeping behind the `jobs`/`/metrics` views.
struct JobState {
    spec: String,
    wl: NodeWorkload,
    status: JobStatus,
    submitted_at: Instant,
    steps_done: usize,
    step_seconds_sum: f64,
    comm_bytes_up: u64,
    comm_bytes_down: u64,
    comm_time_seconds: f64,
    cancel: Arc<AtomicBool>,
    /// The submitting connection's write half; progress and completion
    /// frames stream here. `None` once the client hangs up.
    conn: Option<Arc<Mutex<TcpStream>>>,
    error: Option<String>,
}

struct Shared {
    queue: Mutex<JobQueue>,
    jobs: Mutex<BTreeMap<u32, JobState>>,
    /// `None` after shutdown takes it — job threads clone it at
    /// dispatch, so the lane owner's channel closes once they finish.
    lanes: Mutex<Option<LaneHandle>>,
    shutdown: AtomicBool,
    /// Scheduler wait summary: (sum of admission→start seconds, count).
    wait: Mutex<(f64, u64)>,
    /// Log-bucketed latency distributions behind `/metrics` (wait-free
    /// recording; job threads feed the job-scoped pair through
    /// [`JobObs`]).
    sched_wait: Histogram,
    step_latency: Arc<Histogram>,
    collective_wait: Arc<Histogram>,
    /// Finished jobs kept visible in `/metrics` (the cardinality cap).
    retention: usize,
    job_threads: Mutex<Vec<JoinHandle<()>>>,
}

/// A running daemon. Keep it alive for the daemon's lifetime; call
/// [`DaemonHandle::shutdown`] to drain and join everything.
pub struct Daemon {
    shared: Arc<Shared>,
    lanes: SharedLanes,
    control_addr: std::net::SocketAddr,
    metrics_addr: std::net::SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
    metrics_thread: Option<JoinHandle<()>>,
}

impl Daemon {
    /// Bind both listeners, build the shared lane mesh, start the
    /// accept threads. Fails loudly on a busy port or a bad mesh shape.
    pub fn start(cfg: &ServeConfig) -> anyhow::Result<Daemon> {
        anyhow::ensure!(cfg.workers >= 1, "serve needs at least one lane worker");
        let lanes = SharedLanes::start(cfg.workers, cfg.transport, cfg.group_size)?;
        let control = TcpListener::bind(&cfg.bind)
            .map_err(|e| anyhow::anyhow!("serve bind {}: {e}", cfg.bind))?;
        let metrics_l = TcpListener::bind(&cfg.metrics_bind)
            .map_err(|e| anyhow::anyhow!("metrics bind {}: {e}", cfg.metrics_bind))?;
        let control_addr = control.local_addr()?;
        let metrics_addr = metrics_l.local_addr()?;
        control.set_nonblocking(true)?;
        metrics_l.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(JobQueue::new(cfg.max_queue, cfg.max_concurrent)),
            jobs: Mutex::new(BTreeMap::new()),
            lanes: Mutex::new(Some(lanes.handle())),
            shutdown: AtomicBool::new(false),
            wait: Mutex::new((0.0, 0)),
            sched_wait: Histogram::default(),
            step_latency: Arc::new(Histogram::default()),
            collective_wait: Arc::new(Histogram::default()),
            retention: cfg.metrics_job_retention,
            job_threads: Mutex::new(Vec::new()),
        });
        let s1 = shared.clone();
        let accept_thread = std::thread::spawn(move || accept_loop(s1, control));
        let s2 = shared.clone();
        let metrics_thread = std::thread::spawn(move || metrics_loop(s2, metrics_l));
        Ok(Daemon {
            shared,
            lanes,
            control_addr,
            metrics_addr,
            accept_thread: Some(accept_thread),
            metrics_thread: Some(metrics_thread),
        })
    }

    pub fn control_addr(&self) -> String {
        self.control_addr.to_string()
    }

    pub fn metrics_addr(&self) -> String {
        self.metrics_addr.to_string()
    }

    /// The lane mesh's latched fault, if any (the drained-shutdown
    /// satellite asserts this stays `None`).
    pub fn lane_fault(&self) -> Option<String> {
        self.lanes.fault()
    }

    /// Current scrape snapshot without a socket round-trip (tests).
    pub fn metrics_text(&self) -> String {
        metrics::render(&snapshot(&self.shared))
    }

    /// Drain and stop: refuse new admissions, cancel the still-queued,
    /// signal running jobs to stop at their next step boundary, join
    /// every thread, then drop the mesh (clean lane EOFs). Returns the
    /// latched lane fault, `None` when the mesh stayed healthy.
    pub fn shutdown(mut self) -> Option<String> {
        let mut cancelled_conns: Vec<(Arc<Mutex<TcpStream>>, u32)> = Vec::new();
        {
            let mut q = self.shared.queue.lock().unwrap();
            // The flag goes up under the queue lock because try_dispatch
            // checks it under the same lock: once this scope owns the
            // lock, no dispatch can slip a job past the drain, and any
            // earlier dispatch has already pushed its JoinHandle.
            self.shared.shutdown.store(true, Ordering::SeqCst);
            q.drain();
            let dropped = q.cancel_all_queued();
            let mut jobs = self.shared.jobs.lock().unwrap();
            for id in dropped {
                if let Some(j) = jobs.get_mut(&id) {
                    j.status = JobStatus::Cancelled;
                    if let Some(c) = &j.conn {
                        cancelled_conns.push((c.clone(), id));
                    }
                }
            }
            for &id in q.running_ids() {
                if let Some(j) = jobs.get(&id) {
                    j.cancel.store(true, Ordering::SeqCst);
                }
            }
        }
        // Socket writes only after the daemon locks drop: a stalled
        // client may eat a write timeout, never wedge the queue.
        for (c, id) in cancelled_conns {
            let _ = write_frame(
                &c,
                &WireMsg::JobCancelled {
                    job: id,
                    outcome: CancelOutcome::Dequeued.to_byte(),
                },
            );
        }
        // Job threads re-dispatch on completion, so drain until the
        // handle list stays empty (dispatch early-returns once the
        // shutdown flag is up, so this converges).
        loop {
            let handles: Vec<JoinHandle<()>> = {
                let mut h = self.shared.job_threads.lock().unwrap();
                h.drain(..).collect()
            };
            if handles.is_empty() {
                break;
            }
            for h in handles {
                let _ = h.join();
            }
        }
        // No new lane clones after this; job threads (the only other
        // cloners) are joined, so the owner's channel can close.
        drop(self.shared.lanes.lock().unwrap().take());
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.metrics_thread.take() {
            let _ = h.join();
        }
        // `self.lanes` drops when this returns: joins the owner, the
        // mesh tears down with EOFs.
        self.lanes.fault()
    }
}

fn write_frame(conn: &Arc<Mutex<TcpStream>>, msg: &WireMsg) -> anyhow::Result<()> {
    let mut s = conn.lock().unwrap();
    wire::write_msg(&mut *s, msg)
}

fn accept_loop(shared: Arc<Shared>, listener: TcpListener) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                // A stalled client (full TCP send buffer) must never
                // block a write forever — progress frames come from job
                // threads and replies from conn threads, and an
                // unbounded write_all there would pin a job or a lock.
                // A timed-out write may leave that client's stream
                // desynced; the writer drops the conn, never the daemon.
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let s = shared.clone();
                // Detached on purpose: blocking framed reads have no
                // clean poll point; the process exit reaps them.
                std::thread::spawn(move || client_conn(s, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => break,
        }
    }
}

/// One control connection: Hello-gated, then a loop of framed requests.
fn client_conn(shared: Arc<Shared>, stream: TcpStream) {
    let mut reader = stream;
    let writer = match reader.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    match wire::read_msg(&mut reader) {
        Ok(WireMsg::Hello {
            purpose: Purpose::Client,
            codec,
            ..
        }) if codec >= WIRE_CODEC_VERSION => {}
        Ok(WireMsg::Hello { purpose, codec, .. }) => {
            let _ = write_frame(
                &writer,
                &WireMsg::JobRejected {
                    reason: format!(
                        "serve needs a client hello at wire codec v{WIRE_CODEC_VERSION}+, \
                         got {purpose:?} v{codec}"
                    ),
                },
            );
            return;
        }
        // Not a hello (or EOF/garbage): hang up, like the mesh
        // rendezvous does for strangers.
        _ => return,
    }
    loop {
        let msg = match wire::read_msg(&mut reader) {
            Ok(m) => m,
            Err(_) => return, // EOF or mis-framed: the conn is done
        };
        match msg {
            WireMsg::SubmitJob { spec } => handle_submit(&shared, &writer, spec),
            WireMsg::QueryStats { what } => {
                let text = render_stats(&shared, what);
                let _ = write_frame(&writer, &WireMsg::StatsReport { text });
            }
            WireMsg::CancelJob { job } => handle_cancel(&shared, &writer, job),
            other => {
                let _ = write_frame(
                    &writer,
                    &WireMsg::JobRejected {
                        reason: format!("unexpected frame on the client plane: {other:?}"),
                    },
                );
                return;
            }
        }
    }
}

fn handle_submit(shared: &Arc<Shared>, writer: &Arc<Mutex<TcpStream>>, spec: String) {
    let wl = match protocol::parse_spec(&spec) {
        Ok(wl) => wl,
        Err(e) => {
            shared.queue.lock().unwrap().note_rejected();
            let _ = write_frame(
                writer,
                &WireMsg::JobRejected {
                    reason: RejectReason::BadSpec(format!("{e:#}")).render(),
                },
            );
            return;
        }
    };
    // Admission and the state insert happen under ONE queue lock scope
    // (queue → jobs nesting, same order as shutdown/snapshot): a
    // concurrent try_dispatch serializes on the queue lock for
    // `start_next`, so it can never pop an id whose JobState is not in
    // the map yet.
    //
    // The conn's writer mutex is held across admission so JobAccepted
    // is always the job's first frame on this connection — a dispatch
    // racing from another completing job queues its progress frames
    // behind it. Ordering stays acyclic because the writer mutex is
    // only ever taken with no daemon locks held (every write_frame
    // call site) or, here, *before* them — never after.
    let mut w = writer.lock().unwrap();
    let (reply, admitted) = {
        let mut q = shared.queue.lock().unwrap();
        match q.submit() {
            Submission::Rejected(r) => (WireMsg::JobRejected { reason: r.render() }, false),
            Submission::Admitted { id, queue_pos } => {
                shared.jobs.lock().unwrap().insert(
                    id,
                    JobState {
                        spec,
                        wl,
                        status: JobStatus::Queued,
                        submitted_at: Instant::now(),
                        steps_done: 0,
                        step_seconds_sum: 0.0,
                        comm_bytes_up: 0,
                        comm_bytes_down: 0,
                        comm_time_seconds: 0.0,
                        cancel: Arc::new(AtomicBool::new(false)),
                        conn: Some(writer.clone()),
                        error: None,
                    },
                );
                (WireMsg::JobAccepted { job: id, queue_pos }, true)
            }
        }
    };
    let _ = wire::write_msg(&mut *w, &reply);
    drop(w);
    if admitted {
        try_dispatch(shared);
    }
}

fn handle_cancel(shared: &Arc<Shared>, writer: &Arc<Mutex<TcpStream>>, job: u32) {
    // The queue check and the cancel-flag store are one atomic step
    // under the queue lock: job_thread picks its terminal frame under
    // the same lock, so a Signalled ack here guarantees the submitter
    // sees exactly one JobCancelled — never JobCancelled then JobDone,
    // even when the job finishes its last step in a photo finish.
    let reply = {
        let mut q = shared.queue.lock().unwrap();
        match q.cancel(job) {
            Some(CancelOutcome::Dequeued) => {
                {
                    let mut jobs = shared.jobs.lock().unwrap();
                    if let Some(j) = jobs.get_mut(&job) {
                        j.status = JobStatus::Cancelled;
                    }
                    prune_finished_jobs(&mut jobs, shared.retention);
                }
                WireMsg::JobCancelled {
                    job,
                    outcome: CancelOutcome::Dequeued.to_byte(),
                }
            }
            Some(CancelOutcome::Signalled) => {
                if let Some(j) = shared.jobs.lock().unwrap().get(&job) {
                    j.cancel.store(true, Ordering::SeqCst);
                }
                WireMsg::JobCancelled {
                    job,
                    outcome: CancelOutcome::Signalled.to_byte(),
                }
            }
            None => WireMsg::JobRejected {
                reason: format!("cancel: job {job} is unknown or already finished"),
            },
        }
    };
    let _ = write_frame(writer, &reply);
}

/// Start every runnable job (FIFO under the concurrency cap). Called
/// after each admission and each completion; a no-op once draining.
///
/// Each iteration — shutdown check, pop, spawn, handle push — runs
/// under ONE queue lock scope. That pins down two races with
/// `shutdown()` (which sets the flag under the same lock): no dispatch
/// can start after shutdown owns the queue lock, and any dispatch that
/// won the lock first has already pushed its `JoinHandle` by the time
/// shutdown's join loop looks, so no job thread escapes the drain.
fn try_dispatch(shared: &Arc<Shared>) {
    loop {
        let mut q = shared.queue.lock().unwrap();
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Some(id) = q.start_next() else {
            return;
        };
        let Some(lanes) = shared.lanes.lock().unwrap().clone() else {
            // Only shutdown takes the lanes, and it raises the flag
            // first — unreachable, but free the slot rather than leak it.
            q.complete_cancelled(id);
            return;
        };
        let state = {
            let mut jobs = shared.jobs.lock().unwrap();
            jobs.get_mut(&id).map(|j| {
                j.status = JobStatus::Running;
                (
                    j.wl.clone(),
                    j.cancel.clone(),
                    j.conn.clone(),
                    j.submitted_at.elapsed().as_secs_f64(),
                )
            })
        };
        let Some((wl, cancel, conn, waited_s)) = state else {
            // An admitted id must have a state entry (handle_submit
            // inserts it under the queue lock); if the invariant ever
            // breaks, free the slot instead of poisoning the daemon.
            q.complete(id, false);
            continue;
        };
        {
            let mut w = shared.wait.lock().unwrap();
            w.0 += waited_s;
            w.1 += 1;
        }
        shared.sched_wait.record_secs(waited_s);
        // Retroactive span: the wait already happened (admission →
        // this dispatch), so synthesize it from its measured length.
        if obs::enabled() {
            let end = obs::now_ns();
            let start = end.saturating_sub((waited_s * 1e9) as u64);
            let mut sp = obs::Span::new(obs::Category::SchedWait, start, end);
            sp.job = id;
            obs::record_span(sp);
        }
        let _sp = obs::span(obs::Category::Dispatch).job(id);
        let s = shared.clone();
        let handle = std::thread::spawn(move || job_thread(s, id, wl, lanes, cancel, conn));
        shared.job_threads.lock().unwrap().push(handle);
    }
}

fn job_thread(
    shared: Arc<Shared>,
    id: u32,
    wl: NodeWorkload,
    lanes: LaneHandle,
    cancel: Arc<AtomicBool>,
    conn: Option<Arc<Mutex<TcpStream>>>,
) {
    let mut conn = conn;
    let hobs = JobObs {
        step_latency: Some(shared.step_latency.clone()),
        collective_wait: Some(shared.collective_wait.clone()),
    };
    let result = run_job(id, &wl, &lanes, &cancel, &hobs, |done, total, _secs| {
        if let Some(j) = shared.jobs.lock().unwrap().get_mut(&id) {
            j.steps_done = done;
        }
        // A dead or stalled client must not kill the job: the write
        // times out (set at accept), and one failure drops the conn so
        // later steps don't re-pay the timeout.
        let client_died = match &conn {
            Some(c) => write_frame(
                c,
                &WireMsg::JobProgress {
                    job: id,
                    step: done as u32,
                    total: total as u32,
                },
            )
            .is_err(),
            None => false,
        };
        if client_died {
            conn = None;
        }
    });
    // The terminal transition runs under the queue lock so it is atomic
    // against handle_cancel: an acknowledged cancel wins even over a
    // photo-finish completion, keeping the submitter's terminal frame
    // unique (one JobCancelled, no trailing JobDone).
    let frame = match result {
        Ok(report) => {
            // Rendered before the lock (it formats every step); thrown
            // away in the rare case an acknowledged cancel wins below.
            let rendered = if report.completed {
                render_digest(&report.digest)
                    .unwrap_or_else(|e| format!("error: digest render failed: {e:#}"))
            } else {
                String::new()
            };
            let mut q = shared.queue.lock().unwrap();
            let completed = report.completed && !cancel.load(Ordering::SeqCst);
            let digest = if completed { rendered } else { String::new() };
            {
                let mut jobs = shared.jobs.lock().unwrap();
                if let Some(j) = jobs.get_mut(&id) {
                    j.status = if completed {
                        JobStatus::Done
                    } else {
                        JobStatus::Cancelled
                    };
                    j.steps_done = report.digest.steps.len();
                    j.step_seconds_sum = report.step_seconds.iter().sum();
                    for s in &report.digest.steps {
                        j.comm_bytes_up += s.comm.bytes_up_per_worker as u64;
                        j.comm_bytes_down += s.comm.bytes_down_per_worker as u64;
                        j.comm_time_seconds += s.comm.time_s;
                    }
                }
                prune_finished_jobs(&mut jobs, shared.retention);
            }
            if completed {
                q.complete(id, true);
                WireMsg::JobDone { job: id, digest }
            } else {
                q.complete_cancelled(id);
                WireMsg::JobCancelled {
                    job: id,
                    outcome: CancelOutcome::Signalled.to_byte(),
                }
            }
        }
        Err(e) => {
            let cause = format!("{e:#}");
            let mut q = shared.queue.lock().unwrap();
            let cancelled = cancel.load(Ordering::SeqCst);
            {
                let mut jobs = shared.jobs.lock().unwrap();
                if let Some(j) = jobs.get_mut(&id) {
                    j.status = if cancelled {
                        JobStatus::Cancelled
                    } else {
                        JobStatus::Failed
                    };
                    j.error = Some(cause.clone());
                }
                prune_finished_jobs(&mut jobs, shared.retention);
            }
            if cancelled {
                q.complete_cancelled(id);
                WireMsg::JobCancelled {
                    job: id,
                    outcome: CancelOutcome::Signalled.to_byte(),
                }
            } else {
                q.complete(id, false);
                // Convention: a failed job's JobDone digest is "error: ...".
                WireMsg::JobDone {
                    job: id,
                    digest: format!("error: {cause}"),
                }
            }
        }
    };
    if let Some(c) = &conn {
        let _ = write_frame(c, &frame);
    }
    try_dispatch(&shared);
}

/// Drop the oldest *finished* jobs past the retention cap so the
/// per-job `/metrics` series stay bounded on a long-lived daemon.
/// Queued/running jobs never count against the cap and are never
/// pruned; ids ascend with submission order, so `BTreeMap` iteration
/// order is age order. Called at every terminal transition, under the
/// jobs lock.
fn prune_finished_jobs(jobs: &mut BTreeMap<u32, JobState>, keep: usize) {
    let finished: Vec<u32> = jobs
        .iter()
        .filter(|(_, j)| {
            matches!(
                j.status,
                JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled
            )
        })
        .map(|(&id, _)| id)
        .collect();
    for id in finished.iter().take(finished.len().saturating_sub(keep)) {
        jobs.remove(id);
    }
}

/// Assemble the `/metrics` snapshot under the daemon's locks (in the
/// queue → jobs → wait order every multi-lock path uses).
fn snapshot(shared: &Shared) -> ServeMetrics {
    let lanes = shared.lanes.lock().unwrap().clone();
    let codec = lanes
        .as_ref()
        .map(|l| l.codec_snapshot())
        .unwrap_or_default();
    let lane_faulted = lanes.as_ref().and_then(|l| l.fault()).is_some();
    let q = shared.queue.lock().unwrap();
    let jobs = shared.jobs.lock().unwrap();
    let (wait_seconds_sum, wait_count) = *shared.wait.lock().unwrap();
    let c = q.counters();
    ServeMetrics {
        queue_depth: q.depth(),
        running: q.running(),
        max_queue: q.max_queue(),
        max_concurrent: q.max_concurrent(),
        submitted: c.submitted,
        rejected: c.rejected,
        completed: c.completed,
        failed: c.failed,
        cancelled: c.cancelled,
        wait_seconds_sum,
        wait_count,
        sched_wait: shared.sched_wait.snapshot(),
        step_latency: shared.step_latency.snapshot(),
        collective_wait: shared.collective_wait.snapshot(),
        rtt: crate::comm::socket::rtt_snapshot(),
        jobs: jobs
            .iter()
            .map(|(&id, j)| JobMetrics {
                id,
                scheme: j.wl.scheme.clone(),
                state: j.status.label(),
                steps_done: j.steps_done,
                steps_total: j.wl.steps,
                step_seconds_sum: j.step_seconds_sum,
                comm_bytes_up: j.comm_bytes_up,
                comm_bytes_down: j.comm_bytes_down,
                comm_time_seconds: j.comm_time_seconds,
            })
            .collect(),
        codec,
        lane_faulted,
    }
}

/// `QueryStats` text: `what` 0 = one summary line, 1 = the job table.
fn render_stats(shared: &Arc<Shared>, what: u8) -> String {
    let m = snapshot(shared);
    if what == 0 {
        return format!(
            "serve | queued={} running={} submitted={} rejected={} completed={} \
             failed={} cancelled={} wait-mean={:.3}s lanes={}\n",
            m.queue_depth,
            m.running,
            m.submitted,
            m.rejected,
            m.completed,
            m.failed,
            m.cancelled,
            if m.wait_count > 0 {
                m.wait_seconds_sum / m.wait_count as f64
            } else {
                0.0
            },
            if m.lane_faulted { "FAULTED" } else { "healthy" },
        );
    }
    let jobs = shared.jobs.lock().unwrap();
    if jobs.is_empty() {
        return "no jobs yet\n".into();
    }
    let mut out = String::new();
    for (id, j) in jobs.iter() {
        out.push_str(&format!(
            "job={id} state={} steps={}/{} spec='{}'{}\n",
            j.status.label(),
            j.steps_done,
            j.wl.steps,
            j.spec.trim(),
            match &j.error {
                Some(e) => format!(" error='{e}'"),
                None => String::new(),
            }
        ));
    }
    out
}

fn metrics_loop(shared: Arc<Shared>, listener: TcpListener) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => metrics_conn(&shared, stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => break,
        }
    }
}

/// One scrape: read the request head, answer, close. Plain HTTP/1.0 by
/// hand — no HTTP stack in the dependency tree.
fn metrics_conn(shared: &Shared, mut stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    // Scrapes are served inline on the accept thread; a scraper that
    // stops reading must not block the whole metrics plane.
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let text = String::from_utf8_lossy(&head);
    let path = text
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("/")
        .to_string();
    let response = metrics::http_response(&path, &snapshot(shared));
    let _ = stream.write_all(response.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_overrides_are_strict() {
        // Env vars are process-global; touch them briefly, mirroring
        // socket::tests::env_heartbeat_is_strict.
        std::env::set_var(ENV_SERVE_ADDR, "127.0.0.1:7777");
        assert_eq!(env_serve_addr().unwrap().as_deref(), Some("127.0.0.1:7777"));
        std::env::set_var(ENV_SERVE_ADDR, "  ");
        assert!(env_serve_addr().is_err(), "set-but-empty must be loud");
        std::env::remove_var(ENV_SERVE_ADDR);
        assert_eq!(env_serve_addr().unwrap(), None);

        std::env::set_var(ENV_SERVE_MAX_QUEUE, "12");
        assert_eq!(env_serve_max_queue().unwrap(), Some(12));
        std::env::set_var(ENV_SERVE_MAX_QUEUE, "many");
        assert!(env_serve_max_queue().is_err(), "set-but-invalid must be loud");
        std::env::remove_var(ENV_SERVE_MAX_QUEUE);
        assert_eq!(env_serve_max_queue().unwrap(), None);
    }

    fn state(status: JobStatus) -> JobState {
        JobState {
            spec: String::new(),
            wl: NodeWorkload::default(),
            status,
            submitted_at: Instant::now(),
            steps_done: 0,
            step_seconds_sum: 0.0,
            comm_bytes_up: 0,
            comm_bytes_down: 0,
            comm_time_seconds: 0.0,
            cancel: Arc::new(AtomicBool::new(false)),
            conn: None,
            error: None,
        }
    }

    #[test]
    fn metrics_retention_prunes_oldest_finished_jobs_only() {
        let mut jobs = BTreeMap::new();
        for id in 1..=10u32 {
            jobs.insert(id, state(JobStatus::Done));
        }
        jobs.insert(11, state(JobStatus::Running));
        jobs.insert(12, state(JobStatus::Queued));
        prune_finished_jobs(&mut jobs, 3);
        let kept: Vec<u32> = jobs.keys().copied().collect();
        assert_eq!(
            kept,
            vec![8, 9, 10, 11, 12],
            "oldest finished pruned; running/queued untouched"
        );
        prune_finished_jobs(&mut jobs, 3);
        assert_eq!(jobs.len(), 5, "idempotent at the bound");
    }

    #[test]
    fn daemon_starts_scrapes_and_shuts_down_clean_with_no_jobs() {
        let cfg = ServeConfig {
            bind: "127.0.0.1:0".into(),
            metrics_bind: "127.0.0.1:0".into(),
            ..ServeConfig::default()
        };
        let d = Daemon::start(&cfg).unwrap();
        assert_ne!(d.control_addr(), d.metrics_addr());
        let text = d.metrics_text();
        assert!(text.contains("scalecom_serve_queue_depth 0"), "{text}");
        assert!(text.contains("scalecom_serve_lane_faulted 0"), "{text}");
        assert_eq!(d.shutdown(), None, "idle shutdown latches no lane fault");
    }
}
