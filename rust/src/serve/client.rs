//! The client half of the serve control plane: what `scalecom
//! submit|status|jobs|cancel` speak.
//!
//! One framed TCP connection per command, opened with a
//! `Hello { purpose: Client }` at the current wire-codec version so the
//! daemon can version-gate before anything else crosses the wire.
//! `submit --follow` then just reads the daemon's stream — acceptance,
//! per-step progress, and the terminal frame — so the CLI renders live
//! state without any polling.

use crate::comm::wire::{self, Purpose, WireMsg, WIRE_CODEC_VERSION};
use crate::runtime::socket::NodeWorkload;
use crate::serve::job::{run_steps, StepVerdict};
use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

/// How a followed submission ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Ran every step; `digest` is the rendered digest text (or
    /// `error: ...` when the job failed server-side — the daemon's
    /// documented convention).
    Done { job: u32, digest: String },
    /// Refused at admission (backpressure, drain, or a bad spec).
    Rejected(String),
    /// Cancelled while queued or mid-run.
    Cancelled { job: u32 },
}

/// A framed control connection to a serve daemon.
pub struct ClientConn {
    stream: TcpStream,
}

impl ClientConn {
    pub fn connect(addr: &str, timeout: Duration) -> anyhow::Result<ClientConn> {
        let target = std::net::ToSocketAddrs::to_socket_addrs(addr)
            .map_err(|e| anyhow::anyhow!("serve address '{addr}': {e}"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("serve address '{addr}' resolved to nothing"))?;
        let mut stream = TcpStream::connect_timeout(&target, timeout)
            .map_err(|e| anyhow::anyhow!("connect {addr}: {e} (is the daemon up?)"))?;
        stream.set_nodelay(true).ok();
        wire::write_msg(
            &mut stream,
            &WireMsg::Hello {
                rank: 0,
                purpose: Purpose::Client,
                codec: WIRE_CODEC_VERSION,
            },
        )?;
        Ok(ClientConn { stream })
    }

    /// Submit a spec. With `follow`, stream progress lines to `out` and
    /// block until the terminal frame; without it, return right after
    /// the admission reply (progress frames are the daemon's to drop
    /// when this connection closes).
    pub fn submit(
        &mut self,
        spec: &str,
        follow: bool,
        out: &mut dyn Write,
    ) -> anyhow::Result<SubmitOutcome> {
        wire::write_msg(
            &mut self.stream,
            &WireMsg::SubmitJob { spec: spec.to_string() },
        )?;
        let job = match wire::read_msg(&mut self.stream)? {
            WireMsg::JobAccepted { job, queue_pos } => {
                writeln!(out, "accepted job={job} queue-pos={queue_pos}")?;
                job
            }
            WireMsg::JobRejected { reason } => return Ok(SubmitOutcome::Rejected(reason)),
            other => anyhow::bail!("expected an admission reply, got {other:?}"),
        };
        if !follow {
            return Ok(SubmitOutcome::Done {
                job,
                digest: String::new(),
            });
        }
        loop {
            match wire::read_msg(&mut self.stream)? {
                WireMsg::JobProgress { job: j, step, total } if j == job => {
                    writeln!(out, "progress job={job} step={step}/{total}")?;
                }
                WireMsg::JobDone { job: j, digest } if j == job => {
                    return Ok(SubmitOutcome::Done { job, digest });
                }
                WireMsg::JobCancelled { job: j, .. } if j == job => {
                    return Ok(SubmitOutcome::Cancelled { job });
                }
                other => anyhow::bail!("job {job}: unexpected frame {other:?}"),
            }
        }
    }

    /// `QueryStats` round-trip: `what` 0 = summary line, 1 = job table.
    pub fn query_stats(&mut self, what: u8) -> anyhow::Result<String> {
        wire::write_msg(&mut self.stream, &WireMsg::QueryStats { what })?;
        match wire::read_msg(&mut self.stream)? {
            WireMsg::StatsReport { text } => Ok(text),
            other => anyhow::bail!("expected a stats report, got {other:?}"),
        }
    }

    /// Cancel a job; returns the outcome byte (0 = dequeued, 1 =
    /// signalled mid-run) or the daemon's refusal.
    pub fn cancel(&mut self, job: u32) -> anyhow::Result<u8> {
        wire::write_msg(&mut self.stream, &WireMsg::CancelJob { job })?;
        match wire::read_msg(&mut self.stream)? {
            WireMsg::JobCancelled { job: j, outcome } if j == job => Ok(outcome),
            WireMsg::JobRejected { reason } => anyhow::bail!("{reason}"),
            other => anyhow::bail!("expected a cancel ack, got {other:?}"),
        }
    }
}

/// Run the workload locally (no daemon) and return the rendered digest
/// — `scalecom submit --local`, and the parity reference the CI smoke
/// diffs a served digest against. Identical to a served run by
/// construction: both go through [`run_steps`].
pub fn run_local(wl: &NodeWorkload, workers: usize) -> anyhow::Result<String> {
    let digest = run_steps(
        wl,
        workers,
        |_, _, _| Ok(StepVerdict::Continue),
        |_| StepVerdict::Continue,
    )?;
    crate::runtime::socket::render_digest(&digest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::socket::{compare_digests, parse_digest, sequential_digest};

    #[test]
    fn run_local_matches_sequential_digest() {
        let wl = NodeWorkload {
            steps: 5,
            warmup: 1,
            ..NodeWorkload::default()
        };
        let text = run_local(&wl, 3).unwrap();
        let parsed = parse_digest(&text).unwrap();
        let want = sequential_digest(&wl, 3).unwrap();
        compare_digests(&parsed, &want, 0.0, 0.0).unwrap();
    }

    #[test]
    fn connect_refuses_a_dead_address_loudly() {
        // Port 1 on loopback: nothing listens there in CI.
        let err = ClientConn::connect("127.0.0.1:1", Duration::from_millis(200)).unwrap_err();
        assert!(err.to_string().contains("is the daemon up?"), "{err}");
    }
}
