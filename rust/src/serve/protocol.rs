//! The serve-plane job spec: the UTF-8 payload of a `SubmitJob` frame.
//!
//! A spec is a space-separated `key=value` list over the same knobs as
//! `scalecom node` (`scheme=scalecom dim=96 rate=8 steps=50 ...`);
//! unknown keys and malformed values are loud errors that come back as
//! a typed `JobRejected`, never a silently-defaulted run. Parsing
//! produces a [`NodeWorkload`] — the exact struct the one-shot drivers
//! run — so a served job is *definitionally* the same computation as
//! `scalecom node`/`submit --local` with the same flags, which is what
//! makes the digest-parity acceptance check meaningful.

use crate::comm::Topology;
use crate::runtime::socket::NodeWorkload;

/// Parse a `SubmitJob` spec into a validated workload. Missing keys
/// take the [`NodeWorkload::default`] values (except `step-delay-ms`,
/// which also defaults to 0).
pub fn parse_spec(spec: &str) -> anyhow::Result<NodeWorkload> {
    let mut wl = NodeWorkload::default();
    for token in spec.split_whitespace() {
        let (key, value) = token.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("spec token '{token}' is not key=value")
        })?;
        anyhow::ensure!(!value.is_empty(), "spec key '{key}' has an empty value");
        match key {
            "scheme" => wl.scheme = value.to_string(),
            "dim" => wl.dim = parse_num(key, value)?,
            "rate" => wl.rate = parse_num(key, value)?,
            "steps" => wl.steps = parse_num(key, value)?,
            "warmup" => wl.warmup = parse_num(key, value)?,
            "seed" => wl.seed = parse_num::<u64>(key, value)?,
            "beta" => {
                wl.beta = value.parse::<f32>().map_err(|_| {
                    anyhow::anyhow!("spec key 'beta' expects a number, got '{value}'")
                })?
            }
            "topology" => wl.topology = Topology::parse(value)?,
            "step-delay-ms" => wl.step_delay_ms = parse_num::<u64>(key, value)?,
            other => anyhow::bail!(
                "unknown spec key '{other}' (expected scheme|dim|rate|steps|warmup|\
                 seed|beta|topology|step-delay-ms)"
            ),
        }
    }
    wl.validate()?;
    Ok(wl)
}

/// Render a workload back into spec text; round-trips through
/// [`parse_spec`]. Every key is emitted explicitly so the spec is
/// self-describing in logs and `jobs` listings.
pub fn render_spec(wl: &NodeWorkload) -> String {
    format!(
        "scheme={} dim={} rate={} steps={} warmup={} seed={} beta={} topology={}{}",
        wl.scheme,
        wl.dim,
        wl.rate,
        wl.steps,
        wl.warmup,
        wl.seed,
        wl.beta,
        match wl.topology {
            Topology::Ring => "ring",
            Topology::ParameterServer => "ps",
        },
        if wl.step_delay_ms > 0 {
            format!(" step-delay-ms={}", wl.step_delay_ms)
        } else {
            String::new()
        }
    )
}

fn parse_num<T: std::str::FromStr>(key: &str, value: &str) -> anyhow::Result<T> {
    value
        .parse::<T>()
        .map_err(|_| anyhow::anyhow!("spec key '{key}' expects an integer, got '{value}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips_and_defaults_apply() {
        let wl = parse_spec("scheme=local-topk dim=128 rate=16 steps=7 seed=9").unwrap();
        assert_eq!(wl.scheme, "local-topk");
        assert_eq!((wl.dim, wl.rate, wl.steps, wl.seed), (128, 16, 7, 9));
        // Untouched keys keep the one-shot defaults.
        let d = NodeWorkload::default();
        assert_eq!((wl.warmup, wl.beta), (d.warmup, d.beta));
        let again = parse_spec(&render_spec(&wl)).unwrap();
        assert_eq!(render_spec(&again), render_spec(&wl));
        // The empty spec is the default workload.
        assert_eq!(render_spec(&parse_spec("").unwrap()), render_spec(&d));
    }

    #[test]
    fn bad_specs_are_loud() {
        for (spec, needle) in [
            ("dim", "not key=value"),
            ("dim=", "empty value"),
            ("dim=abc", "expects an integer"),
            ("beta=x", "expects a number"),
            ("frobnicate=1", "unknown spec key"),
            ("scheme=true-topk", "not runnable"), // NodeWorkload::validate
            ("topology=mesh", "unknown topology"),
        ] {
            let err = parse_spec(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "spec '{spec}': {err}");
        }
    }
}
