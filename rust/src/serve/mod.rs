//! `scalecom serve` — the multi-tenant training daemon.
//!
//! One process, one persistent comm-lane mesh, many jobs: clients
//! submit workload specs over the framed wire protocol
//! ([`protocol`]), a bounded FIFO queue with admission control
//! ([`queue`]) decides who waits and who is refused, a scheduler
//! multiplexes admitted jobs onto the shared lanes ([`lanes`],
//! [`job`]), and a Prometheus-style text endpoint ([`metrics`])
//! exposes the whole thing. [`storm`] replays the scheduler in
//! virtual time for `scalecom simulate --job-storm`.
//!
//! Layering: `queue`/`protocol`/`metrics`/`storm` are pure (no I/O);
//! `lanes` owns the mesh thread; `job` runs one tenant's steps;
//! `daemon` wires them to TCP; `client` is the other end of the wire.

pub mod client;
pub mod daemon;
pub mod job;
pub mod lanes;
pub mod metrics;
pub mod protocol;
pub mod queue;
pub mod storm;

pub use client::{run_local, ClientConn, SubmitOutcome};
pub use daemon::{Daemon, ServeConfig};
pub use job::{run_job, run_steps, JobObs, JobReport, StepVerdict};
pub use lanes::{LaneHandle, SharedLanes};
pub use metrics::{JobMetrics, ServeMetrics};
pub use queue::{CancelOutcome, JobQueue, QueueCounters, RejectReason, Submission};
pub use storm::{run_storm, StormConfig, StormReport};
