//! The daemon's shared comm-lane mesh: one persistent
//! [`CommLanes`](crate::comm::parallel::CommLanes) owned by a dedicated
//! thread, multiplexed across every running job.
//!
//! Concurrency model: job runners send whole collectives (one
//! [`CommJob`] per worker, all tagged with the runner's job id) through
//! an mpsc request channel; the owner thread executes them one at a
//! time (`submit` + `wait`), so collectives from different jobs
//! time-multiplex on the same lane threads and sockets instead of each
//! job paying its own mesh. The owner verifies that the result echoes
//! the submitted job tag — the socket lanes already stamp and check
//! every frame (`comm::socket`), so a tag that comes back wrong means
//! the mesh is mis-framed beyond recovery and the fault is **latched**:
//! every later request fails fast with the original cause instead of
//! touching a broken mesh.

use crate::comm::codec::CodecSnapshot;
use crate::comm::parallel::{CollectiveResult, CommJob, CommLanes, LaneTransport};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum LaneRequest {
    Collective {
        job: u32,
        jobs: Vec<CommJob>,
        reply: Sender<anyhow::Result<CollectiveResult>>,
    },
    Snapshot {
        reply: Sender<CodecSnapshot>,
    },
}

/// The owner side: holds the request channel and the owner thread.
/// Dropping it closes the channel and joins the owner (which drops the
/// mesh — clean lane shutdown, EOFs not RSTs on the socket transport).
/// Every [`LaneHandle`] clone must be dropped first or the join blocks;
/// the daemon joins its job threads before dropping this.
pub struct SharedLanes {
    req: Option<Sender<LaneRequest>>,
    owner: Option<JoinHandle<()>>,
    fault: Arc<Mutex<Option<String>>>,
    workers: usize,
}

/// A cloneable submission handle for job runner threads.
#[derive(Clone)]
pub struct LaneHandle {
    req: Sender<LaneRequest>,
    fault: Arc<Mutex<Option<String>>>,
    workers: usize,
}

impl SharedLanes {
    /// Build the mesh (fallible on the socket transport — it binds real
    /// loopback ports) and start the owner thread.
    pub fn start(
        workers: usize,
        transport: LaneTransport,
        group_size: usize,
    ) -> anyhow::Result<SharedLanes> {
        let lanes = CommLanes::with_topology(workers, transport, group_size)?;
        let fault: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let (req, rx) = channel::<LaneRequest>();
        let owner_fault = fault.clone();
        let owner = std::thread::spawn(move || {
            while let Ok(next) = rx.recv() {
                match next {
                    LaneRequest::Snapshot { reply } => {
                        let _ = reply.send(lanes.codec_snapshot());
                    }
                    LaneRequest::Collective { job, jobs, reply } => {
                        if let Some(cause) = owner_fault.lock().unwrap().clone() {
                            let _ = reply.send(Err(anyhow::anyhow!(
                                "comm lanes faulted earlier: {cause}"
                            )));
                            continue;
                        }
                        lanes.submit(jobs);
                        let out = match lanes.wait() {
                            CollectiveResult::Failed(e) => {
                                *owner_fault.lock().unwrap() = Some(e.clone());
                                Err(anyhow::anyhow!("comm lanes faulted: {e}"))
                            }
                            res @ (CollectiveResult::Reduced { .. }
                            | CollectiveResult::Gathered { .. }) => {
                                let got = match &res {
                                    CollectiveResult::Reduced { job, .. }
                                    | CollectiveResult::Gathered { job, .. } => *job,
                                    CollectiveResult::Failed(_) => unreachable!(),
                                };
                                if got == job {
                                    Ok(res)
                                } else {
                                    let cause = format!(
                                        "lane result for job {got} answered job {job}'s \
                                         collective (mesh out of sync)"
                                    );
                                    *owner_fault.lock().unwrap() = Some(cause.clone());
                                    Err(anyhow::anyhow!(cause))
                                }
                            }
                        };
                        let _ = reply.send(out);
                    }
                }
            }
        });
        Ok(SharedLanes {
            req: Some(req),
            owner: Some(owner),
            fault,
            workers,
        })
    }

    pub fn handle(&self) -> LaneHandle {
        LaneHandle {
            req: self.req.as_ref().expect("lanes alive").clone(),
            fault: self.fault.clone(),
            workers: self.workers,
        }
    }

    /// The latched fault, if any — `None` means every collective so far
    /// (and the final drain) left the mesh healthy.
    pub fn fault(&self) -> Option<String> {
        self.fault.lock().unwrap().clone()
    }
}

impl Drop for SharedLanes {
    fn drop(&mut self) {
        self.req.take(); // close the channel; the owner loop ends
        if let Some(h) = self.owner.take() {
            let _ = h.join();
        }
    }
}

impl LaneHandle {
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run one collective for `job` on the shared mesh: one tagged
    /// [`CommJob`] per worker, blocking until the mesh answers. Errors
    /// if the mesh has a latched fault or the daemon is gone.
    pub fn collective(
        &self,
        job: u32,
        jobs: Vec<CommJob>,
    ) -> anyhow::Result<CollectiveResult> {
        anyhow::ensure!(
            jobs.len() == self.workers,
            "collective needs one job per worker ({} != {})",
            jobs.len(),
            self.workers
        );
        let (reply, rx) = channel();
        self.req
            .send(LaneRequest::Collective { job, jobs, reply })
            .map_err(|_| anyhow::anyhow!("lane owner is gone (daemon shut down)"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("lane owner dropped the collective (shutdown)"))?
    }

    /// Roll up the mesh's entropy-codec counters (zeroes on the channel
    /// transport).
    pub fn codec_snapshot(&self) -> CodecSnapshot {
        let (reply, rx) = channel();
        if self.req.send(LaneRequest::Snapshot { reply }).is_err() {
            return CodecSnapshot::default();
        }
        rx.recv().unwrap_or_default()
    }

    pub fn fault(&self) -> Option<String> {
        self.fault.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::WireCodecConfig;

    fn ring_avg(job: u32, bucket: u32, inputs: &[Vec<f32>]) -> Vec<CommJob> {
        inputs
            .iter()
            .map(|g| CommJob::RingAvg {
                job,
                bucket,
                buf: g.clone(),
            })
            .collect()
    }

    #[test]
    fn two_jobs_share_one_mesh_without_crosstalk() {
        let n = 3;
        let lanes =
            SharedLanes::start(n, LaneTransport::Socket(WireCodecConfig::off()), 0).unwrap();
        let h = lanes.handle();
        // Two "tenants" hammer the same mesh concurrently with disjoint
        // values; every result must echo the right tag and the right
        // average.
        std::thread::scope(|s| {
            for (job, base) in [(1u32, 1.0f32), (2, 100.0)] {
                let h = h.clone();
                s.spawn(move || {
                    for round in 0..5u32 {
                        let inputs: Vec<Vec<f32>> =
                            (0..n).map(|w| vec![base + w as f32; 16]).collect();
                        let want = base + (n as f32 - 1.0) / 2.0;
                        match h.collective(job, ring_avg(job, round, &inputs)).unwrap() {
                            CollectiveResult::Reduced {
                                job: got,
                                bucket,
                                vals,
                            } => {
                                assert_eq!((got, bucket), (job, round));
                                for v in vals {
                                    assert!((v - want).abs() < 1e-5, "job {job}: {v} vs {want}");
                                }
                            }
                            other => panic!("job {job}: unexpected {other:?}"),
                        }
                    }
                });
            }
        });
        assert!(lanes.fault().is_none(), "no latched fault after clean runs");
        drop(h);
        drop(lanes); // clean join, mesh torn down with EOFs
    }

    #[test]
    fn wrong_arity_is_rejected_before_touching_the_mesh() {
        let lanes = SharedLanes::start(2, LaneTransport::Channel, 0).unwrap();
        let h = lanes.handle();
        let err = h.collective(1, ring_avg(1, 0, &[vec![1.0; 4]])).unwrap_err();
        assert!(err.to_string().contains("one job per worker"), "{err}");
        assert!(lanes.fault().is_none());
    }
}
