//! Job-storm mode: replay `N` synthetic submissions against the real
//! [`JobQueue`] admission/scheduling state machine in **virtual time**
//! (`scalecom simulate --job-storm N`).
//!
//! No threads and no clocks — a deterministic event loop advances a
//! virtual clock between arrivals and completions, so the backpressure
//! and fairness numbers (rejection rate under overflow, mean scheduler
//! wait, FIFO order) are exactly reproducible and fast enough for CI.
//! The queue under test is the same `serve::queue::JobQueue` the live
//! daemon schedules with; the storm differs from production only in
//! where the clock comes from.

use crate::serve::queue::{JobQueue, RejectReason, Submission};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct StormConfig {
    /// Synthetic submissions to drive.
    pub jobs: usize,
    pub max_queue: usize,
    pub max_concurrent: usize,
    /// Virtual seconds between consecutive submissions.
    pub submit_every_s: f64,
    /// Virtual seconds one job occupies its concurrency slot.
    pub job_duration_s: f64,
}

impl Default for StormConfig {
    fn default() -> Self {
        StormConfig {
            jobs: 32,
            max_queue: 8,
            max_concurrent: 2,
            submit_every_s: 0.05,
            job_duration_s: 0.4,
        }
    }
}

#[derive(Debug, Clone)]
pub struct StormReport {
    pub admitted: usize,
    pub rejected: usize,
    pub completed: usize,
    /// Completion order is submission order (FIFO held under load).
    pub fifo_preserved: bool,
    pub max_depth: usize,
    pub mean_wait_s: f64,
    pub max_wait_s: f64,
    pub makespan_s: f64,
}

impl StormReport {
    pub fn render(&self) -> String {
        format!(
            "job-storm | admitted={} rejected={} completed={} fifo={} \
             max-depth={} mean-wait={:.3}s max-wait={:.3}s makespan={:.3}s",
            self.admitted,
            self.rejected,
            self.completed,
            if self.fifo_preserved { "preserved" } else { "VIOLATED" },
            self.max_depth,
            self.mean_wait_s,
            self.max_wait_s,
            self.makespan_s
        )
    }
}

/// Run the storm. Deterministic in the config alone.
pub fn run_storm(cfg: &StormConfig) -> anyhow::Result<StormReport> {
    anyhow::ensure!(cfg.jobs >= 1, "--job-storm needs at least one job");
    anyhow::ensure!(
        cfg.submit_every_s >= 0.0 && cfg.job_duration_s > 0.0,
        "storm intervals must be positive"
    );
    let mut q = JobQueue::new(cfg.max_queue, cfg.max_concurrent);
    let mut now = 0.0f64;
    let mut next_submit = 0usize;
    let mut submitted_at: BTreeMap<u32, f64> = BTreeMap::new();
    let mut started_at: BTreeMap<u32, f64> = BTreeMap::new();
    // Running jobs as (finish_time, id), popped earliest-first.
    let mut running: Vec<(f64, u32)> = Vec::new();
    let mut completion_order: Vec<u32> = Vec::new();
    let mut waits: Vec<f64> = Vec::new();
    let mut max_depth = 0usize;
    let (mut admitted, mut rejected) = (0usize, 0usize);
    loop {
        // Dispatch everything runnable at the current instant.
        while let Some(id) = q.start_next() {
            started_at.insert(id, now);
            waits.push(now - submitted_at[&id]);
            running.push((now + cfg.job_duration_s, id));
        }
        max_depth = max_depth.max(q.depth());
        // Next event: the next arrival or the earliest completion.
        let arrival = if next_submit < cfg.jobs {
            Some(next_submit as f64 * cfg.submit_every_s)
        } else {
            None
        };
        let finish = running
            .iter()
            .map(|&(f, _)| f)
            .fold(None::<f64>, |m, f| Some(m.map_or(f, |m| m.min(f))));
        now = match (arrival, finish) {
            (None, None) => break,
            (Some(a), None) => a,
            (None, Some(f)) => f,
            (Some(a), Some(f)) => a.min(f),
        };
        // Completions first (a freed slot can admit this instant's
        // arrival), matching the live daemon's complete-then-dispatch.
        let mut i = 0;
        while i < running.len() {
            if running[i].0 <= now {
                let (_, id) = running.remove(i);
                q.complete(id, true);
                completion_order.push(id);
            } else {
                i += 1;
            }
        }
        if arrival == Some(now) && next_submit < cfg.jobs {
            next_submit += 1;
            match q.submit() {
                Submission::Admitted { id, .. } => {
                    admitted += 1;
                    submitted_at.insert(id, now);
                }
                Submission::Rejected(RejectReason::QueueFull { .. }) => rejected += 1,
                Submission::Rejected(r) => anyhow::bail!("unexpected rejection: {r:?}"),
            }
        }
    }
    let fifo_preserved = completion_order.windows(2).all(|w| w[0] < w[1]);
    let mean_wait_s = if waits.is_empty() {
        0.0
    } else {
        waits.iter().sum::<f64>() / waits.len() as f64
    };
    Ok(StormReport {
        admitted,
        rejected,
        completed: completion_order.len(),
        fifo_preserved,
        max_depth,
        mean_wait_s,
        max_wait_s: waits.iter().copied().fold(0.0, f64::max),
        makespan_s: now,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_is_deterministic_and_fifo() {
        let cfg = StormConfig::default();
        let a = run_storm(&cfg).unwrap();
        let b = run_storm(&cfg).unwrap();
        assert_eq!(a.render(), b.render(), "virtual time is deterministic");
        assert!(a.fifo_preserved);
        assert_eq!(a.admitted, a.completed, "every admitted job eventually ran");
        assert!(a.rejected > 0, "default storm overflows the queue");
        assert_eq!(a.admitted + a.rejected, cfg.jobs);
        assert!(a.max_depth <= cfg.max_queue);
    }

    #[test]
    fn slow_arrivals_never_reject() {
        let cfg = StormConfig {
            jobs: 10,
            submit_every_s: 1.0,
            job_duration_s: 0.1,
            ..StormConfig::default()
        };
        let r = run_storm(&cfg).unwrap();
        assert_eq!((r.admitted, r.rejected, r.completed), (10, 0, 10));
        assert!(r.mean_wait_s < 1e-9, "no queueing when slots are always free");
    }
}
