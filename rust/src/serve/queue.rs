//! Bounded FIFO job queue with admission control — the pure state
//! machine behind the serve daemon's scheduler.
//!
//! No clocks, no threads, no sockets: submissions either get an id and
//! a queue position or a typed [`RejectReason`], `start_next` hands out
//! runnable jobs FIFO under the concurrency cap, and every transition
//! bumps a counter the `/metrics` endpoint reports. Keeping it pure is
//! what lets the property tests drive random submit/complete/cancel
//! interleavings without any real daemon, and what the job-storm
//! simulator replays in virtual time.

use std::collections::VecDeque;

/// Why a submission was refused — typed, so clients and tests can
/// distinguish backpressure from a bad request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// The wait queue is at capacity; resubmit later.
    QueueFull { depth: usize, max: usize },
    /// The daemon is shutting down and admits nothing new.
    Draining,
    /// The job spec did not parse or validate.
    BadSpec(String),
}

impl RejectReason {
    /// The wire text of a `JobRejected` frame.
    pub fn render(&self) -> String {
        match self {
            RejectReason::QueueFull { depth, max } => {
                format!("queue full (depth {depth}/{max}) — resubmit later")
            }
            RejectReason::Draining => "daemon is draining and admits no new jobs".into(),
            RejectReason::BadSpec(e) => format!("bad job spec: {e}"),
        }
    }
}

/// Outcome of [`JobQueue::submit`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Submission {
    /// Admitted with a daemon-unique id and the 0-based wait-queue
    /// position at admission time.
    Admitted { id: u32, queue_pos: u32 },
    Rejected(RejectReason),
}

/// Outcome of [`JobQueue::cancel`], mirroring the `JobCancelled` wire
/// outcome byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// The job was still queued and is gone (wire outcome 0).
    Dequeued,
    /// The job is running; the runner has been signalled and will stop
    /// at its next step boundary (wire outcome 1).
    Signalled,
}

impl CancelOutcome {
    pub fn to_byte(self) -> u8 {
        match self {
            CancelOutcome::Dequeued => 0,
            CancelOutcome::Signalled => 1,
        }
    }
}

/// Monotonic counters over the queue's lifetime (all terminal states
/// are disjoint: completed + failed + cancelled = admitted jobs that
/// have left the system).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueCounters {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
}

/// The bounded FIFO queue + running set. Job ids start at 1: job tag 0
/// is the legacy/one-shot tag on the comm lanes (`CommJob::RingAvg {
/// job: 0, .. }` keeps byte-identical wire framing), so no served job
/// may ever use it.
#[derive(Debug)]
pub struct JobQueue {
    max_queue: usize,
    max_concurrent: usize,
    next_id: u32,
    queued: VecDeque<u32>,
    running: Vec<u32>,
    draining: bool,
    counters: QueueCounters,
}

impl JobQueue {
    pub fn new(max_queue: usize, max_concurrent: usize) -> JobQueue {
        JobQueue {
            max_queue: max_queue.max(1),
            max_concurrent: max_concurrent.max(1),
            next_id: 1,
            queued: VecDeque::new(),
            running: Vec::new(),
            draining: false,
            counters: QueueCounters::default(),
        }
    }

    /// Admit a job or reject with a reason. Admission only reserves the
    /// id and the wait-queue slot; [`JobQueue::start_next`] decides when
    /// it runs.
    pub fn submit(&mut self) -> Submission {
        if self.draining {
            self.counters.rejected += 1;
            return Submission::Rejected(RejectReason::Draining);
        }
        if self.queued.len() >= self.max_queue {
            self.counters.rejected += 1;
            return Submission::Rejected(RejectReason::QueueFull {
                depth: self.queued.len(),
                max: self.max_queue,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queued.push_back(id);
        self.counters.submitted += 1;
        Submission::Admitted {
            id,
            queue_pos: (self.queued.len() - 1) as u32,
        }
    }

    /// Book a rejection that happened before admission (a spec that did
    /// not parse), so the counters still see it.
    pub fn note_rejected(&mut self) {
        self.counters.rejected += 1;
    }

    /// Next runnable job, FIFO, respecting the concurrency cap. Returns
    /// `None` when the queue is empty or the cap is reached.
    pub fn start_next(&mut self) -> Option<u32> {
        if self.running.len() >= self.max_concurrent {
            return None;
        }
        let id = self.queued.pop_front()?;
        self.running.push(id);
        Some(id)
    }

    /// A running job finished; frees its concurrency slot.
    pub fn complete(&mut self, id: u32, ok: bool) {
        if let Some(i) = self.running.iter().position(|&r| r == id) {
            self.running.remove(i);
            if ok {
                self.counters.completed += 1;
            } else {
                self.counters.failed += 1;
            }
        }
    }

    /// A running job stopped at a cancel signal; frees its slot.
    pub fn complete_cancelled(&mut self, id: u32) {
        if let Some(i) = self.running.iter().position(|&r| r == id) {
            self.running.remove(i);
            self.counters.cancelled += 1;
        }
    }

    /// Cancel by id: a queued job is removed outright; a running job is
    /// only *signalled* (the caller flips the runner's cancel flag — the
    /// slot frees when the runner acknowledges via
    /// [`JobQueue::complete_cancelled`]). `None` = unknown/finished id.
    pub fn cancel(&mut self, id: u32) -> Option<CancelOutcome> {
        if let Some(i) = self.queued.iter().position(|&q| q == id) {
            self.queued.remove(i);
            self.counters.cancelled += 1;
            return Some(CancelOutcome::Dequeued);
        }
        if self.running.contains(&id) {
            return Some(CancelOutcome::Signalled);
        }
        None
    }

    /// Stop admitting; queued jobs stay until cancelled or started.
    pub fn drain(&mut self) {
        self.draining = true;
    }

    /// Drop every still-queued job (shutdown path); returns the ids so
    /// the daemon can mark their states cancelled.
    pub fn cancel_all_queued(&mut self) -> Vec<u32> {
        let ids: Vec<u32> = self.queued.drain(..).collect();
        self.counters.cancelled += ids.len() as u64;
        ids
    }

    pub fn depth(&self) -> usize {
        self.queued.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn running_ids(&self) -> &[u32] {
        &self.running
    }

    pub fn counters(&self) -> QueueCounters {
        self.counters
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    pub fn max_concurrent(&self) -> usize {
        self.max_concurrent
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_admission_and_dispatch() {
        let mut q = JobQueue::new(4, 2);
        let ids: Vec<u32> = (0..4)
            .map(|i| match q.submit() {
                Submission::Admitted { id, queue_pos } => {
                    assert_eq!(queue_pos, i as u32);
                    id
                }
                Submission::Rejected(r) => panic!("rejected: {r:?}"),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3, 4], "ids start at 1 (0 is the legacy lane tag)");
        // Cap of 2: exactly two start, in submission order.
        assert_eq!(q.start_next(), Some(1));
        assert_eq!(q.start_next(), Some(2));
        assert_eq!(q.start_next(), None, "concurrency cap holds");
        q.complete(1, true);
        assert_eq!(q.start_next(), Some(3), "FIFO after a slot frees");
        assert_eq!(q.depth(), 1);
        assert_eq!(q.running(), 2);
    }

    #[test]
    fn overflow_rejects_with_typed_reason() {
        let mut q = JobQueue::new(2, 1);
        assert!(matches!(q.submit(), Submission::Admitted { .. }));
        assert!(matches!(q.submit(), Submission::Admitted { .. }));
        match q.submit() {
            Submission::Rejected(RejectReason::QueueFull { depth: 2, max: 2 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert_eq!(q.counters().rejected, 1);
        // Overflow rejection admits again once a slot frees.
        assert_eq!(q.start_next(), Some(1));
        assert!(matches!(q.submit(), Submission::Admitted { id: 3, .. }));
    }

    #[test]
    fn cancel_queued_vs_running() {
        let mut q = JobQueue::new(4, 1);
        let _ = q.submit(); // 1
        let _ = q.submit(); // 2
        assert_eq!(q.start_next(), Some(1));
        assert_eq!(q.cancel(2), Some(CancelOutcome::Dequeued));
        assert_eq!(q.cancel(1), Some(CancelOutcome::Signalled));
        // Signalled does NOT free the slot until the runner acknowledges.
        assert_eq!(q.running(), 1);
        q.complete_cancelled(1);
        assert_eq!(q.running(), 0);
        assert_eq!(q.cancel(7), None, "unknown id");
        let c = q.counters();
        assert_eq!((c.cancelled, c.completed, c.failed), (2, 0, 0));
    }

    #[test]
    fn draining_rejects_everything_new() {
        let mut q = JobQueue::new(4, 1);
        let _ = q.submit();
        q.drain();
        assert!(matches!(
            q.submit(),
            Submission::Rejected(RejectReason::Draining)
        ));
        assert_eq!(q.cancel_all_queued(), vec![1]);
        assert_eq!(q.depth(), 0);
    }
}
