//! Prometheus-style text rendering for the serve daemon's `/metrics`
//! endpoint (exposition format 0.0.4, hand-rolled — no HTTP stack).
//!
//! Pure functions over a plain snapshot struct: the daemon assembles a
//! [`ServeMetrics`] under its locks and the rendering is testable
//! without a socket in sight. Counter names follow the Prometheus
//! conventions (`_total` suffix on counters, `_sum`/`_count` pairs for
//! the latency summaries, `job="N"` labels on the per-job series).

use crate::comm::codec::CodecSnapshot;
use crate::comm::RttSnapshot;
use crate::obs::HistSnapshot;

/// One job's slice of the scrape.
#[derive(Debug, Clone)]
pub struct JobMetrics {
    pub id: u32,
    pub scheme: String,
    pub state: &'static str,
    pub steps_done: usize,
    pub steps_total: usize,
    /// Sum of per-step wall seconds (with `steps_done` as the count,
    /// this is the step-latency summary).
    pub step_seconds_sum: f64,
    /// Per-job `CommStats` rollup from the step records.
    pub comm_bytes_up: u64,
    pub comm_bytes_down: u64,
    pub comm_time_seconds: f64,
}

/// Everything one `/metrics` scrape reports.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub queue_depth: usize,
    pub running: usize,
    pub max_queue: usize,
    pub max_concurrent: usize,
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Scheduler wait (admission → first step) summary (the `stats`
    /// one-liner; the scrape renders the histogram below instead).
    pub wait_seconds_sum: f64,
    pub wait_count: u64,
    /// Log-bucketed latency distributions (power-of-two second edges).
    pub sched_wait: HistSnapshot,
    pub step_latency: HistSnapshot,
    pub collective_wait: HistSnapshot,
    /// Heartbeat round-trip stats over the socket links (all zero on
    /// the channel transport or with heartbeats off).
    pub rtt: RttSnapshot,
    pub jobs: Vec<JobMetrics>,
    /// Shared-lane wire entropy-codec counters.
    pub codec: CodecSnapshot,
    /// A latched lane fault, surfaced as a gauge (0 healthy, 1 faulted).
    pub lane_faulted: bool,
}

impl Default for JobMetrics {
    fn default() -> Self {
        JobMetrics {
            id: 0,
            scheme: String::new(),
            state: "queued",
            steps_done: 0,
            steps_total: 0,
            step_seconds_sum: 0.0,
            comm_bytes_up: 0,
            comm_bytes_down: 0,
            comm_time_seconds: 0.0,
        }
    }
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Render the exposition text.
pub fn render(m: &ServeMetrics) -> String {
    let mut out = String::new();
    header(&mut out, "scalecom_serve_queue_depth", "gauge", "Jobs waiting in the FIFO queue.");
    out.push_str(&format!("scalecom_serve_queue_depth {}\n", m.queue_depth));
    header(&mut out, "scalecom_serve_running", "gauge", "Jobs currently executing on the shared lanes.");
    out.push_str(&format!("scalecom_serve_running {}\n", m.running));
    header(&mut out, "scalecom_serve_queue_capacity", "gauge", "Admission-control limits.");
    out.push_str(&format!(
        "scalecom_serve_queue_capacity{{limit=\"max_queue\"}} {}\n\
         scalecom_serve_queue_capacity{{limit=\"max_concurrent\"}} {}\n",
        m.max_queue, m.max_concurrent
    ));
    header(&mut out, "scalecom_serve_lane_faulted", "gauge", "1 when the shared comm-lane mesh has a latched fault.");
    out.push_str(&format!(
        "scalecom_serve_lane_faulted {}\n",
        u8::from(m.lane_faulted)
    ));
    for (name, v, help) in [
        ("scalecom_serve_jobs_submitted_total", m.submitted, "Jobs admitted to the queue."),
        ("scalecom_serve_jobs_rejected_total", m.rejected, "Submissions refused (backpressure, drain, bad spec)."),
        ("scalecom_serve_jobs_completed_total", m.completed, "Jobs that ran every step."),
        ("scalecom_serve_jobs_failed_total", m.failed, "Jobs that errored mid-run."),
        ("scalecom_serve_jobs_cancelled_total", m.cancelled, "Jobs cancelled while queued or running."),
    ] {
        header(&mut out, name, "counter", help);
        out.push_str(&format!("{name} {v}\n"));
    }
    header(&mut out, "scalecom_serve_scheduler_wait_seconds", "histogram", "Admission-to-first-step wait.");
    m.sched_wait.render_prometheus(&mut out, "scalecom_serve_scheduler_wait_seconds", "");
    header(&mut out, "scalecom_serve_step_latency_seconds", "histogram", "Wall seconds per served job step, all jobs pooled.");
    m.step_latency.render_prometheus(&mut out, "scalecom_serve_step_latency_seconds", "");
    header(&mut out, "scalecom_serve_collective_wait_seconds", "histogram", "Wall seconds blocked in the shared-lane collective per step.");
    m.collective_wait.render_prometheus(&mut out, "scalecom_serve_collective_wait_seconds", "");
    header(&mut out, "scalecom_heartbeat_rtt_seconds", "gauge", "Heartbeat ping-to-pong round trip over the socket links.");
    out.push_str(&format!(
        "scalecom_heartbeat_rtt_seconds{{stat=\"min\"}} {}\n\
         scalecom_heartbeat_rtt_seconds{{stat=\"mean\"}} {}\n\
         scalecom_heartbeat_rtt_seconds{{stat=\"max\"}} {}\n",
        m.rtt.min_secs(),
        m.rtt.mean_secs(),
        m.rtt.max_secs()
    ));
    header(&mut out, "scalecom_heartbeat_rtt_samples_total", "counter", "Heartbeat round trips measured.");
    out.push_str(&format!(
        "scalecom_heartbeat_rtt_samples_total {}\n",
        m.rtt.count
    ));
    if !m.jobs.is_empty() {
        header(&mut out, "scalecom_job_steps_total", "counter", "Steps completed per job.");
        for j in &m.jobs {
            out.push_str(&format!(
                "scalecom_job_steps_total{{job=\"{}\",scheme=\"{}\",state=\"{}\"}} {}\n",
                j.id, j.scheme, j.state, j.steps_done
            ));
        }
        header(&mut out, "scalecom_job_step_latency_seconds", "summary", "Per-step wall time per job.");
        for j in &m.jobs {
            out.push_str(&format!(
                "scalecom_job_step_latency_seconds_sum{{job=\"{}\"}} {}\n\
                 scalecom_job_step_latency_seconds_count{{job=\"{}\"}} {}\n",
                j.id, j.step_seconds_sum, j.id, j.steps_done
            ));
        }
        header(&mut out, "scalecom_job_comm_bytes_total", "counter", "Modeled per-worker comm bytes per job (CommStats rollup).");
        for j in &m.jobs {
            out.push_str(&format!(
                "scalecom_job_comm_bytes_total{{job=\"{}\",direction=\"up\"}} {}\n\
                 scalecom_job_comm_bytes_total{{job=\"{}\",direction=\"down\"}} {}\n",
                j.id, j.comm_bytes_up, j.id, j.comm_bytes_down
            ));
        }
        header(&mut out, "scalecom_job_comm_time_seconds_total", "counter", "Modeled collective time per job.");
        for j in &m.jobs {
            out.push_str(&format!(
                "scalecom_job_comm_time_seconds_total{{job=\"{}\"}} {}\n",
                j.id, j.comm_time_seconds
            ));
        }
    }
    header(&mut out, "scalecom_wire_codec_frames_total", "counter", "Shared-lane wire codec frames.");
    out.push_str(&format!(
        "scalecom_wire_codec_frames_total{{op=\"encode\"}} {}\n\
         scalecom_wire_codec_frames_total{{op=\"packed\"}} {}\n",
        m.codec.enc_frames(),
        m.codec.packed_frames
    ));
    header(&mut out, "scalecom_wire_codec_bytes_total", "counter", "Shared-lane wire codec byte volume.");
    out.push_str(&format!(
        "scalecom_wire_codec_bytes_total{{kind=\"raw\"}} {}\n\
         scalecom_wire_codec_bytes_total{{kind=\"wire\"}} {}\n",
        m.codec.enc_raw_bytes(),
        m.codec.enc_wire_bytes()
    ));
    out
}

/// Wrap the scrape body in a minimal HTTP/1.0 response; any path other
/// than `/metrics` gets a 404 so a stray browser sees something sane.
pub fn http_response(request_path: &str, m: &ServeMetrics) -> String {
    if request_path == "/metrics" {
        let body = render(m);
        format!(
            "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    } else {
        let body = "404 — try /metrics\n";
        format!(
            "HTTP/1.0 404 Not Found\r\nContent-Type: text/plain\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Histogram;

    fn sample() -> ServeMetrics {
        let h = Histogram::new();
        for s in [0.1, 0.1, 0.025, 0.025] {
            h.record_secs(s);
        }
        ServeMetrics {
            queue_depth: 3,
            running: 2,
            max_queue: 8,
            max_concurrent: 2,
            submitted: 7,
            rejected: 1,
            completed: 2,
            failed: 0,
            cancelled: 0,
            wait_seconds_sum: 0.25,
            wait_count: 4,
            sched_wait: h.snapshot(),
            step_latency: HistSnapshot::default(),
            collective_wait: HistSnapshot::default(),
            rtt: RttSnapshot {
                count: 3,
                min_ns: 1_000_000,
                mean_ns: 2_000_000,
                max_ns: 4_000_000,
            },
            jobs: vec![JobMetrics {
                id: 3,
                scheme: "scalecom".into(),
                state: "running",
                steps_done: 17,
                steps_total: 50,
                step_seconds_sum: 0.034,
                comm_bytes_up: 12_000,
                comm_bytes_down: 12_000,
                comm_time_seconds: 0.002,
            }],
            codec: CodecSnapshot::default(),
            lane_faulted: false,
        }
    }

    #[test]
    fn scrape_exposes_the_acceptance_series() {
        let text = render(&sample());
        for needle in [
            "scalecom_serve_queue_depth 3",
            "scalecom_serve_running 2",
            "scalecom_serve_jobs_submitted_total 7",
            "scalecom_serve_jobs_rejected_total 1",
            "scalecom_serve_scheduler_wait_seconds_sum 0.25",
            "scalecom_serve_scheduler_wait_seconds_count 4",
            // 0.025 s lands in the 2^25 ns bucket, 0.1 s in the 2^27 one.
            "scalecom_serve_scheduler_wait_seconds_bucket{le=\"0.033554432\"} 2",
            "scalecom_serve_scheduler_wait_seconds_bucket{le=\"0.134217728\"} 4",
            "scalecom_serve_scheduler_wait_seconds_bucket{le=\"+Inf\"} 4",
            "# TYPE scalecom_serve_scheduler_wait_seconds histogram",
            "scalecom_serve_step_latency_seconds_bucket{le=\"+Inf\"} 0",
            "scalecom_serve_collective_wait_seconds_count 0",
            "scalecom_heartbeat_rtt_seconds{stat=\"min\"} 0.001",
            "scalecom_heartbeat_rtt_seconds{stat=\"mean\"} 0.002",
            "scalecom_heartbeat_rtt_seconds{stat=\"max\"} 0.004",
            "scalecom_heartbeat_rtt_samples_total 3",
            "scalecom_job_steps_total{job=\"3\",scheme=\"scalecom\",state=\"running\"} 17",
            "scalecom_job_step_latency_seconds_sum{job=\"3\"} 0.034",
            "scalecom_job_comm_bytes_total{job=\"3\",direction=\"up\"} 12000",
            "scalecom_job_comm_time_seconds_total{job=\"3\"} 0.002",
            "scalecom_serve_lane_faulted 0",
            "# TYPE scalecom_serve_queue_depth gauge",
        ] {
            assert!(text.contains(needle), "missing '{needle}' in:\n{text}");
        }
    }

    #[test]
    fn http_wrapper_routes_metrics_and_404s_the_rest() {
        let m = sample();
        let ok = http_response("/metrics", &m);
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "{ok}");
        assert!(ok.contains("scalecom_serve_queue_depth 3"));
        let body = ok.split("\r\n\r\n").nth(1).unwrap();
        let declared: usize = ok
            .lines()
            .find(|l| l.starts_with("Content-Length: "))
            .and_then(|l| l.trim_start_matches("Content-Length: ").trim().parse().ok())
            .unwrap();
        assert_eq!(declared, body.len(), "Content-Length matches the body");
        let missing = http_response("/", &m);
        assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");
    }
}
