//! `scalecom` — launcher CLI for the ScaleCom (NeurIPS 2020) reproduction.
//!
//! Subcommands: train, simulate, tune, node, serve, submit, status,
//! jobs, cancel, trace, bench-trend, experiment, perf-model,
//! compress-bench, artifacts-check, list. See `cli::USAGE`.

use anyhow::Result;
use scalecom::cli::{Args, USAGE};
use scalecom::comm::{Backend, Topology};
use scalecom::config::{TomlDoc, TrainConfig};
use scalecom::experiments;
use scalecom::metrics::Table;
use scalecom::models::paper::{paper_net, ALL_PAPER_NETS};
use scalecom::models::zoo::ALL_ZOO_MODELS;
use scalecom::perfmodel::{step_time, Scheme, SystemConfig};
use scalecom::runtime::socket::{
    run_node, NodeSpec, NodeWorkload, DEFAULT_RECONNECT_ATTEMPTS,
};
use scalecom::runtime::{default_artifacts_dir, Engine, Manifest};
use scalecom::simnet::{self, ElasticSpec, SimConfig, TopologyProfile, TuneConfig, SIM_SCHEMES};
use scalecom::trainer::{LrSchedule, Trainer};
use std::time::Duration;

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    match args.subcommand.clone().as_deref() {
        Some("train") => cmd_train(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("tune") => cmd_tune(&mut args),
        Some("node") => cmd_node(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("submit") => cmd_submit(&mut args),
        Some("status") => cmd_status(&mut args),
        Some("jobs") => cmd_jobs(&mut args),
        Some("cancel") => cmd_cancel(&mut args),
        Some("trace") => cmd_trace(&mut args),
        Some("bench-trend") => cmd_bench_trend(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("perf-model") => cmd_perf_model(&mut args),
        Some("compress-bench") => cmd_compress_bench(&mut args),
        Some("artifacts-check") => cmd_artifacts_check(&mut args),
        Some("list") => cmd_list(),
        Some(other) => anyhow::bail!("unknown subcommand '{other}'\n\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_train(args: &mut Args) -> Result<()> {
    // start from file config if given, then apply flag overrides
    let mut cfg = match args.str_opt("config") {
        Some(path) => TrainConfig::from_toml(&TomlDoc::load(path.as_ref())?)?,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.str_opt("model") {
        cfg.model = m;
    }
    // artifact batch is fixed per model; keep config in sync
    if let Ok(zoo) = scalecom::models::zoo_model(&cfg.model) {
        cfg.batch_per_worker = zoo.batch_per_worker;
        cfg.compress.rate = zoo.default_rate;
    }
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.lr = args.f64_or("lr", cfg.lr)?;
    if let Some(s) = args.str_opt("scheme") {
        cfg.compress.scheme = s;
    }
    cfg.compress.rate = args.usize_or("rate", cfg.compress.rate)?;
    cfg.compress.beta = args.f64_or("beta", cfg.compress.beta as f64)? as f32;
    cfg.compress.warmup_steps =
        args.usize_or("compress-warmup", cfg.compress.warmup_steps)?;
    cfg.compress.use_flops_rule = args.flag("flops-rule");
    if let Some(t) = args.str_opt("topology") {
        cfg.fabric_topology = t;
    }
    if let Some(b) = args.str_opt("backend") {
        cfg.backend = b;
    }
    // `--bucket-bytes auto` defers to the calibrated tune sweep below
    // (after every knob the sweep depends on is final).
    let bucket_auto = match args.str_opt("bucket-bytes") {
        Some(v) if v == "auto" => true,
        Some(v) => {
            cfg.bucket_bytes = v.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("--bucket-bytes expects a byte count or 'auto', got '{v}'")
            })?;
            false
        }
        None => false,
    };
    // Hierarchical ring-of-rings (0 = flat). Flag overrides may change
    // workers and group_size independently, so re-check the tiling here
    // rather than trusting the file-load validation.
    cfg.group_size = args.usize_or("group-size", cfg.group_size)?;
    scalecom::comm::parallel::validate_group_size(cfg.workers, cfg.group_size)?;
    // Wire entropy codec: CLI flag > SCALECOM_WIRE_COMPRESSION env >
    // config file (socket backend only; inert elsewhere).
    if let Some(w) = args.str_opt("wire-compression") {
        cfg.wire_compression = w;
    } else if let Some(mode) = scalecom::comm::codec::env_wire_compression()? {
        cfg.wire_compression = mode.label().to_string();
    }
    if let Some(w) = args.str_opt("wire-compression-dense") {
        cfg.wire_compression_dense = w;
    }
    if let Some(w) = args.str_opt("wire-compression-sparse") {
        cfg.wire_compression_sparse = w;
    }
    // The socket backend wants an explicit deployment choice: loopback
    // (in-process TCP mesh) or a real multi-process ring via `node`.
    let peers = args.str_opt("peers");
    if Backend::parse(&cfg.backend)? == Backend::Socket {
        match peers.as_deref() {
            None => anyhow::bail!(
                "--backend socket needs --peers: pass --peers loopback to run the \
                 coordination step over an in-process localhost TCP mesh, or \
                 launch one process per worker with `scalecom node --role ... \
                 --bind ... --peers ...` (see the README's multi-node section)"
            ),
            Some("loopback") | Some("local") => {}
            Some(other) => anyhow::bail!(
                "`train` runs every worker in one process; --peers {other} looks \
                 like a multi-process peer list, which `scalecom node` launches \
                 (one process per peer). For in-process socket training pass \
                 --peers loopback"
            ),
        }
    } else if peers.is_some() {
        anyhow::bail!("--peers only applies to --backend socket (or `scalecom node`)");
    }
    cfg.eval_every = args.usize_or("eval-every", cfg.steps.max(4) / 4)?;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    if let Some(dir) = args.str_opt("artifacts") {
        cfg.artifacts_dir = dir;
    }
    let use_kernel = args.flag("kernel-compress");
    let lr_warmup = args.usize_or("lr-warmup", 0)?;
    let quiet = args.flag("quiet");
    let trace_out = args.str_opt("trace-out");
    args.finish()?;
    if trace_out.is_some() {
        scalecom::obs::set_enabled(true);
        scalecom::obs::mark_sync();
    }

    // `--bucket-bytes auto`: run the calibrated tune sweep with this
    // run's workers/scheme/rate (tune-grade defaults elsewhere — the
    // same sweep `scalecom tune` prints) and train with the winner,
    // exactly as if the user had copied the printed flag by hand.
    if bucket_auto {
        if cfg.compress.scheme == "none" {
            println!("bucket-bytes auto: dense exchange is monolithic — using 0");
            cfg.bucket_bytes = 0;
        } else {
            let td = TuneConfig::default();
            let tune_cfg = TuneConfig {
                workers: cfg.workers,
                scheme: cfg.compress.scheme.clone(),
                rate: cfg.compress.rate,
                seed: cfg.seed,
                ..td
            };
            let profile = TopologyProfile::resolve("uniform")?;
            let (outcome, resolved) =
                simnet::tune::auto_bucket_bytes(&tune_cfg, &profile, None)?;
            println!(
                "bucket-bytes auto → {} ({}, {:.3} ms simulated step, \
                 compute {:.3} ns/element calibrated)",
                resolved,
                outcome.best.label(),
                outcome.best.mean_step_s * 1e3,
                outcome.compute_per_elem_s * 1e9,
            );
            cfg.bucket_bytes = resolved;
        }
    }

    println!(
        "training {} | workers={} steps={} scheme={} rate={}x beta={} topo={} backend={}{}{}{}",
        cfg.model,
        cfg.workers,
        cfg.steps,
        cfg.compress.scheme,
        cfg.compress.rate,
        cfg.compress.beta,
        cfg.fabric_topology,
        cfg.backend,
        if cfg.bucket_bytes > 0 {
            format!(" bucket-bytes={}", cfg.bucket_bytes)
        } else {
            String::new()
        },
        if cfg.group_size >= 2 {
            format!(" group-size={}", cfg.group_size)
        } else {
            String::new()
        },
        if use_kernel { " [L1-kernel compression]" } else { "" }
    );
    if cfg.wire_compression != "off" {
        println!("wire compression: {}", cfg.wire_codec()?.label());
    }
    let peak = cfg.lr;
    let mut trainer = Trainer::from_config(cfg)?;
    trainer.use_kernel = use_kernel;
    if lr_warmup > 0 {
        trainer.schedule = LrSchedule::warmup_linear(peak / 8.0, peak, lr_warmup);
    }
    let log = trainer.run()?;
    if !quiet {
        let mut table = Table::new(&["step", "loss", "lr", "rate", "eval_loss", "eval_acc"]);
        let every = (log.rows.len() / 12).max(1);
        for row in log.rows.iter().step_by(every) {
            table.row(vec![
                format!("{:.0}", row[0]),
                format!("{:.4}", row[1]),
                format!("{:.4}", row[2]),
                format!("{:.0}x", row[3]),
                if row[7].is_nan() { "-".into() } else { format!("{:.4}", row[7]) },
                if row[8].is_nan() { "-".into() } else { format!("{:.1}%", row[8] * 100.0) },
            ]);
        }
        println!("{}", table.render());
    }
    let (eval_loss, eval_acc) = trainer.evaluate()?;
    println!(
        "final: train_loss={:.4} eval_loss={eval_loss:.4} eval_acc={:.1}% wall={:.1}s",
        log.tail_mean("loss", 20).unwrap_or(f64::NAN),
        eval_acc * 100.0,
        log.last("wall_s").unwrap_or(0.0),
    );
    let path = log.save_csv(std::path::Path::new("results"))?;
    println!("metrics: {}", path.display());
    if let Some(p) = &trace_out {
        scalecom::obs::chrome::export(p, "train")?;
        println!("trace written: {p}");
    }
    Ok(())
}

/// Paper-scale runs of the real coordination code under simulated
/// link timing: every scheme × worker count deterministically, with a
/// trace digest locking the timeline and a selection digest locking the
/// values to the sequential backend.
fn cmd_simulate(args: &mut Args) -> Result<()> {
    // `--job-storm N`: replay N synthetic submissions against the serve
    // scheduler in virtual time (deterministic backpressure/fairness
    // numbers; no daemon, no threads). Own flag set, so it branches
    // before the link-timing knobs are consumed.
    if let Some(jobs) = args.str_opt("job-storm") {
        let sd = scalecom::serve::StormConfig::default();
        let storm = scalecom::serve::StormConfig {
            jobs: jobs.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("--job-storm expects a job count, got '{jobs}'")
            })?,
            max_queue: args.usize_or("storm-max-queue", sd.max_queue)?,
            max_concurrent: args.usize_or("storm-max-concurrent", sd.max_concurrent)?,
            submit_every_s: args.f64_or("storm-submit-every-ms", sd.submit_every_s * 1e3)?
                * 1e-3,
            job_duration_s: args.f64_or("storm-job-ms", sd.job_duration_s * 1e3)? * 1e-3,
        };
        args.finish()?;
        let report = scalecom::serve::run_storm(&storm)?;
        println!("{}", report.render());
        anyhow::ensure!(
            report.fifo_preserved,
            "job-storm: completion order violated FIFO"
        );
        return Ok(());
    }
    let d = SimConfig::default();
    let profile = TopologyProfile::resolve(&args.str_or("profile", "uniform"))?;
    let workers = args.usize_or("workers", 64)?;
    let sweep = args.str_opt("sweep-workers");
    let scheme = args.str_or("scheme", "all");
    let base = SimConfig {
        workers,
        dim: args.usize_or("dim", 65_536)?,
        scheme: String::new(), // filled per run below
        rate: args.usize_or("rate", d.rate)?,
        steps: args.usize_or("steps", d.steps)?,
        warmup_steps: args.usize_or("compress-warmup", 0)?,
        beta: args.f64_or("beta", 1.0)? as f32,
        seed: args.usize_or("seed", d.seed as usize)? as u64,
        layers: args.usize_or("layers", d.layers)?,
        bucket_bytes: args.usize_or("bucket-bytes", 0)?,
        compute_per_elem_s: args.f64_or("compute-per-elem-ns", d.compute_per_elem_s * 1e9)?
            * 1e-9,
        overlapped: args.flag("overlapped"),
    };
    let show_trace = args.flag("trace");
    let trace_out = args.str_opt("trace-out");
    // Elastic membership: inject one fail-stop fault and charge the
    // recovery wave (detect, restart, re-rendezvous, resume, replay) in
    // virtual time. Selections stay bit-identical to the fault-free run.
    let kill_step = args.str_opt("elastic-kill-step");
    let kill_worker = args.usize_or("elastic-kill-worker", 1)?;
    let elastic_hb_ms = args.f64_or("elastic-heartbeat-ms", 100.0)?;
    let elastic_restart_ms = args.f64_or("elastic-restart-ms", 1000.0)?;
    let elastic = match kill_step {
        Some(s) => Some(ElasticSpec {
            kill_step: s.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("--elastic-kill-step expects an integer, got '{s}'")
            })?,
            kill_worker,
            heartbeat_s: elastic_hb_ms * 1e-3,
            restart_s: elastic_restart_ms * 1e-3,
        }),
        None => None,
    };
    args.finish()?;
    let schemes: Vec<String> = if scheme == "all" {
        SIM_SCHEMES.iter().map(|s| s.to_string()).collect()
    } else {
        vec![scheme]
    };
    let worker_counts: Vec<usize> = match sweep {
        None => vec![workers],
        Some(list) => {
            let mut ns = Vec::new();
            for part in list.split(',') {
                let part = part.trim();
                ns.push(part.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("--sweep-workers expects comma-separated integers, got '{part}'")
                })?);
            }
            anyhow::ensure!(!ns.is_empty(), "--sweep-workers list is empty");
            ns
        }
    };
    if trace_out.is_some() {
        anyhow::ensure!(
            schemes.len() == 1 && worker_counts.len() == 1,
            "--trace-out writes one run's trace: pass a single --scheme \
             (not 'all') and drop --sweep-workers"
        );
    }
    println!(
        "simnet | profile={} dim={} rate={}x steps={} layers={} bucket-bytes={}{}",
        profile.name,
        base.dim,
        base.rate,
        base.steps,
        base.layers,
        base.bucket_bytes,
        if base.overlapped { " overlapped" } else { "" }
    );
    if let Some(el) = &elastic {
        println!(
            "elastic | kill worker {} at step {} | heartbeat {:.0} ms restart {:.0} ms \
             (detect+rejoin+replay charged in virtual time; selections unchanged)",
            el.kill_worker,
            el.kill_step,
            el.heartbeat_s * 1e3,
            el.restart_s * 1e3
        );
    }
    let mut table = Table::new(&[
        "scheme",
        "n",
        "step ms",
        "compute ms",
        "comm ms",
        "comm frac",
        "trace digest",
        "selections",
    ]);
    for scheme in &schemes {
        for &n in &worker_counts {
            let mut cfg = base.clone();
            cfg.scheme = scheme.clone();
            cfg.workers = n;
            let r = match &elastic {
                Some(el) => simnet::simulate_elastic(&cfg, &profile, el)?,
                None => simnet::simulate(&cfg, &profile)?,
            };
            if elastic.is_some() {
                let recovery: f64 = r
                    .trace
                    .iter()
                    .filter(|e| {
                        matches!(
                            e.op,
                            "compute_aborted"
                                | "fault_detect"
                                | "worker_restart"
                                | "rendezvous"
                                | "resume_reduce"
                        )
                    })
                    .map(|e| e.end_s - e.start_s)
                    .sum();
                println!(
                    "elastic {scheme} n={n}: recovery charged {:.3} ms virtual",
                    recovery * 1e3
                );
            }
            let steps = r.steps as f64;
            let busy = r.compute_s + r.comm_s;
            table.row(vec![
                scheme.clone(),
                n.to_string(),
                format!("{:.3}", r.mean_step_s() * 1e3),
                format!("{:.3}", r.compute_s / steps * 1e3),
                format!("{:.3}", r.comm_s / steps * 1e3),
                format!("{:.1}%", if busy > 0.0 { r.comm_s / busy * 100.0 } else { 0.0 }),
                r.trace_digest(),
                r.selection_digest(),
            ]);
            if show_trace {
                print!("{}", r.trace_summary());
            }
            if let Some(p) = &trace_out {
                scalecom::obs::chrome::from_sim(&r).write(p)?;
                println!("trace written: {p}");
            }
        }
    }
    println!("{}", table.render());
    Ok(())
}

/// Bucket-plan autotuner: calibrate the compute cost from measured real
/// steps, sweep every achievable bucket plan (and the overlapped
/// driving mode) through the simulator, and print the winning
/// `--bucket-bytes`.
fn cmd_tune(args: &mut Args) -> Result<()> {
    let d = TuneConfig::default();
    let cfg = TuneConfig {
        workers: args.usize_or("workers", d.workers)?,
        dim: args.usize_or("dim", d.dim)?,
        scheme: args.str_or("scheme", &d.scheme),
        rate: args.usize_or("rate", d.rate)?,
        layers: args.usize_or("layers", d.layers)?,
        steps: args.usize_or("steps", d.steps)?,
        seed: args.usize_or("seed", d.seed as usize)? as u64,
        calibration_steps: args.usize_or("calibration-steps", d.calibration_steps)?,
    };
    let profile = TopologyProfile::resolve(&args.str_or("profile", "uniform"))?;
    let cpe_override_ns = args.str_opt("compute-per-elem-ns");
    args.finish()?;
    let calibrated = cpe_override_ns.is_none();
    let outcome = match cpe_override_ns {
        Some(v) => {
            let ns: f64 = v.parse().map_err(|_| {
                anyhow::anyhow!("--compute-per-elem-ns expects a number, got '{v}'")
            })?;
            simnet::tune::tune_with_compute(&cfg, &profile, ns * 1e-9)?
        }
        None => simnet::tune(&cfg, &profile)?,
    };
    println!(
        "tune | profile={} workers={} dim={} scheme={} rate={}x layers={} | \
         compute {:.3} ns/element ({})",
        profile.name,
        cfg.workers,
        cfg.dim,
        cfg.scheme,
        cfg.rate,
        cfg.layers,
        outcome.compute_per_elem_s * 1e9,
        if calibrated { "calibrated from real steps" } else { "given" },
    );
    // Closed-form cross-check (perfmodel::step_time_bucketed's uniform
    // shape): Tc from the calibration, Tm from the monolithic sweep
    // point, prediction max(Tc, Tm) + min(Tc, Tm)/B.
    let tc = cfg.dim as f64 * outcome.compute_per_elem_s;
    let mono = outcome
        .evals
        .iter()
        .find(|e| e.buckets == 1 && !e.overlapped)
        .map(|e| e.mean_step_s);
    let tm = mono.map(|m| (m - tc).max(0.0));
    let mut table = Table::new(&["plan", "--bucket-bytes", "step ms", "vs best", "closed form ms"]);
    for e in &outcome.evals {
        let closed = match tm {
            Some(tm) if !e.overlapped => {
                format!("{:.3}", (tc.max(tm) + tc.min(tm) / e.buckets as f64) * 1e3)
            }
            Some(tm) => format!("{:.3}", tc.max(tm) * 1e3),
            None => "-".into(),
        };
        table.row(vec![
            e.label(),
            e.bucket_bytes.to_string(),
            format!("{:.3}", e.mean_step_s * 1e3),
            format!("{:.2}x", e.mean_step_s / outcome.best.mean_step_s),
            closed,
        ]);
    }
    println!("{}", table.render());
    if outcome.best.overlapped {
        println!(
            "best: {} — keep --bucket-bytes 0 and drive steps through \
             step_overlapped (cross-step overlap wins on this profile)",
            outcome.best.label()
        );
    } else {
        println!(
            "best: {} — train with --bucket-bytes {}",
            outcome.best.label(),
            outcome.best.bucket_bytes
        );
    }
    Ok(())
}

/// One node of a multi-process socket ring: rendezvous over --peers,
/// run the deterministic synthetic coordination workload on real TCP
/// collectives, and (on the coordinator) emit the parity digest.
fn cmd_node(args: &mut Args) -> Result<()> {
    let role = args.str_opt("role");
    let bind = args.str_opt("bind");
    let peers = args.str_opt("peers");
    // One source of truth for defaults: NodeWorkload::default() (its
    // topology is Ring, matching the "ring" string fallback).
    let d = NodeWorkload::default();
    let wl = NodeWorkload {
        scheme: args.str_or("scheme", &d.scheme),
        dim: args.usize_or("dim", d.dim)?,
        rate: args.usize_or("rate", d.rate)?,
        steps: args.usize_or("steps", d.steps)?,
        warmup: args.usize_or("compress-warmup", d.warmup)?,
        seed: args.usize_or("seed", d.seed as usize)? as u64,
        beta: args.f64_or("beta", d.beta as f64)? as f32,
        topology: Topology::parse(&args.str_or("topology", "ring"))?,
        step_delay_ms: args.usize_or("step-delay-ms", d.step_delay_ms as usize)? as u64,
    };
    let timeout = Duration::from_secs(args.usize_or("timeout-secs", 30)?.max(1) as u64);
    // Same precedence as `train`: flag > SCALECOM_WIRE_COMPRESSION env >
    // default off. Every node of one ring must agree on the mode.
    let wire_mode = match args.str_opt("wire-compression") {
        Some(w) => w,
        None => scalecom::comm::codec::env_wire_compression()?
            .map(|m| m.label().to_string())
            .unwrap_or_else(|| "off".to_string()),
    };
    let wire_dense = args.str_or("wire-compression-dense", "auto");
    let wire_sparse = args.str_or("wire-compression-sparse", "auto");
    // Fault tolerance: liveness pings (0 = off) and reconnect-with-resume.
    // Same precedence as the wire codec: flag > SCALECOM_HEARTBEAT_MS env
    // > default off.
    let heartbeat_ms = match args.str_opt("heartbeat-ms") {
        Some(s) => s
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("--heartbeat-ms expects an integer, got '{s}'"))?,
        None => scalecom::runtime::socket::env_heartbeat_ms()?.unwrap_or(0),
    };
    let heartbeat = (heartbeat_ms > 0).then(|| Duration::from_millis(heartbeat_ms));
    let reconnect = args.flag("reconnect");
    let snapshot_dir = args.str_opt("snapshot-dir").map(std::path::PathBuf::from);
    let max_reconnect_attempts =
        args.usize_or("max-reconnect-attempts", DEFAULT_RECONNECT_ATTEMPTS)?;
    // Hierarchical ring-of-rings (0 = flat). Must match on every node
    // of the fleet and tile the peer count — validated at launch.
    let group_size = args.usize_or("group-size", 0)?;
    let trace_out = args.str_opt("trace-out");
    args.finish()?;
    // The sync anchor is marked inside run_node at the post-rendezvous
    // point (right after the Hello handshakes), so per-rank files merge
    // on a shared clock event.
    if trace_out.is_some() {
        scalecom::obs::set_enabled(true);
    }
    let wire_codec =
        scalecom::comm::WireCodecConfig::from_strings(&wire_mode, &wire_dense, &wire_sparse)?;
    let mut spec =
        NodeSpec::from_flags(role.as_deref(), bind.as_deref(), peers.as_deref(), timeout)?
            .with_wire_codec(wire_codec)
            .with_fault_tolerance(heartbeat, reconnect, snapshot_dir)
            .with_group_size(group_size)?;
    spec.max_reconnect_attempts = max_reconnect_attempts;
    // Graceful SIGINT/SIGTERM: every CLI-launched node votes in the
    // fleet-wide drain ballot, so the whole ring stops at the same step
    // boundary with clean EOFs instead of mid-collective RSTs.
    scalecom::util::signal::install_shutdown_handler();
    let spec = spec.with_graceful(true);
    let stdout = std::io::stdout();
    run_node(&spec, &wl, &mut stdout.lock())?;
    if let Some(p) = &trace_out {
        scalecom::obs::chrome::export(p, "node")?;
        println!("trace written: {p}");
    }
    Ok(())
}

/// Control-plane address with the serve precedence: `--addr` flag >
/// `SCALECOM_SERVE_ADDR` env > the default bind.
fn serve_addr(args: &mut Args) -> Result<String> {
    Ok(match args.str_opt("addr") {
        Some(a) => a,
        None => scalecom::serve::daemon::env_serve_addr()?
            .unwrap_or_else(|| scalecom::serve::ServeConfig::default().bind),
    })
}

/// The multi-tenant training daemon: one persistent lane mesh, a
/// bounded FIFO job queue, the framed client protocol, and the
/// Prometheus-style `/metrics` endpoint. Runs until SIGINT/SIGTERM,
/// then drains.
fn cmd_serve(args: &mut Args) -> Result<()> {
    let d = scalecom::serve::ServeConfig::default();
    // Flag > SCALECOM_SERVE_ADDR env > default, like the other knobs.
    let bind = match args.str_opt("bind") {
        Some(b) => b,
        None => scalecom::serve::daemon::env_serve_addr()?.unwrap_or(d.bind),
    };
    let metrics_bind = args.str_or("metrics-bind", &d.metrics_bind);
    let workers = args.usize_or("workers", d.workers)?;
    let group_size = args.usize_or("group-size", d.group_size)?;
    let max_queue = match args.str_opt("max-queue") {
        Some(s) => s
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--max-queue expects an integer, got '{s}'"))?,
        None => scalecom::serve::daemon::env_serve_max_queue()?.unwrap_or(d.max_queue),
    };
    let max_concurrent = args.usize_or("max-concurrent", d.max_concurrent)?;
    let metrics_job_retention =
        args.usize_or("metrics-job-retention", d.metrics_job_retention)?;
    let trace_out = args.str_opt("trace-out");
    // Lane wire codec, same precedence as `train`/`node` (socket
    // transport only; inert on channels).
    let wire_mode = match args.str_opt("wire-compression") {
        Some(w) => w,
        None => scalecom::comm::codec::env_wire_compression()?
            .map(|m| m.label().to_string())
            .unwrap_or_else(|| "off".to_string()),
    };
    let wire_dense = args.str_or("wire-compression-dense", "auto");
    let wire_sparse = args.str_or("wire-compression-sparse", "auto");
    let transport_name = args.str_or("lane-transport", "socket");
    args.finish()?;
    let codec =
        scalecom::comm::WireCodecConfig::from_strings(&wire_mode, &wire_dense, &wire_sparse)?;
    let transport = match transport_name.as_str() {
        "channel" => scalecom::comm::parallel::LaneTransport::Channel,
        "socket" => scalecom::comm::parallel::LaneTransport::Socket(codec),
        other => anyhow::bail!("--lane-transport expects channel|socket, got '{other}'"),
    };
    scalecom::util::signal::install_shutdown_handler();
    // No handshake on the serve plane — the daemon's startup instant is
    // the clock-sync anchor for its (single-process) trace.
    if trace_out.is_some() {
        scalecom::obs::set_enabled(true);
        scalecom::obs::mark_sync();
    }
    let daemon = scalecom::serve::Daemon::start(&scalecom::serve::ServeConfig {
        bind,
        metrics_bind,
        workers,
        group_size,
        transport,
        max_queue,
        max_concurrent,
        metrics_job_retention,
    })?;
    println!(
        "serve listening addr={} metrics={} workers={} transport={} \
         max-queue={} max-concurrent={}",
        daemon.control_addr(),
        daemon.metrics_addr(),
        workers,
        transport_name,
        max_queue,
        max_concurrent,
    );
    while !scalecom::util::signal::shutdown_requested() {
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("serve draining: queued jobs cancelled, running jobs stop at a step boundary");
    let fault = daemon.shutdown();
    if let Some(p) = &trace_out {
        scalecom::obs::chrome::export(p, "serve")?;
        println!("trace written: {p}");
    }
    match fault {
        None => {
            println!("serve drained cleanly");
            Ok(())
        }
        Some(fault) => anyhow::bail!("serve drained with a latched lane fault: {fault}"),
    }
}

/// Submit a job spec to a serve daemon (or run it locally with
/// `--local` — the digest-parity reference for a served run).
fn cmd_submit(args: &mut Args) -> Result<()> {
    let spec = match args.str_opt("spec") {
        Some(s) => s,
        // Bare key=value tokens double as the spec:
        //   scalecom submit scheme=scalecom steps=20
        None => args.positional.join(" "),
    };
    if args.flag("local") {
        let workers = args.usize_or("workers", 2)?;
        args.finish()?;
        let wl = scalecom::serve::protocol::parse_spec(&spec)?;
        print!("{}", scalecom::serve::run_local(&wl, workers)?);
        return Ok(());
    }
    let addr = serve_addr(args)?;
    let follow = !args.flag("no-follow");
    let timeout = Duration::from_secs(args.usize_or("timeout-secs", 10)?.max(1) as u64);
    args.finish()?;
    let mut conn = scalecom::serve::ClientConn::connect(&addr, timeout)?;
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    match conn.submit(&spec, follow, &mut out)? {
        scalecom::serve::SubmitOutcome::Done { job, digest } => {
            if !follow {
                println!("job {job} submitted (not following)");
            } else if let Some(cause) = digest.strip_prefix("error: ") {
                anyhow::bail!("job {job} failed: {cause}");
            } else {
                // The raw digest text, so a served run diffs cleanly
                // against `submit --local` / `node` output.
                print!("{digest}");
            }
            Ok(())
        }
        scalecom::serve::SubmitOutcome::Rejected(reason) => {
            anyhow::bail!("rejected: {reason}")
        }
        scalecom::serve::SubmitOutcome::Cancelled { job } => {
            anyhow::bail!("job {job} was cancelled before completing")
        }
    }
}

/// One-line daemon summary (queue depth, counters, lane health).
fn cmd_status(args: &mut Args) -> Result<()> {
    let addr = serve_addr(args)?;
    let timeout = Duration::from_secs(args.usize_or("timeout-secs", 10)?.max(1) as u64);
    args.finish()?;
    let mut conn = scalecom::serve::ClientConn::connect(&addr, timeout)?;
    print!("{}", conn.query_stats(0)?);
    Ok(())
}

/// Per-job table: state, progress, spec.
fn cmd_jobs(args: &mut Args) -> Result<()> {
    let addr = serve_addr(args)?;
    let timeout = Duration::from_secs(args.usize_or("timeout-secs", 10)?.max(1) as u64);
    args.finish()?;
    let mut conn = scalecom::serve::ClientConn::connect(&addr, timeout)?;
    print!("{}", conn.query_stats(1)?);
    Ok(())
}

/// Cancel a queued or running job by id.
fn cmd_cancel(args: &mut Args) -> Result<()> {
    let addr = serve_addr(args)?;
    let job = args
        .str_opt("job")
        .ok_or_else(|| anyhow::anyhow!("cancel needs --job <id>"))?;
    let job: u32 = job
        .parse()
        .map_err(|_| anyhow::anyhow!("--job expects an integer id, got '{job}'"))?;
    let timeout = Duration::from_secs(args.usize_or("timeout-secs", 10)?.max(1) as u64);
    args.finish()?;
    let mut conn = scalecom::serve::ClientConn::connect(&addr, timeout)?;
    match conn.cancel(job)? {
        0 => println!("job {job} cancelled (was still queued)"),
        _ => println!("job {job} signalled; it stops at its next step boundary"),
    }
    Ok(())
}

/// Offline tooling over the Chrome-trace files every runtime emits via
/// `--trace-out`: merge per-rank files on their handshake sync anchors,
/// print a per-category report, or diff a measured trace against a
/// simnet prediction.
fn cmd_trace(args: &mut Args) -> Result<()> {
    use scalecom::obs::chrome::{self, TraceFile};
    let verb = args.positional.first().cloned();
    match verb.as_deref() {
        Some("merge") => {
            let out = args.str_or("out", "trace-merged.json");
            args.finish()?;
            let inputs = &args.positional[1..];
            anyhow::ensure!(
                inputs.len() >= 2,
                "trace merge wants two or more per-rank trace files"
            );
            let files = inputs
                .iter()
                .map(|p| TraceFile::read(p))
                .collect::<Result<Vec<_>>>()?;
            let merged = chrome::merge(&files);
            merged.write(&out)?;
            println!(
                "merged {} files ({} events, {} dropped) into {out}",
                files.len(),
                merged.events.len(),
                merged.dropped
            );
            Ok(())
        }
        Some("report") => {
            args.finish()?;
            anyhow::ensure!(
                args.positional.len() == 2,
                "trace report wants exactly one trace file"
            );
            let f = TraceFile::read(&args.positional[1])?;
            print!("{}", chrome::report(&f));
            Ok(())
        }
        Some("diff") => {
            args.finish()?;
            anyhow::ensure!(
                args.positional.len() == 3,
                "trace diff wants <measured.json> <predicted.json>"
            );
            let real = TraceFile::read(&args.positional[1])?;
            let sim = TraceFile::read(&args.positional[2])?;
            print!("{}", chrome::diff(&real, &sim));
            Ok(())
        }
        _ => anyhow::bail!(
            "trace wants a verb: merge [--out F] <a.json> <b.json> ... | \
             report <f.json> | diff <measured.json> <predicted.json>"
        ),
    }
}

/// Bench-trend gate: compare a current `bench_allreduce --json` artifact
/// against a baseline and fail on median regressions past the budget.
fn cmd_bench_trend(args: &mut Args) -> Result<()> {
    let baseline = args
        .str_opt("baseline")
        .ok_or_else(|| anyhow::anyhow!("bench-trend needs --baseline <json>"))?;
    let current = args
        .str_opt("current")
        .ok_or_else(|| anyhow::anyhow!("bench-trend needs --current <json>"))?;
    let max_regress = args.f64_or("max-regress", 0.15)?;
    let prefixes = args.str_or("prefixes", "allreduce,codec/");
    args.finish()?;
    let prefixes: Vec<String> =
        prefixes.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect();
    // First run on a branch: no baseline artifact to diff against. The
    // gate skips (exit 0) instead of failing — a present-but-corrupt
    // baseline is still a hard error inside the helper.
    let report = match scalecom::bench::trend::compare_files_with_optional_baseline(
        std::path::Path::new(&baseline),
        std::path::Path::new(&current),
        &prefixes,
        max_regress,
    )? {
        Some(report) => report,
        None => {
            println!("bench-trend: no baseline — gate skipped ({baseline} is missing or empty)");
            return Ok(());
        }
    };
    print!("{}", report.render());
    anyhow::ensure!(
        report.regressions.is_empty(),
        "bench-trend: {} benchmark(s) regressed more than {:.0}% vs baseline",
        report.regressions.len(),
        max_regress * 100.0
    );
    println!(
        "bench-trend OK: {} benchmark(s) compared, none regressed more than {:.0}%",
        report.compared.len(),
        max_regress * 100.0
    );
    Ok(())
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    let quick = args.flag("quick");
    args.finish()?;
    let id = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: scalecom experiment <id> [--quick]"))?;
    experiments::run(&id, quick)
}

fn cmd_perf_model(args: &mut Args) -> Result<()> {
    let net_name = args.str_or("net", "resnet50");
    let sys = SystemConfig {
        workers: args.usize_or("workers", 64)?,
        peak_tflops: args.f64_or("tflops", 100.0)?,
        compute_efficiency: args.f64_or("efficiency", 0.2)?,
        bandwidth_gbps: args.f64_or("bandwidth", 32.0)?,
        minibatch_per_worker: args.usize_or("batch", 8)?,
        compression: args.f64_or("compression", 112.0)?,
        overlap: args.f64_or("overlap", 0.0)?,
    };
    args.finish()?;
    let net = paper_net(&net_name)?;
    println!(
        "{} | {:.1}M params, {:.2} GFLOPs fwd/sample | {} workers, {} mb/worker, {} GBps",
        net.name,
        net.total_params() as f64 / 1e6,
        net.total_fwd_flops() / 1e9,
        sys.workers,
        sys.minibatch_per_worker,
        sys.bandwidth_gbps
    );
    let mut table = Table::new(&[
        "scheme", "compute ms", "up ms", "down ms", "index ms", "total ms", "comm frac", "speedup",
    ]);
    let base = step_time(&net, &sys, Scheme::None).total_s;
    for scheme in [Scheme::None, Scheme::LocalTopK, Scheme::ScaleCom] {
        let t = step_time(&net, &sys, scheme);
        table.row(vec![
            t.scheme.label().to_string(),
            format!("{:.3}", t.compute_s * 1e3),
            format!("{:.3}", t.grad_up_s * 1e3),
            format!("{:.3}", t.grad_down_s * 1e3),
            format!("{:.3}", t.index_s * 1e3),
            format!("{:.3}", t.total_s * 1e3),
            format!("{:.1}%", t.comm_fraction() * 100.0),
            format!("{:.2}x", base / t.total_s),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_compress_bench(args: &mut Args) -> Result<()> {
    let quick = args.flag("quick");
    args.finish()?;
    experiments::table1::run(quick)
}

fn cmd_artifacts_check(args: &mut Args) -> Result<()> {
    let dir = args
        .str_opt("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    args.finish()?;
    let manifest = Manifest::load(&dir)?;
    println!("manifest: {} ({} models)", dir.display(), manifest.models.len());
    let engine = Engine::cpu()?;
    println!("pjrt platform: {}", engine.platform());
    let mut table = Table::new(&["model", "dim", "k", "batch", "smoke loss"]);
    for name in manifest.models.keys() {
        let model = engine.load_model(&manifest, name)?;
        let params = model.load_init_params()?;
        let zoo = scalecom::models::zoo_model(name)?;
        let ds = zoo.dataset(0);
        let batch = ds.batch(0, 1, 0, model.mm.batch);
        let (loss, grads) = model.train_step(&params, &batch)?;
        anyhow::ensure!(grads.len() == model.mm.dim);
        table.row(vec![
            name.clone(),
            model.mm.dim.to_string(),
            model.mm.k.to_string(),
            model.mm.batch.to_string(),
            format!("{loss:.4}"),
        ]);
    }
    println!("{}", table.render());
    println!("artifacts OK");
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("trainable models (artifact-backed):");
    for m in ALL_ZOO_MODELS {
        println!(
            "  {:<16} {:<44} batch/worker={} default rate={}x",
            m.name, m.stands_in_for, m.batch_per_worker, m.default_rate
        );
    }
    println!("\ncompression schemes:");
    for s in [
        "scalecom (CLT-k, chunked quasi-sort)",
        "scalecom-exact (CLT-k, exact top-k)",
        "local-topk / local-topk-chunk",
        "true-topk (oracle)",
        "random-k",
        "gtop-k",
        "sketch-k",
        "none (dense baseline)",
    ] {
        println!("  {s}");
    }
    println!("\npaper networks (perf model):");
    for n in ALL_PAPER_NETS {
        let net = paper_net(n)?;
        println!(
            "  {:<12} {:>6.1}M params  {:>6.2} GFLOPs fwd/sample",
            n,
            net.total_params() as f64 / 1e6,
            net.total_fwd_flops() / 1e9
        );
    }
    println!("\nexperiments:");
    for (id, desc) in experiments::list() {
        println!("  {id:<8} {desc}");
    }
    Ok(())
}
