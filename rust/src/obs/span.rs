//! Lock-free, per-thread ring-buffer span recorder.
//!
//! The recorder is built so the *disabled* path costs one relaxed
//! atomic load and the *enabled* path never blocks: every thread owns a
//! single-producer/single-consumer ring of fixed capacity, pushes are a
//! bounds check plus one release store, and overflow drops the new span
//! and bumps a counter instead of waiting for the drain side. The only
//! lock in the module guards the registry of rings, taken at thread
//! registration and at drain time (export) — never on a hot path.
//!
//! Time is a process-local monotonic clock: nanoseconds since the first
//! call to [`now_ns`] in this process. Cross-process alignment happens
//! at export time via the sync anchor ([`mark_sync`] is called when the
//! Hello handshake / mesh formation completes, and `trace merge`
//! rebases every file so the anchors coincide).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Spans buffered per thread before drop-and-count kicks in. At ~32
/// bytes a span this bounds recorder memory at 512 KiB per thread.
pub const RING_CAPACITY: usize = 1 << 14;

/// What a span measures. The split into `compute`/`comm`/`sched` kinds
/// (see [`Category::kind`]) is what the overlap-efficiency report and
/// the simnet diff aggregate over.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Category {
    /// Error-feedback memory update (`begin_step` / EF accumulate).
    EfUpdate,
    /// CLT-k / top-k index selection.
    Select,
    /// Sparse-value gather / sparsify into wire form.
    Encode,
    /// `CommLanes::submit` (handing jobs to the lane threads).
    LaneSubmit,
    /// `CommLanes::wait` / coordinator waiting on a collective result.
    LaneWait,
    /// Sender-side queue wait (writer thread idle on the send queue).
    QueueWait,
    /// Socket write of an encoded frame.
    WireWrite,
    /// Socket read of a frame body.
    WireRead,
    /// Wire-codec frame encode.
    CodecEncode,
    /// Wire-codec frame decode.
    CodecDecode,
    /// Serve scheduler: admission-to-dispatch wait.
    SchedWait,
    /// Serve scheduler: dispatch bookkeeping + job-thread spawn.
    Dispatch,
    /// One step of a served job.
    JobStep,
    /// A whole collective exchange (submit-to-reduced).
    Collective,
}

impl Category {
    pub const ALL: [Category; 14] = [
        Category::EfUpdate,
        Category::Select,
        Category::Encode,
        Category::LaneSubmit,
        Category::LaneWait,
        Category::QueueWait,
        Category::WireWrite,
        Category::WireRead,
        Category::CodecEncode,
        Category::CodecDecode,
        Category::SchedWait,
        Category::Dispatch,
        Category::JobStep,
        Category::Collective,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Category::EfUpdate => "ef-update",
            Category::Select => "select",
            Category::Encode => "encode",
            Category::LaneSubmit => "lane-submit",
            Category::LaneWait => "lane-wait",
            Category::QueueWait => "queue-wait",
            Category::WireWrite => "wire-write",
            Category::WireRead => "wire-read",
            Category::CodecEncode => "codec-encode",
            Category::CodecDecode => "codec-decode",
            Category::SchedWait => "sched-wait",
            Category::Dispatch => "dispatch",
            Category::JobStep => "job-step",
            Category::Collective => "collective",
        }
    }

    /// Aggregation kind: `compute` (CPU work), `comm` (waiting on or
    /// moving bytes), `sched` (serve-plane bookkeeping).
    pub fn kind(self) -> &'static str {
        match self {
            Category::EfUpdate
            | Category::Select
            | Category::Encode
            | Category::CodecEncode
            | Category::CodecDecode => "compute",
            Category::LaneSubmit
            | Category::LaneWait
            | Category::QueueWait
            | Category::WireWrite
            | Category::WireRead
            | Category::Collective => "comm",
            Category::SchedWait | Category::Dispatch | Category::JobStep => "sched",
        }
    }

    pub fn parse(s: &str) -> Option<Category> {
        Category::ALL.into_iter().find(|c| c.label() == s)
    }
}

/// One recorded interval. `start_ns`/`end_ns` are [`now_ns`] readings;
/// the tag fields default to 0 when a site has nothing to say.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub cat: Category,
    pub start_ns: u64,
    pub end_ns: u64,
    pub step: u32,
    pub bucket: u32,
    pub job: u32,
    pub level: u8,
}

impl Span {
    pub fn new(cat: Category, start_ns: u64, end_ns: u64) -> Span {
        Span {
            cat,
            start_ns,
            end_ns,
            step: 0,
            bucket: 0,
            job: 0,
            level: 0,
        }
    }
}

fn anchor() -> &'static Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now)
}

/// Monotonic nanoseconds since this process's first reading.
#[inline]
pub fn now_ns() -> u64 {
    anchor().elapsed().as_nanos() as u64
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RANK: AtomicU32 = AtomicU32::new(0);
static SYNC_NS: AtomicU64 = AtomicU64::new(0);

/// Turn recording on or off. Off is the default and costs one relaxed
/// load per instrumentation site.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Process-global rank tag stamped into exported traces (`pid` in the
/// Chrome schema).
pub fn set_rank(rank: u32) {
    RANK.store(rank, Ordering::Relaxed);
}

pub fn rank() -> u32 {
    RANK.load(Ordering::Relaxed)
}

/// Record "now" as this process's clock-sync anchor. Called when the
/// Hello handshake / mesh formation completes, which every rank reaches
/// at (wall-clock) nearly the same instant — `trace merge` rebases all
/// files so these anchors coincide.
pub fn mark_sync() {
    SYNC_NS.store(now_ns(), Ordering::Relaxed);
}

pub fn sync_ns() -> u64 {
    SYNC_NS.load(Ordering::Relaxed)
}

/// One thread's SPSC span ring. The owning thread is the only producer
/// (`push`); the drain side is the only consumer and is serialized by
/// the registry lock. Cursors are monotonic; `tail - head` is the fill.
pub(crate) struct ThreadRing {
    tid: u32,
    slots: Box<[UnsafeCell<MaybeUninit<Span>>]>,
    /// Consumer cursor (next slot to read).
    head: AtomicUsize,
    /// Producer cursor (next slot to write).
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// The per-slot UnsafeCells are only written by the producer thread for
// slots in [tail, head + cap) and only read by the consumer for slots
// in [head, tail); the Acquire/Release pairs on the cursors order the
// slot accesses.
unsafe impl Sync for ThreadRing {}
unsafe impl Send for ThreadRing {}

impl ThreadRing {
    pub(crate) fn new(tid: u32, capacity: usize) -> ThreadRing {
        assert!(capacity > 0);
        let slots = (0..capacity)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        ThreadRing {
            tid,
            slots,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Producer side: never blocks; a full ring drops the new span.
    pub(crate) fn push(&self, s: Span) {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail - head == self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let idx = tail % self.slots.len();
        unsafe { (*self.slots[idx].get()).write(s) };
        self.tail.store(tail + 1, Ordering::Release);
    }

    /// Consumer side (one caller at a time — the registry lock).
    pub(crate) fn drain_into(&self, out: &mut Vec<(u32, Span)>) {
        let tail = self.tail.load(Ordering::Acquire);
        let mut head = self.head.load(Ordering::Relaxed);
        while head < tail {
            let idx = head % self.slots.len();
            let s = unsafe { (*self.slots[idx].get()).assume_init_read() };
            out.push((self.tid, s));
            head += 1;
        }
        self.head.store(head, Ordering::Release);
    }

    pub(crate) fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

struct Registry {
    rings: Mutex<Vec<Arc<ThreadRing>>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        rings: Mutex::new(Vec::new()),
    })
}

static NEXT_TID: AtomicU32 = AtomicU32::new(1);

thread_local! {
    static LOCAL: Arc<ThreadRing> = {
        let ring = Arc::new(ThreadRing::new(
            NEXT_TID.fetch_add(1, Ordering::Relaxed),
            RING_CAPACITY,
        ));
        registry().rings.lock().unwrap().push(ring.clone());
        ring
    };
}

/// Record a fully built span (used for retroactive intervals, e.g. the
/// scheduler wait measured from a stored admission instant). A no-op
/// when recording is disabled.
pub fn record_span(s: Span) {
    if !enabled() {
        return;
    }
    // try_with: a span dropped during thread teardown is discarded
    // rather than panicking in a destructor.
    let _ = LOCAL.try_with(|ring| ring.push(s));
}

/// Everything drained from every thread ring: `(tid, span)` in
/// per-thread record order, plus the cumulative overflow-drop count.
pub struct Drained {
    pub spans: Vec<(u32, Span)>,
    pub dropped: u64,
}

/// Destructively drain every registered ring (export side).
pub fn drain_all() -> Drained {
    let rings = registry().rings.lock().unwrap();
    let mut spans = Vec::new();
    let mut dropped = 0;
    for ring in rings.iter() {
        ring.drain_into(&mut spans);
        dropped += ring.dropped();
    }
    Drained { spans, dropped }
}

/// RAII span: created (un-armed and clock-free when recording is off)
/// at the start of a phase, records on drop.
pub struct SpanGuard {
    cat: Category,
    start_ns: u64,
    step: u32,
    bucket: u32,
    job: u32,
    level: u8,
    armed: bool,
}

/// Open a span for `cat`. When recording is disabled this is one
/// relaxed load — no clock read, nothing recorded on drop.
#[inline]
pub fn span(cat: Category) -> SpanGuard {
    if !enabled() {
        return SpanGuard {
            cat,
            start_ns: 0,
            step: 0,
            bucket: 0,
            job: 0,
            level: 0,
            armed: false,
        };
    }
    SpanGuard {
        cat,
        start_ns: now_ns(),
        step: 0,
        bucket: 0,
        job: 0,
        level: 0,
        armed: true,
    }
}

impl SpanGuard {
    pub fn step(mut self, t: u32) -> SpanGuard {
        self.step = t;
        self
    }

    pub fn bucket(mut self, b: u32) -> SpanGuard {
        self.bucket = b;
        self
    }

    pub fn job(mut self, j: u32) -> SpanGuard {
        self.job = j;
        self
    }

    pub fn level(mut self, l: u8) -> SpanGuard {
        self.level = l;
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        record_span(Span {
            cat: self.cat,
            start_ns: self.start_ns,
            end_ns: now_ns(),
            step: self.step,
            bucket: self.bucket,
            job: self.job,
            level: self.level,
        });
    }
}

/// Serializes tests that toggle the process-global `ENABLED` flag or
/// drain the registry (the unit tests here and the recorder proptests
/// in [`crate::proptest`]): cargo runs tests on parallel threads, and
/// two tests racing on the flag would see each other's spans.
#[cfg(test)]
pub(crate) fn test_recorder_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(cat: Category, start: u64, end: u64) -> Span {
        Span::new(cat, start, end)
    }

    #[test]
    fn ring_fifo_below_capacity() {
        let ring = ThreadRing::new(7, 8);
        for i in 0..5 {
            ring.push(mk(Category::Select, i, i + 1));
        }
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 5);
        assert_eq!(ring.dropped(), 0);
        for (i, (tid, s)) in out.iter().enumerate() {
            assert_eq!(*tid, 7);
            assert_eq!(s.start_ns, i as u64);
        }
        // Drained slots are reusable.
        ring.push(mk(Category::Encode, 9, 10));
        out.clear();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.cat, Category::Encode);
    }

    #[test]
    fn ring_drops_exactly_past_capacity() {
        let ring = ThreadRing::new(1, 4);
        for i in 0..10 {
            ring.push(mk(Category::Select, i, i + 1));
        }
        assert_eq!(ring.dropped(), 6, "capacity 4, 10 pushes: 6 dropped");
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 4);
        // Drop-newest: the survivors are the FIRST four pushes.
        let starts: Vec<u64> = out.iter().map(|(_, s)| s.start_ns).collect();
        assert_eq!(starts, vec![0, 1, 2, 3]);
    }

    #[test]
    fn disabled_guard_records_nothing() {
        let _lock = test_recorder_lock();
        set_enabled(false);
        let g = span(Category::Collective).step(3);
        assert!(!g.armed);
        drop(g);
        // No panic, nothing armed; behavior of the global drain is
        // covered by the proptests (which serialize on a shared lock).
    }

    #[test]
    fn category_labels_roundtrip() {
        for c in Category::ALL {
            assert_eq!(Category::parse(c.label()), Some(c));
            assert!(matches!(c.kind(), "compute" | "comm" | "sched"));
        }
        assert_eq!(Category::parse("nope"), None);
    }
}
