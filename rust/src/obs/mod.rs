//! Observability: the tracing spine for **real** runs.
//!
//! Three pieces:
//!  - [`span`]: a lock-free, per-thread ring-buffer span recorder.
//!    Instrumentation sites call [`span::span`] (RAII guard) or
//!    [`span::record_span`]; with recording off (the default) every
//!    site costs one relaxed atomic load and records nothing.
//!  - [`chrome`]: Chrome-trace/Perfetto JSON export (`--trace-out` on
//!    `train`, `node`, `serve`, `simulate`) plus the
//!    `scalecom trace merge|report|diff` operations — simnet emits the
//!    same event schema, so predicted and measured timelines diff
//!    phase by phase.
//!  - [`hist`]: power-of-two-bucketed latency histograms backing the
//!    serve `/metrics` endpoint and the bench distribution section.
//!
//! Overhead contract: tracing off is a no-op (benched by
//! `bench_allreduce obs/*`), tracing on stays within a few percent of
//! step time — recording never blocks, never allocates, and drops
//! spans (counted) instead of waiting when a ring fills.

pub mod chrome;
pub mod hist;
pub mod span;

pub use hist::{HistSnapshot, Histogram};
pub use span::{
    enabled, mark_sync, now_ns, rank, record_span, set_enabled, set_rank, span, sync_ns,
    Category, Span, SpanGuard,
};
