//! Chrome-trace/Perfetto JSON export for recorded spans, plus the
//! `scalecom trace merge|report|diff` operations over those files.
//!
//! One process writes one file (`--trace-out`): the standard
//! `{"traceEvents": [...]}` object with complete (`"ph":"X"`) events —
//! `ts`/`dur` in microseconds, `pid` = rank, `tid` = recorder thread —
//! plus a `metadata` object carrying the rank, role, clock-sync anchor
//! ([`crate::obs::span::mark_sync`], recorded when the Hello handshake
//! completes) and the overflow-drop count. `chrome://tracing` and
//! Perfetto open the files directly.
//!
//! `merge` aligns per-rank files by rebasing every file so the sync
//! anchors coincide (ranks reach mesh formation at nearly the same
//! wall-clock instant). `report` prints per-category totals and the
//! comm/compute overlap efficiency. `diff` compares a real trace
//! against a `simulate --trace-out` file phase by phase — simnet
//! events are converted through [`from_sim`] into the same schema.

use crate::json::{obj, Json};
use crate::obs::span::{self, Category};
use crate::simnet::{SimReport, TraceEvent};
use std::collections::BTreeMap;

/// One complete event, schema-equal between real runs and simnet.
#[derive(Debug, Clone, PartialEq)]
pub struct ChromeEvent {
    /// Phase name: a [`Category::label`] for real runs, the simnet op
    /// string for simulated ones.
    pub name: String,
    /// Aggregation kind: `compute` | `comm` | `sched`.
    pub cat: String,
    pub ts_us: f64,
    pub dur_us: f64,
    pub pid: u32,
    pub tid: u32,
    /// Numeric tags (step/bucket/job/level, simnet adds bytes).
    pub args: BTreeMap<String, f64>,
}

/// A parsed/authored trace file.
#[derive(Debug, Clone, Default)]
pub struct TraceFile {
    pub events: Vec<ChromeEvent>,
    pub rank: u32,
    pub role: String,
    /// Clock-sync anchor in trace-local nanoseconds.
    pub sync_ns: u64,
    pub dropped: u64,
}

fn event_json(e: &ChromeEvent) -> Json {
    let args = Json::Obj(
        e.args
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect(),
    );
    obj(vec![
        ("name", Json::from(e.name.as_str())),
        ("cat", Json::from(e.cat.as_str())),
        ("ph", Json::from("X")),
        ("ts", Json::Num(e.ts_us)),
        ("dur", Json::Num(e.dur_us)),
        ("pid", Json::Num(e.pid as f64)),
        ("tid", Json::Num(e.tid as f64)),
        ("args", args),
    ])
}

impl TraceFile {
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "traceEvents",
                Json::Arr(self.events.iter().map(event_json).collect()),
            ),
            (
                "metadata",
                obj(vec![
                    ("rank", Json::Num(self.rank as f64)),
                    ("role", Json::from(self.role.as_str())),
                    ("sync_ns", Json::Num(self.sync_ns as f64)),
                    ("dropped", Json::Num(self.dropped as f64)),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<TraceFile> {
        let events_json = v
            .req("traceEvents")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("traceEvents is not an array"))?;
        let mut events = Vec::with_capacity(events_json.len());
        for (i, e) in events_json.iter().enumerate() {
            let num = |key: &str| -> anyhow::Result<f64> {
                e.req(key)?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("event {i}: '{key}' is not a number"))
            };
            let args = match e.get("args").and_then(|a| a.as_obj()) {
                Some(m) => m
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n)))
                    .collect(),
                None => BTreeMap::new(),
            };
            events.push(ChromeEvent {
                name: e
                    .req("name")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("event {i}: 'name' is not a string"))?
                    .to_string(),
                cat: e
                    .get("cat")
                    .and_then(|c| c.as_str())
                    .unwrap_or("compute")
                    .to_string(),
                ts_us: num("ts")?,
                dur_us: num("dur")?,
                pid: num("pid")? as u32,
                tid: num("tid")? as u32,
                args,
            });
        }
        let meta = v.get("metadata");
        let meta_num = |key: &str| -> f64 {
            meta.and_then(|m| m.get(key)).and_then(|x| x.as_f64()).unwrap_or(0.0)
        };
        Ok(TraceFile {
            events,
            rank: meta_num("rank") as u32,
            role: meta
                .and_then(|m| m.get("role"))
                .and_then(|r| r.as_str())
                .unwrap_or("")
                .to_string(),
            sync_ns: meta_num("sync_ns") as u64,
            dropped: meta_num("dropped") as u64,
        })
    }

    pub fn write(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("write trace {path}: {e}"))
    }

    pub fn read(path: &str) -> anyhow::Result<TraceFile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read trace {path}: {e}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse trace {path}: {e}"))?;
        TraceFile::from_json(&v)
    }
}

/// Drain the global recorder into a [`TraceFile`] stamped with this
/// process's rank/role/sync anchor.
pub fn drain_to_file(role: &str) -> TraceFile {
    let drained = span::drain_all();
    let rank = span::rank();
    let mut events = Vec::with_capacity(drained.spans.len());
    for (tid, s) in drained.spans {
        let mut args = BTreeMap::new();
        args.insert("step".to_string(), s.step as f64);
        args.insert("bucket".to_string(), s.bucket as f64);
        args.insert("job".to_string(), s.job as f64);
        args.insert("level".to_string(), s.level as f64);
        events.push(ChromeEvent {
            name: s.cat.label().to_string(),
            cat: s.cat.kind().to_string(),
            ts_us: s.start_ns as f64 / 1000.0,
            dur_us: s.end_ns.saturating_sub(s.start_ns) as f64 / 1000.0,
            pid: rank,
            tid,
            args,
        });
    }
    events.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    TraceFile {
        events,
        rank,
        role: role.to_string(),
        sync_ns: span::sync_ns(),
        dropped: drained.dropped,
    }
}

/// Drain the recorder and write one process's trace file.
pub fn export(path: &str, role: &str) -> anyhow::Result<()> {
    drain_to_file(role).write(path)
}

/// Convert a simnet report into the shared schema: virtual seconds
/// become microseconds, `pid` 0, `tid` 0, and the op string is the
/// event name. Purely a projection of `report.trace` — the trace
/// digest hashes the original events and is untouched.
pub fn from_sim(report: &SimReport) -> TraceFile {
    let events = report.trace.iter().map(sim_event).collect::<Vec<_>>();
    TraceFile {
        events,
        rank: 0,
        role: format!("simulate:{}", report.scheme),
        sync_ns: 0,
        dropped: 0,
    }
}

fn sim_event(e: &TraceEvent) -> ChromeEvent {
    let mut args = BTreeMap::new();
    args.insert("step".to_string(), e.step as f64);
    args.insert("bucket".to_string(), e.bucket as f64);
    args.insert("bytes".to_string(), e.bytes as f64);
    ChromeEvent {
        name: e.op.to_string(),
        cat: sim_kind(e.op).to_string(),
        ts_us: e.start_s * 1e6,
        dur_us: (e.end_s - e.start_s).max(0.0) * 1e6,
        pid: 0,
        tid: 0,
        args,
    }
}

/// Simnet ops that model CPU work; everything else is on-the-wire.
fn sim_kind(op: &str) -> &'static str {
    if op.starts_with("compute") {
        "compute"
    } else {
        "comm"
    }
}

/// Merge per-rank files into one timeline: every file is rebased so
/// its sync anchor lands at the same merged-time instant (the maximum
/// anchor across files, so no event goes negative for files that
/// started recording at their anchor), and `pid` is forced to the
/// file's rank so Perfetto shows one track group per rank.
pub fn merge(files: &[TraceFile]) -> TraceFile {
    let base_us = files
        .iter()
        .map(|f| f.sync_ns as f64 / 1000.0)
        .fold(0.0f64, f64::max);
    let mut events = Vec::new();
    let mut dropped = 0;
    for f in files {
        let shift = base_us - f.sync_ns as f64 / 1000.0;
        for e in &f.events {
            let mut e = e.clone();
            e.ts_us += shift;
            e.pid = f.rank;
            events.push(e);
        }
        dropped += f.dropped;
    }
    events.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    TraceFile {
        events,
        rank: 0,
        role: "merged".to_string(),
        sync_ns: (base_us * 1000.0) as u64,
        dropped,
    }
}

struct PhaseTotal {
    count: usize,
    total_us: f64,
}

fn totals_by_name(f: &TraceFile) -> BTreeMap<String, PhaseTotal> {
    let mut m: BTreeMap<String, PhaseTotal> = BTreeMap::new();
    for e in &f.events {
        let t = m.entry(e.name.clone()).or_insert(PhaseTotal {
            count: 0,
            total_us: 0.0,
        });
        t.count += 1;
        t.total_us += e.dur_us;
    }
    m
}

fn totals_by_kind(f: &TraceFile) -> BTreeMap<String, f64> {
    let mut m = BTreeMap::new();
    for e in &f.events {
        *m.entry(e.cat.clone()).or_insert(0.0) += e.dur_us;
    }
    m
}

/// Length of the union of `[start, end)` intervals, microseconds.
fn union_us(mut iv: Vec<(f64, f64)>) -> f64 {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (s, e) in iv {
        match &mut cur {
            Some((_, ce)) if s <= *ce => *ce = ce.max(e),
            _ => {
                if let Some((cs, ce)) = cur {
                    total += ce - cs;
                }
                cur = Some((s, e));
            }
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

/// Per-pid comm/compute busy time and their overlap. Overlap
/// efficiency = overlapped time / min(comm busy, compute busy): 1.0
/// means the shorter side is fully hidden behind the longer one.
fn overlap_by_pid(f: &TraceFile) -> BTreeMap<u32, (f64, f64, f64)> {
    let mut per: BTreeMap<u32, (Vec<(f64, f64)>, Vec<(f64, f64)>)> = BTreeMap::new();
    for e in &f.events {
        let iv = (e.ts_us, e.ts_us + e.dur_us);
        let entry = per.entry(e.pid).or_default();
        match e.cat.as_str() {
            "comm" => entry.0.push(iv),
            "compute" => entry.1.push(iv),
            _ => {}
        }
    }
    per.into_iter()
        .map(|(pid, (comm, compute))| {
            let comm_busy = union_us(comm.clone());
            let compute_busy = union_us(compute.clone());
            // Overlap = |union(comm)| + |union(compute)| - |union(both)|.
            let both: Vec<(f64, f64)> = comm.into_iter().chain(compute).collect();
            let overlapped = (comm_busy + compute_busy - union_us(both)).max(0.0);
            (pid, (comm_busy, compute_busy, overlapped))
        })
        .collect()
}

/// Human-readable per-category totals + overlap efficiency.
pub fn report(f: &TraceFile) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace report: {} events, role={}, dropped={}\n",
        f.events.len(),
        if f.role.is_empty() { "?" } else { f.role.as_str() },
        f.dropped
    ));
    out.push_str("category                 count      total ms      mean us\n");
    for (name, t) in totals_by_name(f) {
        let kind = Category::parse(&name)
            .map(|c| c.kind())
            .unwrap_or_else(|| sim_kind(&name));
        out.push_str(&format!(
            "{:<17}{:>7} {:>9} {:>13.3} {:>12.2}\n",
            name,
            format!("[{kind}]"),
            t.count,
            t.total_us / 1000.0,
            t.total_us / t.count.max(1) as f64
        ));
    }
    for (pid, (comm, compute, overlapped)) in overlap_by_pid(f) {
        let denom = comm.min(compute);
        let eff = if denom > 0.0 { overlapped / denom } else { 0.0 };
        out.push_str(&format!(
            "rank {pid}: comm busy {:.3} ms, compute busy {:.3} ms, \
             overlapped {:.3} ms, overlap efficiency {:.1}%\n",
            comm / 1000.0,
            compute / 1000.0,
            overlapped / 1000.0,
            eff * 100.0
        ));
    }
    out
}

fn delta_pct(real: f64, sim: f64) -> String {
    if sim > 0.0 {
        format!("{:+.1}%", (real - sim) / sim * 100.0)
    } else {
        "n/a".to_string()
    }
}

/// Predicted-vs-measured: per-kind totals for both files, plus
/// per-name rows for names present in both (the shared schema means
/// simnet op names and real category labels only partially intersect,
/// so the kind-level rows are the headline numbers).
pub fn diff(real: &TraceFile, sim: &TraceFile) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace diff: measured '{}' ({} events) vs predicted '{}' ({} events)\n",
        if real.role.is_empty() { "?" } else { real.role.as_str() },
        real.events.len(),
        if sim.role.is_empty() { "?" } else { sim.role.as_str() },
        sim.events.len()
    ));
    out.push_str("phase          measured ms   predicted ms     delta\n");
    let rk = totals_by_kind(real);
    let sk = totals_by_kind(sim);
    let mut kinds: Vec<&String> = rk.keys().chain(sk.keys()).collect();
    kinds.sort();
    kinds.dedup();
    for kind in kinds {
        let r = rk.get(kind).copied().unwrap_or(0.0);
        let s = sk.get(kind).copied().unwrap_or(0.0);
        out.push_str(&format!(
            "{:<14}{:>12.3} {:>14.3} {:>9}\n",
            kind,
            r / 1000.0,
            s / 1000.0,
            delta_pct(r, s)
        ));
    }
    let rt = totals_by_name(real);
    let st = totals_by_name(sim);
    let shared: Vec<&String> = rt.keys().filter(|k| st.contains_key(*k)).collect();
    if !shared.is_empty() {
        out.push_str("shared phases:\n");
        for name in shared {
            let r = rt[name].total_us;
            let s = st[name].total_us;
            out.push_str(&format!(
                "  {:<12}{:>12.3} {:>14.3} {:>9}\n",
                name,
                r / 1000.0,
                s / 1000.0,
                delta_pct(r, s)
            ));
        }
    }
    let only_real: Vec<&String> = rt.keys().filter(|k| !st.contains_key(*k)).collect();
    let only_sim: Vec<&String> = st.keys().filter(|k| !rt.contains_key(*k)).collect();
    if !only_real.is_empty() {
        out.push_str(&format!(
            "measured-only phases: {}\n",
            only_real.iter().map(|s| s.as_str()).collect::<Vec<_>>().join(", ")
        ));
    }
    if !only_sim.is_empty() {
        out.push_str(&format!(
            "predicted-only phases: {}\n",
            only_sim.iter().map(|s| s.as_str()).collect::<Vec<_>>().join(", ")
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, cat: &str, ts: f64, dur: f64, pid: u32) -> ChromeEvent {
        ChromeEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ts_us: ts,
            dur_us: dur,
            pid,
            tid: 1,
            args: [("step".to_string(), 2.0)].into_iter().collect(),
        }
    }

    fn tmp_path(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!(
            "scalecom-trace-{}-{tag}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("t.json").to_string_lossy().into_owned()
    }

    #[test]
    fn json_roundtrip_preserves_events_and_metadata() {
        let f = TraceFile {
            events: vec![
                ev("select", "compute", 10.0, 5.0, 3),
                ev("wire-write", "comm", 12.0, 4.0, 3),
            ],
            rank: 3,
            role: "node".to_string(),
            sync_ns: 9000,
            dropped: 2,
        };
        let parsed = TraceFile::from_json(&Json::parse(&f.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed.events, f.events);
        assert_eq!(parsed.rank, 3);
        assert_eq!(parsed.role, "node");
        assert_eq!(parsed.sync_ns, 9000);
        assert_eq!(parsed.dropped, 2);
    }

    #[test]
    fn merge_rebases_to_the_latest_sync_anchor() {
        // Rank 0's anchor is at 2000 ns, rank 1's at 5000 ns: rank 0's
        // events shift right by 3 us so the anchors coincide.
        let a = TraceFile {
            events: vec![ev("select", "compute", 2.0, 1.0, 0)],
            rank: 0,
            role: "node".into(),
            sync_ns: 2000,
            dropped: 1,
        };
        let b = TraceFile {
            events: vec![ev("wire-write", "comm", 5.0, 1.0, 1)],
            rank: 1,
            role: "node".into(),
            sync_ns: 5000,
            dropped: 2,
        };
        let m = merge(&[a, b]);
        assert_eq!(m.events.len(), 2);
        assert_eq!(m.dropped, 3);
        // Both events sat exactly at their file's anchor, so they land
        // at the same merged timestamp.
        assert!((m.events[0].ts_us - 5.0).abs() < 1e-9, "{:?}", m.events);
        assert!((m.events[1].ts_us - 5.0).abs() < 1e-9, "{:?}", m.events);
        let pids: Vec<u32> = m.events.iter().map(|e| e.pid).collect();
        assert!(pids.contains(&0) && pids.contains(&1));
    }

    #[test]
    fn file_roundtrip_through_merge() {
        let pa = tmp_path("a");
        let pb = tmp_path("b");
        TraceFile {
            events: vec![ev("select", "compute", 1.0, 2.0, 0)],
            rank: 0,
            role: "node".into(),
            sync_ns: 0,
            dropped: 0,
        }
        .write(&pa)
        .unwrap();
        TraceFile {
            events: vec![ev("collective", "comm", 3.0, 2.0, 1)],
            rank: 1,
            role: "node".into(),
            sync_ns: 0,
            dropped: 0,
        }
        .write(&pb)
        .unwrap();
        let merged = merge(&[TraceFile::read(&pa).unwrap(), TraceFile::read(&pb).unwrap()]);
        let pm = tmp_path("m");
        merged.write(&pm).unwrap();
        let back = TraceFile::read(&pm).unwrap();
        assert_eq!(back.events.len(), 2);
        assert_eq!(back.role, "merged");
        let text = report(&back);
        assert!(text.contains("select"), "{text}");
        assert!(text.contains("collective"), "{text}");
    }

    #[test]
    fn overlap_efficiency_counts_hidden_comm() {
        // compute [0,10), comm [5,15): 5 us overlapped, min busy 10.
        let f = TraceFile {
            events: vec![
                ev("select", "compute", 0.0, 10.0, 0),
                ev("collective", "comm", 5.0, 10.0, 0),
            ],
            ..TraceFile::default()
        };
        let per = overlap_by_pid(&f);
        let (comm, compute, overlapped) = per[&0];
        assert!((comm - 10.0).abs() < 1e-9);
        assert!((compute - 10.0).abs() < 1e-9);
        assert!((overlapped - 5.0).abs() < 1e-9);
        let text = report(&f);
        assert!(text.contains("overlap efficiency 50.0%"), "{text}");
    }

    #[test]
    fn union_merges_touching_and_nested_intervals() {
        assert!((union_us(vec![]) - 0.0).abs() < 1e-12);
        assert!((union_us(vec![(0.0, 2.0), (1.0, 3.0)]) - 3.0).abs() < 1e-12);
        assert!((union_us(vec![(0.0, 10.0), (2.0, 3.0)]) - 10.0).abs() < 1e-12);
        assert!((union_us(vec![(0.0, 1.0), (5.0, 6.0)]) - 2.0).abs() < 1e-12);
        assert!((union_us(vec![(0.0, 1.0), (1.0, 2.0)]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn diff_reports_kind_deltas() {
        let real = TraceFile {
            events: vec![ev("select", "compute", 0.0, 11.0, 0)],
            role: "node".into(),
            ..TraceFile::default()
        };
        let sim = TraceFile {
            events: vec![ev("compute", "compute", 0.0, 10.0, 0)],
            role: "simulate:scalecom".into(),
            ..TraceFile::default()
        };
        let text = diff(&real, &sim);
        assert!(text.contains("compute"), "{text}");
        assert!(text.contains("+10.0%"), "{text}");
        assert!(text.contains("measured-only phases: select"), "{text}");
    }
}
