//! Log-bucketed latency histograms: power-of-two nanosecond buckets,
//! lock-free recording (relaxed atomics), Prometheus histogram
//! rendering, and percentile estimates off the bucket counts.
//!
//! Bucket `i` holds durations in `[2^i, 2^(i+1))` ns (bucket 0 also
//! takes 0 and 1 ns), so 40 buckets cover one nanosecond to ~18 minutes
//! with a fixed 2x resolution — good enough for p50/p95/p99 on step
//! latencies and scheduler waits without any locking or rebinning.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets: the top bucket's upper edge is
/// `2^BUCKETS` ns ≈ 1100 s.
pub const BUCKETS: usize = 40;

/// Upper edge of bucket `i` in nanoseconds (exclusive).
fn upper_edge_ns(i: usize) -> u64 {
    1u64 << (i + 1)
}

fn bucket_index(ns: u64) -> usize {
    if ns < 2 {
        0
    } else {
        ((63 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Shareable recorder: `record` is wait-free (three relaxed atomic
/// adds), so the daemon can hand one `Arc<Histogram>` to every job
/// thread.
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn record_ns(&self, ns: u64) {
        self.counts[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_secs(&self, s: f64) {
        self.record_ns(if s <= 0.0 { 0 } else { (s * 1e9) as u64 });
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of a [`Histogram`], safe to carry across the
/// daemon's snapshot path and render without further synchronization.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistSnapshot {
    /// Per-bucket counts; empty means "never recorded" and renders as
    /// an all-zero histogram.
    pub counts: Vec<u64>,
    pub sum_ns: u64,
    pub count: u64,
}

impl HistSnapshot {
    pub fn sum_seconds(&self) -> f64 {
        self.sum_ns as f64 * 1e-9
    }

    /// Upper-edge estimate of the `p`-quantile (`0 < p <= 1`) in
    /// nanoseconds; 0 when empty.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (p * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return upper_edge_ns(i);
            }
        }
        upper_edge_ns(self.counts.len().saturating_sub(1).max(1) - 1)
    }

    pub fn percentile_secs(&self, p: f64) -> f64 {
        self.percentile_ns(p) as f64 * 1e-9
    }

    /// Append Prometheus histogram exposition lines (`_bucket{le=...}`
    /// cumulative counts up to the last nonempty bucket, `+Inf`,
    /// `_sum`, `_count`). `labels` is either empty or a
    /// `key="value",...` fragment merged into each bucket's label set.
    pub fn render_prometheus(&self, out: &mut String, name: &str, labels: &str) {
        let last = self
            .counts
            .iter()
            .rposition(|&c| c > 0)
            .map(|i| i + 1)
            .unwrap_or(0);
        let mut cum = 0u64;
        for i in 0..last {
            cum += self.counts[i];
            let le = upper_edge_ns(i) as f64 * 1e-9;
            if labels.is_empty() {
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            } else {
                out.push_str(&format!("{name}_bucket{{{labels},le=\"{le}\"}} {cum}\n"));
            }
        }
        if labels.is_empty() {
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", self.count));
            out.push_str(&format!("{name}_sum {}\n", self.sum_seconds()));
            out.push_str(&format!("{name}_count {}\n", self.count));
        } else {
            out.push_str(&format!(
                "{name}_bucket{{{labels},le=\"+Inf\"}} {}\n",
                self.count
            ));
            out.push_str(&format!("{name}_sum{{{labels}}} {}\n", self.sum_seconds()));
            out.push_str(&format!("{name}_count{{{labels}}} {}\n", self.count));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_powers_of_two() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(1023), 9);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        for i in 0..BUCKETS {
            // Every value in [2^i, 2^(i+1)) lands in bucket i.
            let lo = if i == 0 { 0 } else { 1u64 << i };
            assert_eq!(bucket_index(lo), i);
            assert_eq!(bucket_index(upper_edge_ns(i) - 1), i);
        }
    }

    #[test]
    fn percentiles_walk_the_cumulative_counts() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record_ns(100); // bucket 6, edge 128
        }
        for _ in 0..10 {
            h.record_ns(10_000); // bucket 13, edge 16384
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.percentile_ns(0.50), 128);
        assert_eq!(s.percentile_ns(0.90), 128);
        assert_eq!(s.percentile_ns(0.95), 16_384);
        assert_eq!(s.percentile_ns(0.99), 16_384);
        assert_eq!(s.percentile_ns(1.0), 16_384);
        assert!((s.sum_seconds() - (90.0 * 100.0 + 10.0 * 10_000.0) * 1e-9).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_renders_and_reports_zero() {
        let s = HistSnapshot::default();
        assert_eq!(s.percentile_ns(0.99), 0);
        let mut out = String::new();
        s.render_prometheus(&mut out, "x_seconds", "");
        assert!(out.contains("x_seconds_bucket{le=\"+Inf\"} 0"), "{out}");
        assert!(out.contains("x_seconds_count 0"), "{out}");
    }

    #[test]
    fn prometheus_rendering_is_cumulative_and_labeled() {
        let h = Histogram::new();
        h.record_secs(0.001); // 1e6 ns → bucket 19, edge 2^20 ns
        h.record_secs(0.004); // 4e6 ns → bucket 21
        let s = h.snapshot();
        let mut out = String::new();
        s.render_prometheus(&mut out, "lat_seconds", "stage=\"wait\"");
        assert!(
            out.contains("lat_seconds_bucket{stage=\"wait\",le=\"0.002097152\"} 1"),
            "{out}"
        );
        assert!(
            out.contains("lat_seconds_bucket{stage=\"wait\",le=\"+Inf\"} 2"),
            "{out}"
        );
        assert!(out.contains("lat_seconds_count{stage=\"wait\"} 2"), "{out}");
        // Cumulative counts never decrease.
        let mut prev = 0u64;
        for line in out.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "{out}");
            prev = v;
        }
    }
}
