//! In-repo property-testing mini-framework.
//!
//! crates.io is unavailable offline, so instead of `proptest` we provide a
//! small, deterministic harness: seeded generators + an iteration budget +
//! failure-case reporting. Shrinking is approximated by retrying a failing
//! case at progressively smaller `size` parameters, which in practice
//! localizes failures well for the vector/index-set inputs used here.
//!
//! Usage:
//! ```no_run
//! use scalecom::proptest::{Gen, check};
//! check("sum is commutative", 200, |g| {
//!     let a = g.f32_vec(1..=64, 10.0);
//!     let b = g.f32_vec_len(a.len(), 10.0);
//!     let ab: f32 = a.iter().zip(&b).map(|(x, y)| x + y).sum();
//!     let ba: f32 = b.iter().zip(&a).map(|(x, y)| x + y).sum();
//!     assert!((ab - ba).abs() < 1e-3);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::RangeInclusive;

/// Generator handle passed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Case index (0..cases); early cases draw smaller inputs so that
    /// failures are reported on the smallest reproducing size first.
    pub case: usize,
    pub cases: usize,
}

impl Gen {
    /// Scale a maximum size by the case ramp: case 0 explores tiny inputs,
    /// the last case the full range.
    fn ramp(&self, lo: usize, hi: usize) -> usize {
        if hi <= lo || self.cases <= 1 {
            return hi;
        }
        let frac = (self.case + 1) as f64 / self.cases as f64;
        lo + ((hi - lo) as f64 * frac).ceil() as usize
    }

    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let hi = self.ramp(lo, hi);
        if hi == lo {
            lo
        } else {
            lo + self.rng.next_below((hi - lo + 1) as u64) as usize
        }
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of normal(0, scale) floats, length drawn from `len`.
    pub fn f32_vec(&mut self, len: RangeInclusive<usize>, scale: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        self.f32_vec_len(n, scale)
    }

    pub fn f32_vec_len(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, scale);
        v
    }

    /// Vector with occasional special values (zeros, ties, large/small
    /// magnitudes) — the adversarial cases for top-k selection.
    pub fn f32_vec_adversarial(&mut self, len: RangeInclusive<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let r = self.rng.next_below(10);
            v.push(match r {
                0 => 0.0,
                1 => 1.0, // deliberate ties
                2 => -1.0,
                3 => self.f32_in(-1e-6, 1e-6),
                4 => self.f32_in(-1e6, 1e6),
                _ => self.rng.next_normal_f32(0.0, 1.0),
            });
        }
        v
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` iterations with deterministic seeds. Panics
/// (with the failing case index and seed) if any iteration panics.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    check_seeded(name, 0xC0FFEE, cases, prop)
}

pub fn check_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    seed: u64,
    cases: usize,
    prop: F,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen {
            rng: Rng::new(case_seed),
            case,
            cases,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed=0x{case_seed:x}): {msg}"
            );
        }
    }
}

/// Definition (1) invariants, property-tested across every registered
/// scheme: commutative compressors must hand all workers ONE shared index
/// set whose sparse reduce is permutation-invariant; non-commutative
/// compressors must produce per-worker sets and therefore route through
/// the gather (build-up) collective, never the reduce.
#[cfg(test)]
mod definition1 {
    use super::check;
    use crate::comm::{Fabric, FabricConfig};
    use crate::compress::{schemes::make_compressor, sparsify, Selection, SparseGrad};
    use crate::coordinator::{Coordinator, Mode};

    const COMMUTATIVE: &[&str] = &[
        "scalecom",
        "scalecom-exact",
        "true-topk",
        "random-k",
        "gtop-k",
        "sketch-k",
    ];
    const NON_COMMUTATIVE: &[&str] = &["local-topk"];

    #[test]
    fn commutative_schemes_share_one_set_and_reduce_is_permutation_invariant() {
        for &scheme in COMMUTATIVE {
            check(&format!("Definition 1: {scheme}"), 30, |g| {
                let n = g.usize_in(2..=8);
                let dim = g.usize_in(8..=128);
                let k = g.usize_in(1..=dim / 2);
                let step = g.usize_in(0..=17);
                let grads: Vec<Vec<f32>> =
                    (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
                let views: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
                let mut c = make_compressor(scheme, dim.div_ceil(k), 9).unwrap();
                assert!(c.is_commutative(), "{scheme} claims commutativity");
                let idx = match c.select(step, &views, k) {
                    Selection::Shared(ix) => ix,
                    Selection::PerWorker(_) => {
                        panic!("{scheme}: commutative scheme must share one index set")
                    }
                };
                // Every worker sparsifies with the same set; summing the
                // sparse vectors must not depend on worker order.
                let sparses: Vec<SparseGrad> =
                    grads.iter().map(|w| sparsify(w, &idx)).collect();
                let sum_in = |order: &[usize]| -> Vec<f32> {
                    let mut acc = sparses[order[0]].clone();
                    for &w in &order[1..] {
                        acc = acc.add_same_indices(&sparses[w]);
                    }
                    acc.values
                };
                let natural: Vec<usize> = (0..n).collect();
                let mut shuffled = natural.clone();
                g.rng().shuffle(&mut shuffled);
                let a = sum_in(&natural);
                let b = sum_in(&shuffled);
                for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                    assert!(
                        (x - y).abs() <= 1e-6 * x.abs().max(1.0),
                        "{scheme}: reduce not permutation-invariant at {i}: {x} vs {y} \
                         (order {shuffled:?})"
                    );
                }
            });
        }
    }

    #[test]
    fn non_commutative_schemes_produce_per_worker_sets() {
        for &scheme in NON_COMMUTATIVE {
            check(&format!("non-commutative: {scheme}"), 30, |g| {
                let n = g.usize_in(2..=8);
                let dim = g.usize_in(8..=128);
                let k = g.usize_in(1..=dim / 2);
                let grads: Vec<Vec<f32>> =
                    (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
                let views: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
                let mut c = make_compressor(scheme, dim.div_ceil(k), 9).unwrap();
                assert!(!c.is_commutative());
                match c.select(0, &views, k) {
                    Selection::PerWorker(per) => assert_eq!(per.len(), n),
                    Selection::Shared(_) => {
                        panic!("{scheme}: non-commutative scheme must not share a set")
                    }
                }
            });
        }
    }

    #[test]
    fn fabric_routing_reduce_for_commutative_gather_for_non_commutative() {
        // Through the coordinator, commutative schemes must only ever hit
        // the reduce collective and non-commutative ones only the gather.
        let n = 4;
        let dim = 64;
        let mk = |scheme: &str| {
            let fabric = Fabric::new(FabricConfig {
                workers: n,
                ..FabricConfig::default()
            });
            Coordinator::new(
                n,
                dim,
                Mode::Compressed(make_compressor(scheme, 8, 3).unwrap()),
                1.0,
                8,
                fabric,
                0,
            )
        };
        let mut rng = crate::util::rng::Rng::new(4);
        for (&scheme, expect_op) in COMMUTATIVE
            .iter()
            .map(|s| (s, "sparse_allreduce_shared"))
            .chain(NON_COMMUTATIVE.iter().map(|s| (s, "sparse_gather")))
        {
            let mut c = mk(scheme);
            for t in 0..3 {
                let grads: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut v = vec![0.0; dim];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    })
                    .collect();
                let _ = c.step(t, &grads);
            }
            for op in &c.fabric.stats().ops {
                assert_eq!(
                    op.op, expect_op,
                    "{scheme} routed through '{}' instead of '{expect_op}'",
                    op.op
                );
            }
        }
    }
}

/// Pipelined-engine properties: the persistent pool's double-buffer must
/// keep the exact Algorithm-1 semantics.
///
///   (a) **one-step-lag EF math** — step t's EF-gradient/selection
///       compute reads exactly the post-step-(t−1) memory, even while
///       step t−1's collective is still in flight;
///   (b) **shutdown/drain** — stopping the run at a random step leaves no
///       step partially applied: every submitted step's memory update is
///       complete (FIFO drain), and dropping the pool with results still
///       in flight neither hangs nor panics.
#[cfg(test)]
mod pipeline {
    use super::check;
    use crate::comm::{Backend, Fabric, FabricConfig, Topology};
    use crate::compress::schemes::make_compressor;
    use crate::coordinator::{Coordinator, Mode};
    use crate::util::floats::allclose;

    fn coord(scheme: &str, n: usize, dim: usize, k: usize, backend: Backend) -> Coordinator {
        let fabric = Fabric::new(FabricConfig {
            workers: n,
            topology: Topology::Ring,
            ..FabricConfig::default()
        });
        let mode = Mode::Compressed(make_compressor(scheme, dim.div_ceil(k), 9).unwrap());
        Coordinator::new(n, dim, mode, 0.5, k, fabric, 0).with_backend(backend)
    }

    #[test]
    fn pipelined_compute_reads_exactly_post_previous_step_memory() {
        check("one-step-lag EF math", 20, |g| {
            let n = g.usize_in(2..=6);
            let dim = g.usize_in(8..=96);
            let k = g.usize_in(1..=(dim / 2).max(1));
            let steps = g.usize_in(2..=12);
            // cover both exchange kinds: shared ring + per-worker gather
            let scheme = if g.bool() { "scalecom-exact" } else { "local-topk" };
            let mut seq = coord(scheme, n, dim, k, Backend::Sequential);
            let mut pipe = coord(scheme, n, dim, k, Backend::Pipelined);
            let mut seq_results = Vec::new();
            let mut streamed = Vec::new();
            for t in 0..steps {
                let grads: Vec<Vec<f32>> =
                    (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
                seq_results.push(seq.step(t, &grads));
                if let Some(r) = pipe.step_overlapped(t, &grads) {
                    streamed.push(r);
                }
                // The pool snapshot is FIFO-ordered behind step t's
                // submission: it must equal the sequential post-step-t
                // state — exactly what step t+1's compute will read.
                let ps = pipe.memory_snapshot();
                let ss = seq.memory_snapshot();
                for (w, (a, b)) in ps.iter().zip(&ss).enumerate() {
                    if let Err(i) = allclose(a.memory(), b.memory(), 1e-6, 1e-7) {
                        panic!(
                            "{scheme} n={n} t={t} worker={w} coord {i}: \
                             pipelined memory {} vs sequential {}",
                            a.memory()[i],
                            b.memory()[i]
                        );
                    }
                }
            }
            streamed.extend(pipe.finish_overlapped());
            assert_eq!(streamed.len(), steps);
            for (t, (a, b)) in seq_results.iter().zip(&streamed).enumerate() {
                // selections are a pure function of the EF gradients: a
                // stale or torn memory read would change the top-k sets
                assert_eq!(
                    a.selection, b.selection,
                    "{scheme} n={n} t={t}: selection lag mismatch"
                );
                if let Err(i) = allclose(&a.update, &b.update, 1e-5, 1e-6) {
                    panic!(
                        "{scheme} n={n} t={t} coord {i}: {} vs {}",
                        a.update[i], b.update[i]
                    );
                }
            }
        });
    }

    #[test]
    fn pool_shutdown_drain_leaves_no_step_partially_applied() {
        check("pooled-backend early-stop drain", 20, |g| {
            let n = g.usize_in(2..=5);
            let dim = g.usize_in(8..=64);
            let k = g.usize_in(1..=(dim / 2).max(1));
            let total = g.usize_in(1..=12);
            let stop = g.usize_in(1..=total); // inject an early stop
            let scheme = if g.bool() { "scalecom-exact" } else { "local-topk" };
            // Both pooled backends share the drain contract; the socket
            // pool must additionally tear its TCP mesh down cleanly with
            // a collective's result still uncollected.
            let backend = if g.bool() { Backend::Pipelined } else { Backend::Socket };
            let mut seq = coord(scheme, n, dim, k, Backend::Sequential);
            let mut pipe = coord(scheme, n, dim, k, backend);
            for t in 0..stop {
                let grads: Vec<Vec<f32>> =
                    (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
                let _ = seq.step(t, &grads);
                let _ = pipe.step_overlapped(t, &grads);
            }
            // The last step's collective is still in flight and its
            // result is never collected — yet the memory state must
            // already reflect ALL submitted steps (memory updates never
            // depend on the reduced values), i.e. no partial application.
            let ps = pipe.memory_snapshot();
            let ss = seq.memory_snapshot();
            for (w, (a, b)) in ps.iter().zip(&ss).enumerate() {
                if let Err(i) = allclose(a.memory(), b.memory(), 1e-6, 1e-7) {
                    panic!(
                        "{scheme} n={n} stop={stop} worker={w} coord {i}: \
                         drained memory {} vs sequential {}",
                        a.memory()[i],
                        b.memory()[i]
                    );
                }
            }
            // Drop with a result still pending: lanes must drain their
            // queues and join cleanly (a hang here fails the test by
            // timeout; a panic fails it loudly).
            drop(pipe);
        });
    }
}

/// Bucketed-exchange properties: for ANY layer partition, ANY byte cap,
/// and ANY gradient stream, the per-bucket driver must be observably
/// equivalent to the monolithic layered step (selections exact, updates
/// within the ring tolerance, memories in lockstep) — and the pooled
/// backward-order overlap driver must match the sequential per-bucket
/// reference exactly bucket for bucket.
#[cfg(test)]
mod bucketed_exchange {
    use super::check;
    use crate::comm::{Backend, BucketPlan, Fabric, FabricConfig, Topology};
    use crate::compress::rate::LayerSlice;
    use crate::compress::{schemes::make_compressor, LayerPartition};
    use crate::coordinator::{Coordinator, Mode};
    use crate::util::floats::allclose;

    #[test]
    fn bucketed_equals_monolithic_for_random_partitions() {
        check("bucketed == monolithic (random partitions)", 15, |g| {
            let n = g.usize_in(2..=4);
            let n_layers = g.usize_in(1..=5);
            let mut layers = Vec::new();
            let mut off = 0usize;
            for i in 0..n_layers {
                let len = g.usize_in(4..=32);
                layers.push(LayerSlice {
                    name: format!("l{i}"),
                    offset: off,
                    len,
                    flops_per_sample: 0.0,
                    compress: g.usize_in(0..=3) > 0, // some layers dense
                });
                off += len;
            }
            let partition = LayerPartition::from_layers(layers);
            let dim = partition.total_len();
            let ks: Vec<usize> = partition
                .layers
                .iter()
                .map(|l| if l.compress { g.usize_in(1..=l.len) } else { l.len })
                .collect();
            let plan = BucketPlan::from_partition(&partition, g.usize_in(0..=dim * 4));
            let scheme = if g.bool() { "scalecom-exact" } else { "local-topk" };
            let mk = |backend: Backend| {
                let fabric = Fabric::new(FabricConfig {
                    workers: n,
                    topology: Topology::ParameterServer,
                    ..FabricConfig::default()
                });
                Coordinator::new(
                    n,
                    dim,
                    Mode::Compressed(make_compressor(scheme, 8, 3).unwrap()),
                    0.5,
                    4,
                    fabric,
                    0,
                )
                .with_layered(partition.clone(), ks.clone())
                .with_backend(backend)
            };
            let mut mono = mk(Backend::Sequential);
            let mut buck = mk(Backend::Sequential).with_buckets(plan.clone());
            let mut buck_pool = mk(Backend::Pipelined).with_buckets(plan);
            let steps = g.usize_in(1..=6);
            for t in 0..steps {
                let grads: Vec<Vec<f32>> =
                    (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
                let a = mono.step(t, &grads);
                let b = buck.step_bucketed(t, &grads);
                let c = buck_pool.step_bucketed(t, &grads);
                assert_eq!(a.selection, b.selection, "{scheme} t={t}: selection");
                assert_eq!(b.selection, c.selection, "{scheme} t={t}: pooled selection");
                assert_eq!(a.rate, b.rate, "{scheme} t={t}: rate");
                assert_eq!(b.comm, c.comm, "{scheme} t={t}: pooled comm booking");
                if let Err(i) = allclose(&a.update, &b.update, 1e-5, 1e-6) {
                    panic!("{scheme} t={t} coord {i}: {} vs {}", a.update[i], b.update[i]);
                }
                if let Err(i) = allclose(&b.update, &c.update, 1e-5, 1e-6) {
                    panic!(
                        "{scheme} t={t} coord {i} (pooled): {} vs {}",
                        b.update[i], c.update[i]
                    );
                }
            }
            for ((a, b), c) in mono
                .memory_snapshot()
                .iter()
                .zip(&buck.memory_snapshot())
                .zip(&buck_pool.memory_snapshot())
            {
                assert!(allclose(a.memory(), b.memory(), 1e-6, 1e-7).is_ok());
                assert!(allclose(b.memory(), c.memory(), 1e-6, 1e-7).is_ok());
            }
        });
    }
}

/// Wire-codec properties (the socket transport's framing layer): any
/// `SparseGrad`/dense/control message round-trips bit-exactly — raw,
/// delta+varint packed, and byte-compressed alike; `frame_len` can never
/// drift from `encode`; decoding under adversity — split reads at every
/// byte boundary, truncated frames, hostile lengths, bit flips, zip-bomb
/// declared sizes, random garbage — never panics or mis-frames.
#[cfg(test)]
mod wire_codec {
    use super::check;
    use crate::comm::codec::{CodecStats, FrameCodec, WireCodecConfig, WireCompression};
    use crate::comm::wire::{
        decode_body, encode, frame_len, read_msg, FrameDecoder, Purpose, WireMsg,
        MAX_FRAME_BYTES, TAG_COMPRESSED,
    };
    use crate::compress::SparseGrad;

    /// Draw an arbitrary message (all variants reachable).
    fn arb_msg(g: &mut super::Gen) -> WireMsg {
        match g.usize_in(0..=3) {
            0 => WireMsg::DenseChunk {
                bucket: g.usize_in(0..=u16::MAX as usize) as u32,
                vals: g.f32_vec(0..=64, 10.0),
            },
            1 => {
                let dim = g.usize_in(1..=256);
                let nnz = g.usize_in(0..=dim.min(32));
                // strictly increasing indices in range
                let mut idx: Vec<u32> = Vec::with_capacity(nnz);
                let mut next = 0u32;
                for _ in 0..nnz {
                    let room = dim as u32 - next;
                    if room == 0 {
                        break;
                    }
                    let i = next + g.usize_in(0..=(room as usize - 1) / 2) as u32;
                    idx.push(i);
                    next = i + 1;
                }
                let vals = g.f32_vec_len(idx.len(), 5.0);
                WireMsg::Sparse {
                    bucket: g.usize_in(0..=u16::MAX as usize) as u32,
                    grad: SparseGrad::new(dim, idx, vals),
                }
            }
            2 => WireMsg::Hello {
                rank: g.usize_in(0..=1024) as u32,
                purpose: if g.bool() { Purpose::Ring } else { Purpose::Star },
                codec: g.usize_in(1..=255) as u8,
            },
            _ => WireMsg::Indices(
                (0..g.usize_in(0..=48)).map(|_| g.usize_in(0..=u16::MAX as usize) as u32).collect(),
            ),
        }
    }

    fn bits_equal(a: &WireMsg, b: &WireMsg) -> bool {
        // PartialEq on f32 treats NaN != NaN and -0.0 == 0.0; compare
        // float payloads by bits so the property is about the *codec*.
        match (a, b) {
            (
                WireMsg::DenseChunk { bucket: ba, vals: x },
                WireMsg::DenseChunk { bucket: bb, vals: y },
            ) => {
                ba == bb
                    && x.len() == y.len()
                    && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
            }
            (
                WireMsg::Sparse { bucket: ba, grad: x },
                WireMsg::Sparse { bucket: bb, grad: y },
            ) => {
                ba == bb
                    && x.dim == y.dim
                    && x.indices == y.indices
                    && x.values.len() == y.values.len()
                    && x.values
                        .iter()
                        .zip(&y.values)
                        .all(|(p, q)| p.to_bits() == q.to_bits())
            }
            _ => a == b,
        }
    }

    #[test]
    fn arbitrary_messages_roundtrip_bit_exactly() {
        check("wire roundtrip", 200, |g| {
            let msg = arb_msg(g);
            let frame = encode(&msg);
            let back = decode_body(&frame[4..]).expect("well-formed frame decodes");
            assert!(bits_equal(&msg, &back), "{msg:?} vs {back:?}");
            // and via the blocking reader
            let mut r = frame.as_slice();
            let back2 = read_msg(&mut r).expect("read_msg");
            assert!(bits_equal(&msg, &back2));
            assert!(r.is_empty(), "read_msg must consume exactly one frame");
        });
    }

    #[test]
    fn split_reads_at_every_byte_boundary_reassemble() {
        check("wire split reads", 60, |g| {
            // a short burst of messages, fed to one decoder in two pieces
            // cut at EVERY byte boundary of the concatenated stream
            let msgs: Vec<WireMsg> = (0..g.usize_in(1..=3)).map(|_| arb_msg(g)).collect();
            let stream: Vec<u8> = msgs.iter().flat_map(encode).collect();
            for cut in 0..=stream.len() {
                let mut d = FrameDecoder::new();
                let mut got = d.push(&stream[..cut]).expect("prefix never errors");
                got.extend(d.push(&stream[cut..]).expect("suffix completes"));
                assert_eq!(got.len(), msgs.len(), "cut={cut}");
                for (a, b) in msgs.iter().zip(&got) {
                    assert!(bits_equal(a, b), "cut={cut}");
                }
                assert_eq!(d.pending(), 0, "cut={cut}: no bytes left over");
            }
        });
    }

    #[test]
    fn truncated_frames_never_yield_or_panic() {
        check("wire truncation", 120, |g| {
            let msg = arb_msg(g);
            let frame = encode(&msg);
            let cut = g.usize_in(0..=frame.len().saturating_sub(1));
            let mut d = FrameDecoder::new();
            let got = d.push(&frame[..cut]).expect("a truncated frame just waits");
            assert!(got.is_empty(), "cut={cut}: partial frame must not yield");
            assert_eq!(d.pending(), cut);
            // the blocking reader reports an error (EOF), never hangs/panics
            assert!(read_msg(&mut &frame[..cut]).is_err());
        });
    }

    #[test]
    fn hostile_lengths_and_garbage_never_panic() {
        check("wire adversity", 200, |g| {
            // random garbage through the incremental decoder: Err or Ok,
            // never a panic, never an over-allocation
            let len = g.usize_in(0..=64);
            let bytes: Vec<u8> = (0..len).map(|_| g.usize_in(0..=255) as u8).collect();
            let mut d = FrameDecoder::new();
            let _ = d.push(&bytes);
            // an oversized length field is rejected up front
            let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
            let mut d = FrameDecoder::new();
            assert!(d.push(&huge).is_err());
            // bit-flipped well-formed frames: decode may fail but must
            // not panic; if it succeeds it consumed the whole body
            let mut frame = encode(&arb_msg(g));
            if !frame.is_empty() {
                let pos = g.usize_in(4.min(frame.len() - 1)..=frame.len() - 1);
                frame[pos] ^= 1 << g.usize_in(0..=7);
                let body_len =
                    u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
                if body_len == frame.len() - 4 {
                    let _ = decode_body(&frame[4..]);
                }
            }
        });
    }

    #[test]
    fn encode_length_matches_frame_len_for_every_variant() {
        // `frame_len` preallocates the hot-path encode buffer; a drift
        // from `encode` would mean regrowth copies (or waste) on every
        // multi-MB dense chunk.
        check("wire frame_len == encode len", 300, |g| {
            let msg = arb_msg(g);
            assert_eq!(encode(&msg).len(), frame_len(&msg), "{msg:?}");
        });
    }

    /// An encoder/decoder pair sharing one stats handle, with the
    /// min-size guard disabled so the byte pass sees small frames too.
    fn codec_pair(mode: WireCompression) -> (FrameCodec, FrameCodec) {
        let cfg = WireCodecConfig { mode, min_bytes: 0, ..WireCodecConfig::default() };
        let stats = CodecStats::new();
        (FrameCodec::new(cfg, stats.clone()), FrameCodec::new(cfg, stats))
    }

    /// Half the draws are runs of one repeated value — highly
    /// compressible, so the byte pass actually wraps envelopes instead
    /// of always falling back on incompressible random floats.
    fn arb_msg_maybe_compressible(g: &mut super::Gen) -> WireMsg {
        if g.bool() {
            let n = g.usize_in(0..=160);
            let v = g.f32_in(-4.0, 4.0);
            WireMsg::DenseChunk { bucket: g.usize_in(0..=7) as u32, vals: vec![v; n] }
        } else {
            arb_msg(g)
        }
    }

    #[test]
    fn packed_and_compressed_frames_roundtrip_bit_exactly() {
        for mode in [WireCompression::Delta, WireCompression::Full] {
            check(&format!("wire codec roundtrip ({})", mode.label()), 150, |g| {
                let (mut enc, mut dec) = codec_pair(mode);
                let msg = arb_msg_maybe_compressible(g);
                let mut frame = Vec::new();
                enc.encode_frame_into(&msg, &mut frame).expect("encode");
                let body_len =
                    u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
                assert_eq!(body_len + 4, frame.len(), "header covers the body");
                // the pooled decode path (what the socket receiver runs)
                let back = dec.decode_body(&frame[4..]).expect("codec decode");
                assert!(bits_equal(&msg, &back), "{msg:?} vs {back:?}");
                // and the free-function path behind read_msg/FrameDecoder
                let back2 = decode_body(&frame[4..]).expect("decode_body");
                assert!(bits_equal(&msg, &back2));
            });
        }
    }

    #[test]
    fn split_reads_reassemble_compressed_streams() {
        check("wire codec split reads", 15, |g| {
            let (mut enc, _) = codec_pair(WireCompression::Full);
            let msgs: Vec<WireMsg> =
                (0..g.usize_in(1..=3)).map(|_| arb_msg_maybe_compressible(g)).collect();
            let mut stream = Vec::new();
            for m in &msgs {
                let mut frame = Vec::new();
                enc.encode_frame_into(m, &mut frame).expect("encode");
                stream.extend_from_slice(&frame);
            }
            for cut in 0..=stream.len() {
                let mut d = FrameDecoder::new();
                let mut got = d.push(&stream[..cut]).expect("prefix never errors");
                got.extend(d.push(&stream[cut..]).expect("suffix completes"));
                assert_eq!(got.len(), msgs.len(), "cut={cut}");
                for (a, b) in msgs.iter().zip(&got) {
                    assert!(bits_equal(a, b), "cut={cut}");
                }
                assert_eq!(d.pending(), 0, "cut={cut}: no bytes left over");
            }
        });
    }

    #[test]
    fn truncated_and_bitflipped_compressed_frames_never_panic() {
        check("wire codec adversity", 120, |g| {
            let (mut enc, mut dec) = codec_pair(WireCompression::Full);
            let msg = arb_msg_maybe_compressible(g);
            let mut frame = Vec::new();
            enc.encode_frame_into(&msg, &mut frame).expect("encode");
            // truncation: the incremental decoder waits, the blocking
            // reader errors — neither panics, neither yields a message
            let cut = g.usize_in(0..=frame.len().saturating_sub(1));
            let mut d = FrameDecoder::new();
            assert!(d.push(&frame[..cut]).expect("partial frame waits").is_empty());
            assert!(read_msg(&mut &frame[..cut]).is_err());
            // a bit flip in the body: Err or Ok through both decode
            // paths, never a panic or over-allocation
            if frame.len() > 4 {
                let pos = g.usize_in(4..=frame.len() - 1);
                frame[pos] ^= 1 << g.usize_in(0..=7);
                let _ = decode_body(&frame[4..]);
                let _ = dec.decode_body(&frame[4..]);
            }
        });
    }

    #[test]
    fn zip_bomb_declared_sizes_are_rejected_before_allocation() {
        check("wire zip bomb", 40, |g| {
            // An envelope declaring a decompressed size over the cap must
            // be rejected up front by every decode path — regardless of
            // how little compressed payload actually follows.
            let declared =
                (MAX_FRAME_BYTES + 1 + g.usize_in(0..=1_000_000)) as u32;
            let mut body = vec![TAG_COMPRESSED, 1]; // algo byte 1 = lz1
            crate::comm::codec::put_varint_u32(&mut body, declared);
            body.extend((0..g.usize_in(0..=32)).map(|_| g.usize_in(0..=255) as u8));
            let err = decode_body(&body).expect_err("over-cap declared size");
            assert!(err.to_string().contains("cap"), "{err:#}");
            let (_, mut dec) = codec_pair(WireCompression::Full);
            assert!(dec.decode_body(&body).is_err());
        });
    }
}

/// Simnet determinism + analytic-model lock: same seed + same profile ⇒
/// byte-identical event trace and selections bit-identical to the
/// sequential backend; on uniform zero-latency links the bucketed
/// virtual timeline matches `perfmodel::step_time_bucketed`'s closed
/// form to 1e-9.
#[cfg(test)]
mod simnet_determinism {
    use super::check;
    use crate::comm::{Fabric, FabricConfig};
    use crate::compress::make_compressor;
    use crate::coordinator::{Coordinator, Mode};
    use crate::perfmodel;
    use crate::simnet::engine::{
        simulate, synthetic_grads, uniform_partition, SimConfig, SIM_SCHEMES,
    };
    use crate::simnet::profile::{LinkProfile, StragglerProfile, TopologyProfile};

    #[test]
    fn same_seed_same_profile_identical_trace_and_selections() {
        check("simnet determinism", 12, |g| {
            let n = g.usize_in(2..=6);
            let layers = g.usize_in(1..=6);
            let dim = layers * g.usize_in(16..=64);
            let scheme = SIM_SCHEMES[g.usize_in(0..=SIM_SCHEMES.len() - 1)];
            let profile = TopologyProfile {
                name: "prop".into(),
                link: LinkProfile::new(
                    1.0 + g.f32_in(0.0, 31.0) as f64,
                    g.f32_in(0.0, 5.0) as f64,
                ),
                group_size: 0,
                uplink: LinkProfile::new(8.0, 2.0),
                slow_workers: if g.bool() {
                    vec![g.usize_in(0..=n - 1)]
                } else {
                    Vec::new()
                },
                slow_factor: 1.0 + g.f32_in(0.0, 3.0) as f64,
                straggler: StragglerProfile {
                    prob: g.f32_in(0.0, 0.5) as f64,
                    slowdown: 1.0 + g.f32_in(0.0, 4.0) as f64,
                    jitter: g.f32_in(0.0, 0.2) as f64,
                },
                seed: g.usize_in(0..=1000) as u64,
            };
            let cfg = SimConfig {
                workers: n,
                dim,
                scheme: scheme.into(),
                rate: g.usize_in(2..=16),
                steps: g.usize_in(1..=4),
                warmup_steps: usize::from(g.bool()),
                beta: 1.0,
                seed: g.usize_in(0..=1_000_000) as u64,
                layers,
                bucket_bytes: if g.bool() { (dim / layers) * 4 } else { 0 },
                compute_per_elem_s: 1e-8,
                overlapped: false,
            };
            let a = simulate(&cfg, &profile).expect("simulate");
            let b = simulate(&cfg, &profile).expect("simulate again");
            assert_eq!(a.trace_digest(), b.trace_digest(), "{scheme}: trace");
            assert_eq!(
                a.selection_digest(),
                b.selection_digest(),
                "{scheme}: selections"
            );
            // Selections must be bit-identical to an independently-built
            // sequential coordinator (monolithic driving) over the same
            // synthetic stream — the values half of the contract, which
            // also re-locks bucketed == monolithic selection parity.
            let partition = uniform_partition(dim, layers);
            let ks = partition.per_layer_k(cfg.rate as f64, 32, false);
            let fabric = Fabric::new(FabricConfig {
                workers: n,
                ..FabricConfig::default()
            });
            let k = ((dim as f64 / cfg.rate as f64).ceil() as usize).max(1);
            let mut reference = Coordinator::new(
                n,
                dim,
                Mode::Compressed(make_compressor(scheme, cfg.rate, cfg.seed).expect("scheme")),
                cfg.beta,
                k,
                fabric,
                cfg.warmup_steps,
            )
            .with_layered(partition, ks);
            for t in 0..cfg.steps {
                let grads = synthetic_grads(cfg.seed, t, n, dim);
                let r = reference.step(t, &grads);
                assert_eq!(r.selection, a.selections[t], "{scheme} t={t}");
            }
        });
    }

    #[test]
    fn uniform_links_match_step_time_bucketed_closed_form() {
        // Uniform zero-latency links, no jitter, per-bucket k divisible
        // by n: the engine's pipelined bucket timeline must close to
        // max(Tc, Tm) + min(Tc, Tm)/B — asserted both directly against
        // `perfmodel::bucketed_pipeline_total` and through
        // `perfmodel::step_time_bucketed` on a SystemConfig engineered
        // to the same (Tc, Tm).
        let n = 4usize;
        let layers = 4usize;
        let dim = 4096usize;
        let rate = 4usize; // per-layer k = 1024/4 = 256, divisible by n
        let bw_gbps = 1.0;
        let cpe = 2e-9;
        let profile = TopologyProfile {
            name: "closed-form".into(),
            link: LinkProfile::new(bw_gbps, 0.0),
            group_size: 0,
            uplink: LinkProfile::new(bw_gbps, 0.0),
            slow_workers: Vec::new(),
            slow_factor: 1.0,
            straggler: StragglerProfile::none(),
            seed: 0,
        };
        let layer_bytes = (dim / layers) * 4;
        for (cap, plan_buckets) in [(0usize, 1usize), (2 * layer_bytes, 2), (layer_bytes, 4)] {
            let cfg = SimConfig {
                workers: n,
                dim,
                scheme: "scalecom-exact".into(),
                rate,
                steps: 3,
                warmup_steps: 0,
                beta: 1.0,
                seed: 9,
                layers,
                bucket_bytes: cap,
                compute_per_elem_s: cpe,
                overlapped: false,
            };
            let r = simulate(&cfg, &profile).expect("simulate");
            // Analytic per-bucket intervals from the replayed schedule:
            // tree index broadcast + 2(n-1) uniform ring chunk rounds.
            let bucket_elems = dim / plan_buckets;
            let k_b = bucket_elems / rate;
            let bw = bw_gbps * 1e9;
            let depth = (usize::BITS - (n - 1).leading_zeros()) as f64;
            let tm_b = depth * (k_b * 4) as f64 / bw
                + 2.0 * (n - 1) as f64 * ((k_b / n) * 4) as f64 / bw;
            let tc_b = bucket_elems as f64 * cpe;
            let intervals = vec![(tc_b, tm_b); plan_buckets];
            let expect = perfmodel::bucketed_pipeline_total(&intervals);
            for (t, &step_s) in r.per_step_s.iter().enumerate() {
                assert!(
                    ((step_s - expect) / expect).abs() < 1e-9,
                    "B={plan_buckets} t={t}: sim {step_s} vs pipeline total {expect}"
                );
            }
            // The same total through step_time_bucketed: engineer the
            // system point so its serial Tc/Tm equal the simulated ones.
            let tc = tc_b * plan_buckets as f64;
            let tm = tm_b * plan_buckets as f64;
            let net = crate::models::paper::paper_net("resnet50").expect("paper net");
            let flops = net.train_flops_per_sample() * 8.0;
            let sys = perfmodel::SystemConfig {
                workers: n,
                peak_tflops: 100.0,
                compute_efficiency: flops / (100.0 * 1e12 * tc),
                bandwidth_gbps: 2.0 * net.gradient_bytes() as f64 / (tm * 1e9),
                minibatch_per_worker: 8,
                compression: 112.0,
                overlap: 0.0,
            };
            let model =
                perfmodel::step_time_bucketed(&net, &sys, perfmodel::Scheme::None, plan_buckets);
            let step_s = r.per_step_s[0];
            assert!(
                ((model.total_s - step_s) / step_s).abs() < 1e-9,
                "B={plan_buckets}: step_time_bucketed {} vs sim {step_s}",
                model.total_s
            );
        }
    }
}

/// Ring-schedule invariants, property-tested straight on the shared
/// round helpers (`chunk_bounds` / `reduce_scatter_round` /
/// `all_gather_round`) that every executing ring — channel, socket, and
/// both levels of the ring-of-rings — and the simnet replay all consume:
///
///   (a) the per-round send/recv maps are permutations of the chunk ids
///       and pair up along ring edges (my send chunk is exactly my right
///       neighbor's recv chunk), so every chunk crosses each edge exactly
///       once per phase;
///   (b) after the n−1 reduce-scatter rounds, worker `id` owns the
///       COMPLETE sum of chunk `(id+1)%n`, and the all-gather only ever
///       forwards finished chunks until everyone holds all of them;
///   (c) the same invariants compose to the two-level schedule: intra
///       rings total the group contributions, the leader ring totals the
///       group sums to n, and the chain broadcast hands the finished
///       buffer down — every contribution reduced exactly once;
///   (d) executable rings of EVERY length in 0..3n — crucially len < n,
///       where zero-width chunks must be skipped symmetrically on both
///       sides of an edge so no empty frame crosses the wire — reduce to
///       the elementwise mean on the channel and socket transports, flat
///       and hierarchical alike.
#[cfg(test)]
mod ring_schedule {
    use super::check;
    use crate::comm::codec::{CodecStats, WireCodecConfig};
    use crate::comm::parallel::{
        all_gather_round, chunk_bounds, hier_leader, hier_ring, reduce_scatter_round, ring,
        validate_group_size,
    };
    use crate::util::floats::allclose;

    #[test]
    fn chunk_bounds_tile_the_buffer_for_every_length() {
        check("chunk_bounds tiling", 120, |g| {
            let n = g.usize_in(1..=16);
            let len = g.usize_in(0..=3 * n);
            let bounds = chunk_bounds(len, n);
            assert_eq!(bounds.len(), n);
            assert_eq!(bounds[0].0, 0);
            assert_eq!(bounds[n - 1].1, len);
            for c in 0..n {
                let (lo, hi) = bounds[c];
                assert!(lo <= hi, "chunk {c} inverted: {lo}..{hi}");
                if c + 1 < n {
                    assert_eq!(hi, bounds[c + 1].0, "chunk {c} not contiguous");
                }
                let w = hi - lo;
                assert!(
                    w == len / n || w == len / n + 1,
                    "chunk {c} width {w} unbalanced for len={len} n={n}"
                );
            }
            if len < n {
                let zero = bounds.iter().filter(|(lo, hi)| hi == lo).count();
                assert_eq!(zero, n - len, "len<n must leave exactly n-len empty chunks");
            }
        });
    }

    /// Symbolically drive the flat ring schedule over per-chunk
    /// contribution COUNTS (one integer per (worker, chunk) instead of
    /// f32 payloads), asserting the schedule invariants round by round:
    /// permutation + edge pairing, reduce-scatter ownership on
    /// `(id+1)%n`, and the all-gather forwarding only finished chunks.
    /// Returns the final per-worker counts so the two-level property can
    /// compose intra and uplink runs.
    fn allreduce_counts(n: usize, start: &[Vec<u32>]) -> Vec<Vec<u32>> {
        assert_eq!(start.len(), n);
        assert!(start.iter().all(|row| row.len() == n));
        let totals: Vec<u32> = (0..n).map(|c| start.iter().map(|w| w[c]).sum()).collect();
        let mut acc: Vec<Vec<u32>> = start.to_vec();
        // Reduce-scatter: n-1 rounds of simultaneous neighbor exchange.
        for s in 0..n - 1 {
            let mut sends = vec![false; n];
            let mut recvs = vec![false; n];
            let snapshot = acc.clone();
            for w in 0..n {
                let (send_c, recv_c) = reduce_scatter_round(w, n, s);
                // my send chunk is exactly my right neighbor's recv chunk
                assert_eq!(
                    send_c,
                    reduce_scatter_round((w + 1) % n, n, s).1,
                    "rs round {s}: edge {w}->{} chunk mismatch",
                    (w + 1) % n
                );
                assert!(!sends[send_c] && !recvs[recv_c], "rs round {s}: chunk repeated");
                sends[send_c] = true;
                recvs[recv_c] = true;
                // receive from the LEFT neighbor: add its frozen count
                let left = (w + n - 1) % n;
                assert_eq!(reduce_scatter_round(left, n, s).0, recv_c);
                acc[w][recv_c] += snapshot[left][recv_c];
            }
            assert!(sends.iter().all(|&b| b), "rs round {s}: not a permutation");
        }
        // Ownership: worker w holds the COMPLETE chunk-(w+1)%n sum, the
        // one chunk it never sent during reduce-scatter.
        for w in 0..n {
            let own = (w + 1) % n;
            assert_eq!(
                acc[w][own], totals[own],
                "worker {w} does not own the complete chunk {own} after n-1 rounds"
            );
        }
        // All-gather: circulate the finished chunks by replacement.
        for s in 0..n - 1 {
            let mut sends = vec![false; n];
            let snapshot = acc.clone();
            for w in 0..n {
                let (send_c, recv_c) = all_gather_round(w, n, s);
                assert_eq!(send_c, all_gather_round((w + 1) % n, n, s).1);
                assert!(!sends[send_c], "ag round {s}: chunk repeated");
                sends[send_c] = true;
                // the chunk a worker forwards must already be finished
                assert_eq!(
                    snapshot[w][send_c], totals[send_c],
                    "ag round {s}: worker {w} forwards unfinished chunk {send_c}"
                );
                let left = (w + n - 1) % n;
                assert_eq!(all_gather_round(left, n, s).0, recv_c);
                acc[w][recv_c] = snapshot[left][recv_c];
            }
        }
        for (w, row) in acc.iter().enumerate() {
            assert_eq!(row, &totals, "worker {w} missing finished chunks");
        }
        acc
    }

    #[test]
    fn flat_schedule_sends_every_chunk_once_per_phase_and_lands_ownership() {
        check("flat ring schedule invariants", 80, |g| {
            let n = g.usize_in(2..=16);
            let start: Vec<Vec<u32>> = (0..n).map(|_| vec![1; n]).collect();
            let done = allreduce_counts(n, &start);
            for row in &done {
                assert!(row.iter().all(|&c| c == n as u32));
            }
            // Per worker, the reduce-scatter phase sends n-1 DISTINCT
            // chunks — everything except the chunk it ends up owning.
            for w in 0..n {
                let mut sent: Vec<usize> =
                    (0..n - 1).map(|s| reduce_scatter_round(w, n, s).0).collect();
                sent.sort_unstable();
                sent.dedup();
                assert_eq!(sent.len(), n - 1, "worker {w} repeats a chunk in reduce-scatter");
                assert!(
                    !sent.contains(&((w + 1) % n)),
                    "worker {w} must never send its owned chunk during reduce-scatter"
                );
            }
        });
    }

    #[test]
    fn two_level_schedule_reduces_every_chunk_exactly_once() {
        check("two-level schedule invariants", 60, |g| {
            let m = g.usize_in(2..=6); // group size
            let ngroups = g.usize_in(2..=5); // leader-ring size
            let n = m * ngroups;
            validate_group_size(n, m).expect("constructed to tile");
            // Phase 1: intra-group allreduce over counts — every member
            // ends holding the group total in every chunk.
            let mut group_total = vec![0u32; ngroups];
            for (grp, total) in group_total.iter_mut().enumerate() {
                let start: Vec<Vec<u32>> = (0..m).map(|_| vec![1; m]).collect();
                let done = allreduce_counts(m, &start);
                for (j, row) in done.iter().enumerate() {
                    assert!(
                        row.iter().all(|&c| c == m as u32),
                        "group {grp} member {j}: intra phase incomplete"
                    );
                }
                *total = m as u32;
            }
            // Phase 2: the leader ring reduces the group totals to n —
            // each worker's contribution counted exactly once overall.
            let start: Vec<Vec<u32>> = (0..ngroups)
                .map(|grp| vec![group_total[grp]; ngroups])
                .collect();
            let done = allreduce_counts(ngroups, &start);
            for (grp, row) in done.iter().enumerate() {
                assert!(
                    row.iter().all(|&c| c == n as u32),
                    "leader {grp}: uplink must total n contributions"
                );
            }
            // Phase 3: the chain broadcast copies the leader's finished
            // buffer down unchanged, so every member ends at exactly n.
            for (grp, row) in done.iter().enumerate() {
                for j in 0..m {
                    assert_eq!(row[0], n as u32, "group {grp} member {j}");
                }
            }
        });
    }

    #[test]
    fn hier_leader_preserves_the_flat_cyclic_rotation() {
        check("multi-level CLT-k leader election", 120, |g| {
            let m = g.usize_in(1..=8);
            let ngroups = g.usize_in(2..=6);
            let n = m * ngroups;
            let t = g.usize_in(0..=10_000) as u64;
            let (grp, member) = hier_leader(t, n, m);
            assert!(grp < ngroups && member < m);
            assert_eq!(
                grp * m + member,
                (t % n as u64) as usize,
                "the two-level coordinates must recompose to the flat leader t % n"
            );
        });
    }

    #[test]
    fn rings_of_every_length_round_trip_on_both_transports() {
        check("len in 0..3n ring round-trips", 10, |g| {
            let n = g.usize_in(2..=8);
            let len = g.usize_in(0..=3 * n);
            let inputs: Vec<Vec<f32>> = (0..n).map(|_| g.f32_vec_len(len, 2.0)).collect();
            let mean: Vec<f32> = (0..len)
                .map(|i| inputs.iter().map(|w| w[i]).sum::<f32>() / n as f32)
                .collect();
            let verify = |label: &str, results: Vec<Vec<f32>>| {
                for (w, got) in results.iter().enumerate() {
                    if let Err(i) = allclose(got, &mean, 1e-5, 1e-6) {
                        panic!(
                            "{label} n={n} len={len} worker={w} elem {i}: {} vs mean {}",
                            got[i], mean[i]
                        );
                    }
                }
            };
            // channel, flat
            let handles: Vec<_> = ring(n)
                .into_iter()
                .zip(inputs.clone())
                .map(|(node, mut buf)| {
                    std::thread::spawn(move || {
                        node.allreduce_avg(&mut buf);
                        buf
                    })
                })
                .collect();
            verify(
                "channel flat",
                handles.into_iter().map(|h| h.join().expect("channel flat lane")).collect(),
            );
            // socket, flat
            let timeout = crate::comm::socket::default_timeout().expect("timeout");
            let stats = CodecStats::new();
            let nodes =
                crate::comm::socket::local_ring(n, timeout, WireCodecConfig::default(), &stats)
                    .expect("local socket ring");
            let handles: Vec<_> = nodes
                .into_iter()
                .zip(inputs.clone())
                .map(|(mut node, mut buf)| {
                    std::thread::spawn(move || {
                        node.allreduce_avg(&mut buf).expect("socket allreduce");
                        buf
                    })
                })
                .collect();
            verify(
                "socket flat",
                handles.into_iter().map(|h| h.join().expect("socket flat lane")).collect(),
            );
            // two-level, whenever n admits a hierarchical tiling
            if let Some(gs) = (2..n).find(|m| n % m == 0 && n / m >= 2) {
                let handles: Vec<_> = hier_ring(n, gs)
                    .expect("channel hier ring")
                    .into_iter()
                    .zip(inputs.clone())
                    .map(|(node, mut buf)| {
                        std::thread::spawn(move || {
                            node.allreduce_avg(&mut buf);
                            buf
                        })
                    })
                    .collect();
                verify(
                    "channel hier",
                    handles.into_iter().map(|h| h.join().expect("channel hier lane")).collect(),
                );
                let stats = CodecStats::new();
                let nodes = crate::comm::socket::local_hier_ring(
                    n,
                    gs,
                    timeout,
                    WireCodecConfig::default(),
                    &stats,
                )
                .expect("local socket hier ring");
                let handles: Vec<_> = nodes
                    .into_iter()
                    .zip(inputs)
                    .map(|(mut node, mut buf)| {
                        std::thread::spawn(move || {
                            node.allreduce_avg(&mut buf).expect("socket hier allreduce");
                            buf
                        })
                    })
                    .collect();
                verify(
                    "socket hier",
                    handles.into_iter().map(|h| h.join().expect("socket hier lane")).collect(),
                );
            }
        });
    }
}

/// Span-recorder properties (the tracing spine behind `--trace-out`),
/// driven both on the SPSC ring itself and through the real
/// `span()`/guard API:
///
///   (a) **conservation under concurrency** — producers each on their
///       own ring racing one draining consumer: every pushed span is
///       either collected in push order or counted in `dropped`, never
///       both and never lost; below ring capacity nothing drops at all;
///   (b) **exact drop accounting at capacity** — a full ring drops
///       exactly the overflow pushes (drop-newest) and the survivors
///       are the FIRST `capacity` spans, still in order;
///   (c) **well-nested monotone streams** — recording from several
///       threads at once through RAII guards, each thread's drained
///       stream comes back in drop order (end times monotone) with
///       every inner span contained in its enclosing outer span.
#[cfg(test)]
mod obs_recorder {
    use super::check;
    use crate::obs::span::{self, Category, Span, ThreadRing};
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    #[test]
    fn concurrent_producers_conserve_every_span() {
        check("obs ring conservation", 12, |g| {
            let threads = g.usize_in(1..=4);
            let cap = g.usize_in(4..=64);
            let per = g.usize_in(1..=3 * cap);
            let rings: Vec<Arc<ThreadRing>> = (0..threads)
                .map(|i| Arc::new(ThreadRing::new(i as u32 + 1, cap)))
                .collect();
            let stop = Arc::new(AtomicBool::new(false));
            // One consumer sweeps all rings while the producers push —
            // the SPSC cursor protocol under real contention (in the
            // recorder proper the registry lock serializes consumers,
            // never producers).
            let consumer = {
                let rings = rings.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while !stop.load(Ordering::Acquire) {
                        for r in &rings {
                            r.drain_into(&mut got);
                        }
                        std::thread::yield_now();
                    }
                    // Final sweep after the producers are done.
                    for r in &rings {
                        r.drain_into(&mut got);
                    }
                    got
                })
            };
            let producers: Vec<_> = rings
                .iter()
                .map(|r| {
                    let r = r.clone();
                    std::thread::spawn(move || {
                        for i in 0..per {
                            // Sequence number in start_ns: the order oracle.
                            r.push(Span::new(Category::Select, i as u64, i as u64 + 1));
                        }
                    })
                })
                .collect();
            for p in producers {
                p.join().expect("producer thread");
            }
            stop.store(true, Ordering::Release);
            let got = consumer.join().expect("consumer thread");
            let dropped: u64 = rings.iter().map(|r| r.dropped()).sum();
            assert_eq!(
                got.len() as u64 + dropped,
                (threads * per) as u64,
                "collected + dropped must equal pushed"
            );
            if per <= cap {
                assert_eq!(dropped, 0, "below capacity nothing may drop");
            }
            for ring_id in 1..=threads as u32 {
                let seqs: Vec<u64> = got
                    .iter()
                    .filter(|(tid, _)| *tid == ring_id)
                    .map(|(_, s)| s.start_ns)
                    .collect();
                assert!(
                    seqs.windows(2).all(|w| w[0] < w[1]),
                    "ring {ring_id}: reordered or duplicated spans: {seqs:?}"
                );
            }
        });
    }

    #[test]
    fn full_ring_drops_exactly_the_overflow_and_keeps_the_oldest() {
        check("obs ring drop accounting", 60, |g| {
            let cap = g.usize_in(1..=64);
            let pushes = g.usize_in(0..=4 * cap);
            let ring = ThreadRing::new(9, cap);
            for i in 0..pushes {
                ring.push(Span::new(Category::Encode, i as u64, i as u64 + 1));
            }
            assert_eq!(
                ring.dropped() as usize,
                pushes.saturating_sub(cap),
                "cap {cap}, {pushes} pushes"
            );
            let mut out = Vec::new();
            ring.drain_into(&mut out);
            let kept = pushes.min(cap);
            assert_eq!(out.len(), kept);
            for (i, (_, s)) in out.iter().enumerate() {
                assert_eq!(
                    s.start_ns, i as u64,
                    "drop-newest must keep the first {kept} in order"
                );
            }
        });
    }

    #[test]
    fn guard_streams_are_well_nested_and_monotone_per_thread() {
        let _lock = span::test_recorder_lock();
        check("obs guard nesting", 4, |g| {
            span::set_enabled(true);
            let _ = span::drain_all(); // start from a clean registry
            let threads = g.usize_in(1..=4);
            let reps = g.usize_in(1..=40);
            // Parallel tests in this process may record spans of their
            // own while the flag is up; ours carry a job tag no real
            // code path uses and are filtered on it after the drain.
            let tag = 0xA000_0000u32 | g.case as u32;
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    std::thread::spawn(move || {
                        for t in 0..reps {
                            let outer =
                                span::span(Category::Collective).job(tag).step(t as u32);
                            {
                                let _inner =
                                    span::span(Category::Select).job(tag).step(t as u32);
                                std::hint::black_box(t.wrapping_mul(t));
                            }
                            drop(outer);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("recording thread");
            }
            span::set_enabled(false);
            let drained = span::drain_all();
            let mut per_tid: BTreeMap<u32, Vec<Span>> = BTreeMap::new();
            for (tid, s) in drained.spans {
                if s.job == tag {
                    per_tid.entry(tid).or_default().push(s);
                }
            }
            let total: usize = per_tid.values().map(|v| v.len()).sum();
            assert_eq!(total, threads * reps * 2, "every armed guard records once");
            assert_eq!(per_tid.len(), threads, "one ring per recording thread");
            for (tid, spans) in &per_tid {
                // Record order is drop order: end times never go back.
                assert!(
                    spans.windows(2).all(|w| w[0].end_ns <= w[1].end_ns),
                    "tid {tid}: stream not monotone"
                );
                for (i, pair) in spans.chunks(2).enumerate() {
                    let (inner, outer) = (&pair[0], &pair[1]);
                    assert_eq!(inner.cat, Category::Select, "tid {tid} rep {i}");
                    assert_eq!(outer.cat, Category::Collective, "tid {tid} rep {i}");
                    assert_eq!((inner.step, outer.step), (i as u32, i as u32));
                    assert!(
                        inner.start_ns <= inner.end_ns && outer.start_ns <= outer.end_ns,
                        "tid {tid} rep {i}: inverted interval"
                    );
                    assert!(
                        outer.start_ns <= inner.start_ns && inner.end_ns <= outer.end_ns,
                        "tid {tid} rep {i}: inner [{}, {}] escapes outer [{}, {}]",
                        inner.start_ns,
                        inner.end_ns,
                        outer.start_ns,
                        outer.end_ns
                    );
                }
            }
        });
    }
}

/// Serve-scheduler properties, driven straight on the pure
/// [`JobQueue`](crate::serve::queue::JobQueue) state machine and the
/// shared-lane mesh:
///
///   (a) **FIFO + starvation-freedom** — under ANY interleaving of
///       submit / start_next / complete / cancel, jobs start in exactly
///       admission order (minus cancelled-while-queued), the wait queue
///       and running set never exceed their caps, and once the churn
///       stops every admitted job runs to a terminal state — nothing is
///       stranded and the terminal counters conserve admissions;
///   (b) **job-tag stream isolation** — collectives from interleaved
///       jobs on ONE shared mesh each echo their own tag and their own
///       reduced values, and a collective whose frames carry the WRONG
///       job tag surfaces as a clean mis-framed-stream error that
///       latches: later requests fail fast instead of touching a mesh
///       that is out of sync.
#[cfg(test)]
mod serve_scheduler {
    use super::check;
    use crate::comm::parallel::{CollectiveResult, CommJob, LaneTransport};
    use crate::serve::queue::{CancelOutcome, JobQueue, RejectReason, Submission};
    use crate::serve::SharedLanes;

    #[test]
    fn random_interleavings_stay_fifo_bounded_and_starvation_free() {
        check("serve queue interleavings", 120, |g| {
            let max_queue = g.usize_in(1..=8);
            let max_concurrent = g.usize_in(1..=4);
            let mut q = JobQueue::new(max_queue, max_concurrent);
            let mut admitted: Vec<u32> = Vec::new(); // admission order
            let mut started: Vec<u32> = Vec::new(); // dispatch order
            let mut dequeued: Vec<u32> = Vec::new(); // cancelled while queued
            let mut live: Vec<u32> = Vec::new(); // currently running
            let ops = g.usize_in(1..=120);
            for _ in 0..ops {
                match g.usize_in(0..=3) {
                    0 => match q.submit() {
                        Submission::Admitted { id, queue_pos } => {
                            assert_eq!(
                                queue_pos as usize,
                                q.depth() - 1,
                                "queue_pos must be the admission-time wait position"
                            );
                            admitted.push(id);
                        }
                        Submission::Rejected(RejectReason::QueueFull { depth, max }) => {
                            assert_eq!(
                                (depth, max),
                                (max_queue, max_queue),
                                "QueueFull must only fire at capacity"
                            );
                        }
                        Submission::Rejected(other) => {
                            panic!("live queue rejected with {other:?}")
                        }
                    },
                    1 => match q.start_next() {
                        Some(id) => {
                            started.push(id);
                            live.push(id);
                        }
                        None => assert!(
                            q.depth() == 0 || q.running() == max_concurrent,
                            "start_next refused with queued work and a free slot"
                        ),
                    },
                    2 => {
                        if !live.is_empty() {
                            let id = live.remove(g.usize_in(0..=live.len() - 1));
                            q.complete(id, g.bool());
                        }
                    }
                    _ => {
                        if !admitted.is_empty() {
                            let id = admitted[g.usize_in(0..=admitted.len() - 1)];
                            match q.cancel(id) {
                                Some(CancelOutcome::Dequeued) => dequeued.push(id),
                                Some(CancelOutcome::Signalled) => {
                                    // cancel must only signal a live runner,
                                    // which then acks at its step boundary
                                    assert!(
                                        live.contains(&id),
                                        "Signalled for a job that is not running"
                                    );
                                    live.retain(|&r| r != id);
                                    q.complete_cancelled(id);
                                }
                                None => assert!(
                                    !live.contains(&id),
                                    "cancel lost a running job"
                                ),
                            }
                        }
                    }
                }
                assert!(q.depth() <= max_queue, "wait queue exceeded its cap");
                assert!(q.running() <= max_concurrent, "concurrency cap breached");
            }
            // Churn over: just dispatch and finish — every admitted job
            // must reach a terminal state (starvation-freedom).
            loop {
                while let Some(id) = q.start_next() {
                    started.push(id);
                    live.push(id);
                }
                match live.pop() {
                    Some(id) => q.complete(id, true),
                    None => break,
                }
            }
            assert_eq!(q.depth(), 0, "drained queue must be empty");
            assert_eq!(q.running(), 0);
            let expect: Vec<u32> = admitted
                .iter()
                .copied()
                .filter(|id| !dequeued.contains(id))
                .collect();
            assert_eq!(started, expect, "dispatch violated FIFO admission order");
            let c = q.counters();
            assert_eq!(c.submitted, admitted.len() as u64);
            assert_eq!(
                c.completed + c.failed + c.cancelled,
                admitted.len() as u64,
                "terminal counters must conserve admissions"
            );
        });
    }

    fn tagged(job: u32, bucket: u32, inputs: &[Vec<f32>]) -> Vec<CommJob> {
        inputs
            .iter()
            .map(|b| CommJob::RingAvg {
                job,
                bucket,
                buf: b.clone(),
            })
            .collect()
    }

    #[test]
    fn job_tags_never_cross_streams_and_a_mismatch_faults_cleanly() {
        check("lane job-tag isolation", 8, |g| {
            let n = g.usize_in(2..=4);
            let lanes = SharedLanes::start(n, LaneTransport::Channel, 0).expect("lanes");
            let h = lanes.handle();
            // Random jobs interleaved on ONE mesh: every result must echo
            // the submitting job's tag and ITS values, never a neighbor's.
            for round in 0..g.usize_in(1..=8) as u32 {
                let job = g.usize_in(1..=6) as u32;
                let base = job as f32 * 10.0;
                let len = g.usize_in(1..=32);
                let inputs: Vec<Vec<f32>> =
                    (0..n).map(|w| vec![base + w as f32; len]).collect();
                let want = base + (n as f32 - 1.0) / 2.0;
                match h.collective(job, tagged(job, round, &inputs)).expect("clean mesh") {
                    CollectiveResult::Reduced { job: got, bucket, vals } => {
                        assert_eq!((got, bucket), (job, round), "tag crossed streams");
                        for v in vals {
                            assert!(
                                (v - want).abs() < 1e-5,
                                "job {job} got a foreign reduction: {v} vs {want}"
                            );
                        }
                    }
                    other => panic!("unexpected result {other:?}"),
                }
            }
            assert!(lanes.fault().is_none(), "clean runs must not latch a fault");
            // Inject a collective whose frames carry the WRONG job tag:
            // the stream is mis-framed and must fail cleanly, not crash
            // or hand job `claim` another job's values.
            let claim = g.usize_in(1..=100) as u32;
            let wrong = claim + 1;
            let inputs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; 8]).collect();
            let err = h
                .collective(claim, tagged(wrong, 0, &inputs))
                .expect_err("a mis-tagged stream must not yield a result");
            assert!(err.to_string().contains("mesh out of sync"), "{err:#}");
            let fault = lanes.fault().expect("the mismatch must latch");
            assert!(fault.contains("mesh out of sync"), "{fault}");
            // Latched: later collectives fail fast with the original
            // cause instead of touching the out-of-sync mesh.
            let err = h
                .collective(claim, tagged(claim, 1, &inputs))
                .expect_err("a faulted mesh must refuse new collectives");
            assert!(err.to_string().contains("faulted earlier"), "{err:#}");
            drop(h);
            drop(lanes); // clean owner join even with a latched fault
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is nonneg", 50, |g| {
            let v = g.f32_vec(0..=32, 5.0);
            assert!(v.iter().all(|x| x.abs() >= 0.0));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_case() {
        check("always fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn ramp_grows_sizes() {
        check("ramp", 100, |g| {
            let n = g.usize_in(0..=1000);
            // capture via thread-local-free trick: can't mutate captured
            // vars through Fn, so just sanity-check bounds here.
            assert!(n <= 1000);
        });
        // Direct ramp check without the harness:
        let g_early = Gen {
            rng: Rng::new(1),
            case: 0,
            cases: 100,
        };
        let g_late = Gen {
            rng: Rng::new(1),
            case: 99,
            cases: 100,
        };
        let max_early = g_early.ramp(0, 1000);
        let max_late = g_late.ramp(0, 1000);
        assert!(max_early < max_late);
        assert_eq!(max_late, 1000);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<f32> = Vec::new();
        // Generators with identical (seed, case) must produce identical data.
        let mut g1 = Gen {
            rng: Rng::new(99),
            case: 5,
            cases: 10,
        };
        let mut g2 = Gen {
            rng: Rng::new(99),
            case: 5,
            cases: 10,
        };
        first.extend(g1.f32_vec_len(16, 1.0));
        let second = g2.f32_vec_len(16, 1.0);
        assert_eq!(first, second);
    }

    #[test]
    fn adversarial_contains_ties_eventually() {
        let mut found_tie = false;
        for case in 0..20 {
            let mut g = Gen {
                rng: Rng::new(case),
                case: 19,
                cases: 20,
            };
            let v = g.f32_vec_adversarial(64..=64);
            let ones = v.iter().filter(|&&x| x == 1.0).count();
            if ones >= 2 {
                found_tie = true;
            }
        }
        assert!(found_tie);
    }
}
