//! Minimal JSON parser + writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written
//! by `python/compile/aot.py`) and for experiment/metric dumps. The image
//! has no `serde` offline, so this is an in-repo, fully-tested
//! implementation of the JSON grammar we need: objects, arrays, strings
//! (with escapes), numbers, booleans, null. Numbers are held as f64; the
//! manifest only stores shapes/offsets that fit exactly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ----- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with a readable message — manifest loading wants
    /// hard failures on schema drift.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    // ----- parsing ---------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ----- writing ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-print with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex in \\u"))?;
                            code = code * 16 + d;
                        }
                        // Surrogate pairs unsupported (not needed for the
                        // manifest); map lone surrogates to replacement.
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8 byte")),
                    };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8 sequence"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// Convenience constructors used by the metric writers.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = Json::parse(r#""A\t\"π""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\"π");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m":{"shape":[2,3],"name":"w0","ok":true},"n":1.5}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn accessors_reject_wrong_types() {
        let v = Json::parse("{\"a\": 1.5}").unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), None);
        assert!(v.req("missing").is_err());
        assert!(v.req("a").is_ok());
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("x", Json::from(1usize)), ("y", Json::from("s"))]);
        assert_eq!(v.get("x").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("y").unwrap().as_str(), Some("s"));
    }
}
