//! Length-prefixed binary framing for the socket transport.
//!
//! Every message on a ScaleCom socket is one **frame**:
//!
//! ```text
//! [ u32 LE: body length ][ body ]
//! body = [ u8 tag ][ tag-specific fields, all little-endian ]
//! ```
//!
//! | tag | message      | fields                                                        |
//! |-----|--------------|---------------------------------------------------------------|
//! | 1   | `DenseChunk` | u32 bucket, u32 count, count × f32                            |
//! | 2   | `Sparse`     | u32 bucket, u32 dim, u32 nnz, nnz × u32 idx, nnz × f32 vals   |
//! | 3   | `Hello`      | u32 rank, u8 purpose (0 = ring, 1 = star)                     |
//! | 4   | `Indices`    | u32 count, count × u32                                        |
//!
//! `DenseChunk` carries the ring reduce-scatter/all-gather payloads,
//! `Sparse` the star-gather contributions, and the control tags the
//! rendezvous handshake plus the CLT-k leader's index broadcast. Both
//! payload frames lead with a **bucket id**: the bucketed exchange
//! (`comm::bucket`) keeps several per-bucket collectives in flight on
//! one stream, and the tag lets a receiver verify that an arriving chunk
//! belongs to the collective it is executing — a mismatch is a
//! mis-framed stream (peer out of sync), detected at the first frame
//! instead of silently reducing bucket b's values into bucket b+1's.
//! Monolithic (un-bucketed) collectives use bucket id 0. There
//! is deliberately no shutdown message: an orderly end of run is a
//! flushed socket close, observed by the peer as EOF. f32/f64 values
//! travel as raw IEEE-754 bits, so a value is **bit-identical** after a
//! network hop — the backend determinism contract survives the wire.
//!
//! ## Decode-under-adversity contract
//!
//! A TCP stream can deliver any byte split and any garbage; decoding must
//! never panic, over-allocate, or mis-frame:
//!
//! - the frame header is validated before any allocation: a body length
//!   of 0 or more than [`MAX_FRAME_BYTES`] is rejected;
//! - field counts are checked (in u64, overflow-proof) against the exact
//!   body length — short *and* trailing bytes are both errors;
//! - sparse payloads are only accepted when the index set is strictly
//!   increasing and in-range, so `SparseGrad`'s invariants hold even for
//!   bytes from a hostile or corrupted peer;
//! - [`FrameDecoder`] buffers partial reads, yielding a message only
//!   once its full frame has arrived — a split read at any byte boundary
//!   decodes identically to a single read (property-tested in
//!   `crate::proptest`).

use crate::compress::SparseGrad;
use std::io::{Read, Write};

/// Upper bound on a frame body. Generous for this workload (a dense
/// 1M-parameter f32 gradient is 4 MB) while keeping a corrupted or
/// hostile length field from forcing a huge allocation.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// What an inbound connection is for (field of [`WireMsg::Hello`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Purpose {
    /// The peer is our left ring neighbor; this stream carries chunks.
    Ring,
    /// The peer is a star worker; this stream carries sparse gathers.
    Star,
}

impl Purpose {
    fn to_byte(self) -> u8 {
        match self {
            Purpose::Ring => 0,
            Purpose::Star => 1,
        }
    }

    fn from_byte(b: u8) -> anyhow::Result<Purpose> {
        match b {
            0 => Ok(Purpose::Ring),
            1 => Ok(Purpose::Star),
            other => anyhow::bail!("wire: unknown Hello purpose byte {other}"),
        }
    }
}

/// One framed message.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// A ring hop's dense f32 payload (one reduce-scatter or all-gather
    /// chunk, or a broadcast segment), tagged with the bucket it belongs
    /// to (0 for monolithic collectives).
    DenseChunk { bucket: u32, vals: Vec<f32> },
    /// A star worker's sparsified contribution, bucket-tagged like
    /// [`WireMsg::DenseChunk`].
    Sparse { bucket: u32, grad: SparseGrad },
    /// Rendezvous handshake: sent once by the connecting side so the
    /// accepting side can classify the stream.
    Hello { rank: u32, purpose: Purpose },
    /// The CLT-k leader's index broadcast.
    Indices(Vec<u32>),
}

const TAG_DENSE: u8 = 1;
const TAG_SPARSE: u8 = 2;
const TAG_HELLO: u8 = 3;
const TAG_INDICES: u8 = 4;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Exact frame size (header + body) of `msg` on the wire.
fn frame_len(msg: &WireMsg) -> usize {
    4 + 1
        + match msg {
            WireMsg::DenseChunk { vals, .. } => 8 + 4 * vals.len(),
            WireMsg::Sparse { grad, .. } => 12 + 8 * grad.indices.len(),
            WireMsg::Hello { .. } => 5,
            WireMsg::Indices(idx) => 4 + 4 * idx.len(),
        }
}

/// Encode `msg` as one full frame (header + body), preallocated exactly
/// (dense ring chunks are multi-MB on big models — no regrowth copies on
/// the hot path).
pub fn encode(msg: &WireMsg) -> Vec<u8> {
    let mut out = Vec::with_capacity(frame_len(msg));
    out.extend_from_slice(&[0u8; 4]); // header patched below
    match msg {
        WireMsg::DenseChunk { bucket, vals } => {
            out.push(TAG_DENSE);
            put_u32(&mut out, *bucket);
            put_u32(&mut out, vals.len() as u32);
            for &v in vals {
                put_f32(&mut out, v);
            }
        }
        WireMsg::Sparse { bucket, grad } => {
            out.push(TAG_SPARSE);
            put_u32(&mut out, *bucket);
            put_u32(&mut out, grad.dim as u32);
            put_u32(&mut out, grad.indices.len() as u32);
            for &i in &grad.indices {
                put_u32(&mut out, i);
            }
            for &v in &grad.values {
                put_f32(&mut out, v);
            }
        }
        WireMsg::Hello { rank, purpose } => {
            out.push(TAG_HELLO);
            put_u32(&mut out, *rank);
            out.push(purpose.to_byte());
        }
        WireMsg::Indices(idx) => {
            out.push(TAG_INDICES);
            put_u32(&mut out, idx.len() as u32);
            for &i in idx {
                put_u32(&mut out, i);
            }
        }
    }
    let body_len = (out.len() - 4) as u32;
    out[..4].copy_from_slice(&body_len.to_le_bytes());
    out
}

/// Cursor over a frame body with checked little-endian reads.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "wire: truncated body (need {n} more bytes at offset {}, body is {})",
                    self.pos,
                    self.buf.len()
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Bulk-read `count` little-endian u32s (one bounds check, not one
    /// per element — ring payloads are hot-path, up to millions long).
    fn u32s(&mut self, count: usize) -> anyhow::Result<Vec<u32>> {
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Bulk-read `count` little-endian f32s.
    fn f32s(&mut self, count: usize) -> anyhow::Result<Vec<f32>> {
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "wire: {} trailing bytes after message",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// Check, before allocating, that a `count`-element array of
/// `elem_bytes`-byte elements can still fit in what remains of the body.
fn check_count(c: &Cursor<'_>, count: u32, elem_bytes: u64, what: &str) -> anyhow::Result<usize> {
    let need = count as u64 * elem_bytes;
    let have = (c.buf.len() - c.pos) as u64;
    anyhow::ensure!(
        need <= have,
        "wire: {what} count {count} needs {need} bytes but body has {have} left"
    );
    Ok(count as usize)
}

/// Decode one frame body (everything after the 4-byte length header).
pub fn decode_body(body: &[u8]) -> anyhow::Result<WireMsg> {
    let mut c = Cursor { buf: body, pos: 0 };
    let tag = c.u8()?;
    let msg = match tag {
        TAG_DENSE => {
            let bucket = c.u32()?;
            let count = c.u32()?;
            let count = check_count(&c, count, 4, "dense element")?;
            let vals = c.f32s(count)?;
            c.done()?;
            WireMsg::DenseChunk { bucket, vals }
        }
        TAG_SPARSE => {
            let bucket = c.u32()?;
            let dim = c.u32()? as usize;
            let nnz = c.u32()?;
            let nnz = check_count(&c, nnz, 8, "sparse nnz")?;
            let indices = c.u32s(nnz)?;
            let values = c.f32s(nnz)?;
            c.done()?;
            anyhow::ensure!(
                indices.windows(2).all(|w| w[0] < w[1]),
                "wire: sparse indices must be strictly increasing"
            );
            if let Some(&last) = indices.last() {
                anyhow::ensure!(
                    (last as usize) < dim,
                    "wire: sparse index {last} out of range for dim {dim}"
                );
            }
            WireMsg::Sparse {
                bucket,
                grad: SparseGrad::new(dim, indices, values),
            }
        }
        TAG_HELLO => {
            let rank = c.u32()?;
            let purpose = Purpose::from_byte(c.u8()?)?;
            c.done()?;
            WireMsg::Hello { rank, purpose }
        }
        TAG_INDICES => {
            let n = c.u32()?;
            let n = check_count(&c, n, 4, "index")?;
            let idx = c.u32s(n)?;
            c.done()?;
            WireMsg::Indices(idx)
        }
        other => anyhow::bail!("wire: unknown message tag {other}"),
    };
    Ok(msg)
}

/// Validate a frame header's body length.
fn check_body_len(len: u32) -> anyhow::Result<usize> {
    let len = len as usize;
    anyhow::ensure!(len >= 1, "wire: empty frame body");
    anyhow::ensure!(
        len <= MAX_FRAME_BYTES,
        "wire: frame body of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte cap"
    );
    Ok(len)
}

/// Write one framed message (no flush — callers own buffering policy).
/// The sender enforces the same [`MAX_FRAME_BYTES`] cap the receiver
/// does, so an oversized payload (e.g. a huge `--dim`) fails HERE with a
/// clear config error instead of surfacing on the peer as a misleading
/// "mis-framed stream" fault.
pub fn write_msg<W: Write>(w: &mut W, msg: &WireMsg) -> anyhow::Result<()> {
    let frame = encode(msg);
    anyhow::ensure!(
        frame.len() - 4 <= MAX_FRAME_BYTES,
        "outgoing frame body of {} bytes exceeds the {MAX_FRAME_BYTES}-byte wire cap \
         (payload too large for one frame — lower the dimension or chunk it)",
        frame.len() - 4
    );
    w.write_all(&frame)?;
    Ok(())
}

/// Read one framed message with blocking, exact-length reads.
pub fn read_msg<R: Read>(r: &mut R) -> anyhow::Result<WireMsg> {
    let mut header = [0u8; 4];
    r.read_exact(&mut header)?;
    let len = check_body_len(u32::from_le_bytes(header))?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body)
}

/// Incremental decoder for arbitrarily split reads: feed whatever bytes
/// arrived, collect whole messages. Frames split at any byte boundary —
/// inside the header, inside the body — reassemble identically.
///
/// After an error the stream is mis-framed beyond recovery; drop the
/// decoder (and the connection).
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Bytes buffered but not yet framed (for diagnostics).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    pub fn push(&mut self, bytes: &[u8]) -> anyhow::Result<Vec<WireMsg>> {
        self.buf.extend_from_slice(bytes);
        let mut out = Vec::new();
        loop {
            if self.buf.len() < 4 {
                break;
            }
            let len = check_body_len(u32::from_le_bytes([
                self.buf[0],
                self.buf[1],
                self.buf[2],
                self.buf[3],
            ]))?;
            if self.buf.len() < 4 + len {
                break;
            }
            let msg = decode_body(&self.buf[4..4 + len])?;
            self.buf.drain(..4 + len);
            out.push(msg);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: WireMsg) {
        let frame = encode(&msg);
        let len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
        assert_eq!(len + 4, frame.len(), "header length must cover the body");
        assert_eq!(decode_body(&frame[4..]).unwrap(), msg);
        // and through the incremental decoder
        let mut d = FrameDecoder::new();
        assert_eq!(d.push(&frame).unwrap(), vec![msg]);
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(WireMsg::DenseChunk { bucket: 0, vals: vec![] });
        roundtrip(WireMsg::DenseChunk {
            bucket: 7,
            vals: vec![1.5, -0.0, f32::MIN, f32::MAX],
        });
        roundtrip(WireMsg::Sparse {
            bucket: 0,
            grad: SparseGrad::new(10, vec![0, 3, 9], vec![1.0, -2.0, 0.5]),
        });
        roundtrip(WireMsg::Sparse {
            bucket: u32::MAX,
            grad: SparseGrad::new(0, vec![], vec![]),
        });
        roundtrip(WireMsg::Hello { rank: 7, purpose: Purpose::Ring });
        roundtrip(WireMsg::Hello { rank: 0, purpose: Purpose::Star });
        roundtrip(WireMsg::Indices(vec![5, 1, 5, 0])); // codec-level: duplicates frame fine
        roundtrip(WireMsg::Indices(vec![]));
    }

    #[test]
    fn bucket_tags_survive_the_wire() {
        for bucket in [0u32, 1, 42, u32::MAX] {
            let frame = encode(&WireMsg::DenseChunk { bucket, vals: vec![1.0] });
            match decode_body(&frame[4..]).unwrap() {
                WireMsg::DenseChunk { bucket: got, .. } => assert_eq!(got, bucket),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn f32_payloads_are_bit_exact() {
        let vals = vec![f32::NAN, -0.0, 1e-42, f32::INFINITY];
        let frame = encode(&WireMsg::DenseChunk { bucket: 3, vals: vals.clone() });
        match decode_body(&frame[4..]).unwrap() {
            WireMsg::DenseChunk { bucket, vals: got } => {
                assert_eq!(bucket, 3);
                for (a, b) in vals.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn read_write_through_a_byte_stream() {
        let msgs = vec![
            WireMsg::Indices(vec![1, 2, 3]),
            WireMsg::DenseChunk { bucket: 1, vals: vec![0.25; 7] },
            WireMsg::Hello { rank: 3, purpose: Purpose::Star },
        ];
        let mut stream = Vec::new();
        for m in &msgs {
            write_msg(&mut stream, m).unwrap();
        }
        let mut r = stream.as_slice();
        for m in &msgs {
            assert_eq!(&read_msg(&mut r).unwrap(), m);
        }
        assert!(read_msg(&mut r).is_err(), "clean EOF is an error, not a hang");
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut frame = u32::MAX.to_le_bytes().to_vec();
        frame.push(TAG_INDICES);
        let mut d = FrameDecoder::new();
        let err = d.push(&frame).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        assert!(read_msg(&mut frame.as_slice()).is_err());
    }

    #[test]
    fn zero_length_body_rejected() {
        let frame = 0u32.to_le_bytes();
        assert!(FrameDecoder::new().push(&frame).is_err());
    }

    #[test]
    fn mismatched_counts_rejected() {
        // dense count says 4 elements but body carries 1
        let mut body = vec![TAG_DENSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.extend_from_slice(&4u32.to_le_bytes());
        body.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_body(&body).is_err());
        // a dense frame truncated before the count field
        let mut body = vec![TAG_DENSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket only
        assert!(decode_body(&body).is_err());
        // trailing garbage after a complete message
        let mut body = vec![TAG_INDICES];
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(0xFF);
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn malformed_sparse_rejected() {
        // unsorted indices
        let mut body = vec![TAG_SPARSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.extend_from_slice(&8u32.to_le_bytes()); // dim
        body.extend_from_slice(&2u32.to_le_bytes()); // nnz
        for i in [3u32, 1] {
            body.extend_from_slice(&i.to_le_bytes());
        }
        for v in [1.0f32, 2.0] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        assert!(decode_body(&body).is_err());
        // index out of range for dim
        let mut body = vec![TAG_SPARSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.extend_from_slice(&2u32.to_le_bytes()); // dim
        body.extend_from_slice(&1u32.to_le_bytes()); // nnz
        body.extend_from_slice(&5u32.to_le_bytes());
        body.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn split_reads_reassemble() {
        let frame = encode(&WireMsg::Indices((0..50).collect()));
        for cut in 0..frame.len() {
            let mut d = FrameDecoder::new();
            let first = d.push(&frame[..cut]).unwrap();
            assert!(first.is_empty(), "cut={cut}: partial frame must not yield");
            let second = d.push(&frame[cut..]).unwrap();
            assert_eq!(second.len(), 1, "cut={cut}");
        }
    }
}
