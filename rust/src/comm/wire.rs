//! Length-prefixed binary framing for the socket transport.
//!
//! Every message on a ScaleCom socket is one **frame**:
//!
//! ```text
//! [ u32 LE: body length ][ body ]
//! body = [ u8 tag ][ tag-specific fields, all little-endian ]
//! ```
//!
//! | tag | message         | fields                                                          |
//! |-----|-----------------|-----------------------------------------------------------------|
//! | 1   | `DenseChunk`    | u32 bucket, u32 count, count × f32                              |
//! | 2   | `Sparse`        | u32 bucket, u32 dim, u32 nnz, nnz × u32 idx, nnz × f32 vals     |
//! | 3   | `Hello`         | u32 rank, u8 purpose (0 = ring, 1 = star), u8 codec version     |
//! | 4   | `Indices`       | u32 count, count × u32                                          |
//! | 5   | packed `Sparse` | u32 bucket, varint dim, varint nnz, delta+varint idx, nnz × f32 |
//! | 6   | packed `Indices`| varint count, delta+varint idx                                  |
//! | 7   | compressed body | u8 algo, varint raw_len, compressed inner body (tags 1-6)       |
//! | 8   | `Ping`          | u32 seq                                                         |
//! | 9   | `Pong`          | u32 seq                                                         |
//! | 10  | `Resume`        | u32 rank, u64 step                                              |
//! | 11  | `DenseChunkLvl` | u8 level, u32 bucket, u32 count, count × f32                    |
//! | 12  | `JobChunk`      | u32 job, u8 level, u32 bucket, u32 count, count × f32           |
//! | 13  | `JobSparse`     | u32 job, u32 bucket, u32 dim, u32 nnz, nnz × u32, nnz × f32     |
//! | 14  | `SubmitJob`     | u32 len, len × u8 (UTF-8 job spec)                              |
//! | 15  | `JobAccepted`   | u32 job, u32 queue_pos                                          |
//! | 16  | `JobRejected`   | u32 len, len × u8 (UTF-8 reason)                                |
//! | 17  | `JobProgress`   | u32 job, u32 step, u32 total                                    |
//! | 18  | `JobDone`       | u32 job, u32 len, len × u8 (UTF-8 digest)                       |
//! | 19  | `QueryStats`    | u8 what (0 = summary, 1 = job table)                            |
//! | 20  | `StatsReport`   | u32 len, len × u8 (UTF-8 report)                                |
//! | 21  | `CancelJob`     | u32 job                                                         |
//! | 22  | `JobCancelled`  | u32 job, u8 outcome (0 = dequeued, 1 = signalled)               |
//!
//! Tags 5-7 are the **entropy stage** (`comm::codec`, wire codec v2):
//! sparse index sets are strictly increasing by construction, so they
//! ship as delta+varints, and any body may additionally travel through
//! the in-house byte compressor when that makes it *smaller*. The
//! compressed envelope (tag 7) declares its decompressed size up front;
//! it may not nest. `Hello` now carries the sender's wire codec version
//! ([`WIRE_CODEC_VERSION`]) so a rendezvous can reject a peer too old to
//! decode packed frames with a clear error instead of a mid-run decode
//! fault; a 5-byte legacy `Hello` (no version field) decodes as v1.
//!
//! Tags 8-10 are the **liveness/recovery control plane** (wire codec
//! v3): `Ping`/`Pong` carry the heartbeat that bounds dead-peer
//! detection, and `Resume` circulates each survivor's newest snapshot
//! step around a re-formed ring so every node rolls back to the global
//! minimum before replaying. Control frames are tiny and latency-bound,
//! so — like `Hello` — they are never packed or byte-compressed.
//!
//! Tag 11 is the **hierarchy level tag** (wire codec v4): the two-level
//! ring-of-rings runs an intra-group ring and an inter-group leader ring
//! over the uplink, and `DenseChunkLvl` stamps a level id next to the
//! bucket id so the two streams can never be confused for one another —
//! a mis-wired mesh is detected at the first frame. Level 0 (intra-group
//! and flat-ring traffic) keeps shipping as the legacy `DenseChunk`
//! (tag 1), byte-identical to v3 builds; only uplink frames (level >= 1)
//! wear the new tag, so a flat ring's wire bytes are unchanged. `Hello`
//! gains the `uplink` purpose byte (2) to classify leader-ring
//! rendezvous connections.
//!
//! Tags 12-22 are the **multi-tenant serve plane** (wire codec v5).
//! `JobChunk`/`JobSparse` are the payload frames of shared comm lanes:
//! like the bucket and level tags before them, they stamp a **job id**
//! on every frame of a collective so two jobs multiplexed onto one lane
//! mesh can never have their streams confused — a frame wearing the
//! wrong job id is a mis-framed stream, rejected at frame one. Job id 0
//! (single-tenant traffic) keeps the legacy framing byte-for-byte, so
//! every pre-serve wire byte is unchanged. Tags 14-22 are the client
//! control protocol of `scalecom serve` (submit/progress/stats/cancel);
//! like every control frame they are tiny, latency-bound, and never
//! packed or byte-compressed. `Hello` gains the `client` purpose byte
//! (3); a serve daemon rejects clients older than v5 at the handshake.
//!
//! `DenseChunk` carries the ring reduce-scatter/all-gather payloads,
//! `Sparse` the star-gather contributions, and the control tags the
//! rendezvous handshake plus the CLT-k leader's index broadcast. Both
//! payload frames lead with a **bucket id**: the bucketed exchange
//! (`comm::bucket`) keeps several per-bucket collectives in flight on
//! one stream, and the tag lets a receiver verify that an arriving chunk
//! belongs to the collective it is executing — a mismatch is a
//! mis-framed stream (peer out of sync), detected at the first frame
//! instead of silently reducing bucket b's values into bucket b+1's.
//! Monolithic (un-bucketed) collectives use bucket id 0. There
//! is deliberately no shutdown message: an orderly end of run is a
//! flushed socket close, observed by the peer as EOF. f32/f64 values
//! travel as raw IEEE-754 bits — in packed and compressed frames too —
//! so a value is **bit-identical** after a network hop and the backend
//! determinism contract survives the wire.
//!
//! ## Decode-under-adversity contract
//!
//! A TCP stream can deliver any byte split and any garbage; decoding must
//! never panic, over-allocate, or mis-frame:
//!
//! - the frame header is validated before any allocation: a body length
//!   of 0 or more than [`MAX_FRAME_BYTES`] is rejected;
//! - field counts are checked (in u64, overflow-proof) against the exact
//!   body length — short *and* trailing bytes are both errors;
//! - sparse payloads are only accepted when the index set is strictly
//!   increasing and in-range, so `SparseGrad`'s invariants hold even for
//!   bytes from a hostile or corrupted peer (in packed frames the delta
//!   representation makes strict increase structural);
//! - a compressed envelope's declared decompressed size is capped at
//!   [`MAX_FRAME_BYTES`] **before** any allocation, and the decompressor
//!   enforces it exactly — a "zip bomb" length field cannot force a huge
//!   allocation, and nesting envelopes is rejected;
//! - [`FrameDecoder`] buffers partial reads, yielding a message only
//!   once its full frame has arrived — a split read at any byte boundary
//!   decodes identically to a single read (property-tested in
//!   `crate::proptest`).

use crate::comm::codec;
use crate::compress::SparseGrad;
use std::io::{Read, Write};

/// Upper bound on a frame body. Generous for this workload (a dense
/// 1M-parameter f32 gradient is 4 MB) while keeping a corrupted or
/// hostile length field from forcing a huge allocation. Also caps the
/// *declared decompressed size* of a compressed envelope.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Wire codec version spoken by this build, carried in `Hello`. v1 is
/// the raw tag set (1-4); v2 adds the packed/compressed tags (5-7); v3
/// adds the liveness/recovery control tags (8-10); v4 adds the
/// hierarchy level tag (11) and the `uplink` Hello purpose; v5 adds the
/// job-tagged payload frames (12-13), the serve client protocol
/// (14-22), and the `client` Hello purpose. No bump changes the byte
/// layout of an older tag, so `off`-mode flat-ring frames remain
/// byte-identical to v1 builds.
pub const WIRE_CODEC_VERSION: u8 = 5;

/// What an inbound connection is for (field of [`WireMsg::Hello`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Purpose {
    /// The peer is our left ring neighbor; this stream carries chunks.
    Ring,
    /// The peer is a star worker; this stream carries sparse gathers.
    Star,
    /// The peer is our left neighbor on the inter-group leader ring
    /// (v4); this stream carries level-tagged uplink chunks.
    Uplink,
    /// The peer is a serve client (v5); this stream carries the job
    /// submit/progress/stats control protocol, never collectives.
    Client,
}

impl Purpose {
    fn to_byte(self) -> u8 {
        match self {
            Purpose::Ring => 0,
            Purpose::Star => 1,
            Purpose::Uplink => 2,
            Purpose::Client => 3,
        }
    }

    fn from_byte(b: u8) -> anyhow::Result<Purpose> {
        match b {
            0 => Ok(Purpose::Ring),
            1 => Ok(Purpose::Star),
            2 => Ok(Purpose::Uplink),
            3 => Ok(Purpose::Client),
            other => anyhow::bail!("wire: unknown Hello purpose byte {other}"),
        }
    }
}

/// One framed message.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// A ring hop's dense f32 payload (one reduce-scatter or all-gather
    /// chunk, or a broadcast segment), tagged with the bucket it belongs
    /// to (0 for monolithic collectives).
    DenseChunk { bucket: u32, vals: Vec<f32> },
    /// A star worker's sparsified contribution, bucket-tagged like
    /// [`WireMsg::DenseChunk`].
    Sparse { bucket: u32, grad: SparseGrad },
    /// Rendezvous handshake: sent once by the connecting side so the
    /// accepting side can classify the stream and check codec
    /// compatibility. `codec` is the sender's [`WIRE_CODEC_VERSION`]
    /// (1 for legacy peers that predate the field).
    Hello { rank: u32, purpose: Purpose, codec: u8 },
    /// The CLT-k leader's index broadcast.
    Indices(Vec<u32>),
    /// Heartbeat probe (v3). The sender's liveness monitor expects the
    /// matching [`WireMsg::Pong`] within its detection window.
    Ping { seq: u32 },
    /// Heartbeat reply (v3), echoing the probe's sequence number.
    Pong { seq: u32 },
    /// Recovery handshake (v3): after a re-rendezvous each node
    /// announces the newest step it can resume from (its latest
    /// error-feedback snapshot); the ring min-reduces these so everyone
    /// replays from the same global step.
    Resume { rank: u32, step: u64 },
    /// A hierarchical ring hop's dense payload (v4): like
    /// [`WireMsg::DenseChunk`] but stamped with the topology level it
    /// belongs to (1 = the inter-group leader ring over the uplink).
    /// Level-0 traffic uses the legacy tag so flat rings stay
    /// byte-identical across the version bump.
    DenseChunkLvl { level: u8, bucket: u32, vals: Vec<f32> },
    /// A ring hop's dense payload on a **multi-tenant** lane mesh (v5):
    /// like [`WireMsg::DenseChunkLvl`] but additionally stamped with the
    /// id of the serve job whose collective it belongs to (job >= 1; job
    /// 0 keeps the legacy tags so single-tenant wire bytes never change).
    /// A receiver executing job J rejects any other job's frame — the
    /// same mis-framed-stream contract as the bucket and level tags.
    JobChunk { job: u32, level: u8, bucket: u32, vals: Vec<f32> },
    /// A star worker's sparse contribution on a multi-tenant lane mesh
    /// (v5), job-stamped like [`WireMsg::JobChunk`].
    JobSparse { job: u32, bucket: u32, grad: SparseGrad },
    /// Serve control (v5): a client's job submission. The spec travels
    /// as the canonical `key=value` text form of `serve::JobSpec`.
    SubmitJob { spec: String },
    /// Serve control (v5): admission granted — the assigned job id and
    /// the queue position at admission time (0 = dispatches next).
    JobAccepted { job: u32, queue_pos: u32 },
    /// Serve control (v5): admission denied, with the typed reason's
    /// rendered text (queue full, invalid spec, draining, ...).
    JobRejected { reason: String },
    /// Serve control (v5): streamed per-step progress of a running job.
    JobProgress { job: u32, step: u32, total: u32 },
    /// Serve control (v5): terminal frame of a submit stream — the job
    /// finished and this is its full parity digest text.
    JobDone { job: u32, digest: String },
    /// Serve control (v5): a stats query (`what` 0 = daemon summary,
    /// 1 = the per-job table).
    QueryStats { what: u8 },
    /// Serve control (v5): the daemon's rendered reply to
    /// [`WireMsg::QueryStats`].
    StatsReport { text: String },
    /// Serve control (v5): cancel a queued or running job.
    CancelJob { job: u32 },
    /// Serve control (v5): cancellation acknowledged — `outcome` 0 means
    /// the job was still queued and was dequeued, 1 means a running job
    /// was signalled and will stop at its next step boundary.
    JobCancelled { job: u32, outcome: u8 },
}

const TAG_DENSE: u8 = 1;
const TAG_SPARSE: u8 = 2;
const TAG_HELLO: u8 = 3;
const TAG_INDICES: u8 = 4;
const TAG_SPARSE_PACKED: u8 = 5;
const TAG_INDICES_PACKED: u8 = 6;
pub(crate) const TAG_COMPRESSED: u8 = 7;
const TAG_PING: u8 = 8;
const TAG_PONG: u8 = 9;
const TAG_RESUME: u8 = 10;
const TAG_DENSE_LVL: u8 = 11;
const TAG_JOB_DENSE: u8 = 12;
const TAG_JOB_SPARSE: u8 = 13;
const TAG_SUBMIT_JOB: u8 = 14;
const TAG_JOB_ACCEPTED: u8 = 15;
const TAG_JOB_REJECTED: u8 = 16;
const TAG_JOB_PROGRESS: u8 = 17;
const TAG_JOB_DONE: u8 = 18;
const TAG_QUERY_STATS: u8 = 19;
const TAG_STATS_REPORT: u8 = 20;
const TAG_CANCEL_JOB: u8 = 21;
const TAG_JOB_CANCELLED: u8 = 22;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bulk little-endian append of an f32 slice: one reserve plus chunked
/// copies through a stack buffer instead of a per-element push loop —
/// dense ring chunks are multi-MB, and this is their hot path. Output
/// is byte-identical to the per-element loop (locked by a golden test).
fn put_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    out.reserve(vals.len() * 4);
    let mut tmp = [0u8; 4 * 256];
    for chunk in vals.chunks(256) {
        for (i, v) in chunk.iter().enumerate() {
            tmp[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&tmp[..chunk.len() * 4]);
    }
}

/// Bulk little-endian append of a u32 slice (see [`put_f32s`]).
fn put_u32s(out: &mut Vec<u8>, vals: &[u32]) {
    out.reserve(vals.len() * 4);
    let mut tmp = [0u8; 4 * 256];
    for chunk in vals.chunks(256) {
        for (i, v) in chunk.iter().enumerate() {
            tmp[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&tmp[..chunk.len() * 4]);
    }
}

/// Exact frame size (header + body) of `msg` in the **raw** (v1, tags
/// 1-4) representation — the layout [`encode`] emits. Packed frames are
/// variable-length; their size is whatever `FrameCodec` produced.
/// `encode(msg).len() == frame_len(msg)` is property-tested across all
/// variants so this can never silently drift from the encoder again.
pub fn frame_len(msg: &WireMsg) -> usize {
    4 + 1
        + match msg {
            WireMsg::DenseChunk { vals, .. } => 8 + 4 * vals.len(),
            WireMsg::DenseChunkLvl { vals, .. } => 9 + 4 * vals.len(),
            WireMsg::Sparse { grad, .. } => 12 + 8 * grad.indices.len(),
            WireMsg::Hello { .. } => 6,
            WireMsg::Indices(idx) => 4 + 4 * idx.len(),
            WireMsg::Ping { .. } | WireMsg::Pong { .. } => 4,
            WireMsg::Resume { .. } => 12,
            WireMsg::JobChunk { vals, .. } => 13 + 4 * vals.len(),
            WireMsg::JobSparse { grad, .. } => 16 + 8 * grad.indices.len(),
            WireMsg::SubmitJob { spec } => 4 + spec.len(),
            WireMsg::JobAccepted { .. } => 8,
            WireMsg::JobRejected { reason } => 4 + reason.len(),
            WireMsg::JobProgress { .. } => 12,
            WireMsg::JobDone { digest, .. } => 8 + digest.len(),
            WireMsg::QueryStats { .. } => 1,
            WireMsg::StatsReport { text } => 4 + text.len(),
            WireMsg::CancelJob { .. } => 4,
            WireMsg::JobCancelled { .. } => 5,
        }
}

/// Length-prefixed UTF-8 string field (serve control frames).
fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Append `msg`'s body (tag + fields, no length header) to `out`.
/// With `packing`, sparse/index frames use the delta+varint tags when
/// representable (index broadcasts fall back to raw when not strictly
/// increasing). Returns whether a packed representation was used.
pub(crate) fn encode_body_into(msg: &WireMsg, packing: bool, out: &mut Vec<u8>) -> bool {
    match msg {
        WireMsg::DenseChunk { bucket, vals } => {
            out.push(TAG_DENSE);
            put_u32(out, *bucket);
            put_u32(out, vals.len() as u32);
            put_f32s(out, vals);
            false
        }
        WireMsg::DenseChunkLvl { level, bucket, vals } => {
            out.push(TAG_DENSE_LVL);
            out.push(*level);
            put_u32(out, *bucket);
            put_u32(out, vals.len() as u32);
            put_f32s(out, vals);
            false
        }
        WireMsg::Sparse { bucket, grad } if packing => {
            out.push(TAG_SPARSE_PACKED);
            put_u32(out, *bucket);
            codec::put_varint_u32(out, grad.dim as u32);
            codec::put_varint_u32(out, grad.indices.len() as u32);
            codec::put_index_deltas(out, &grad.indices);
            put_f32s(out, &grad.values);
            true
        }
        WireMsg::Sparse { bucket, grad } => {
            out.push(TAG_SPARSE);
            put_u32(out, *bucket);
            put_u32(out, grad.dim as u32);
            put_u32(out, grad.indices.len() as u32);
            put_u32s(out, &grad.indices);
            put_f32s(out, &grad.values);
            false
        }
        WireMsg::Hello { rank, purpose, codec } => {
            out.push(TAG_HELLO);
            put_u32(out, *rank);
            out.push(purpose.to_byte());
            out.push(*codec);
            false
        }
        WireMsg::Indices(idx) if packing && codec::strictly_increasing(idx) => {
            out.push(TAG_INDICES_PACKED);
            codec::put_varint_u32(out, idx.len() as u32);
            codec::put_index_deltas(out, idx);
            true
        }
        WireMsg::Indices(idx) => {
            out.push(TAG_INDICES);
            put_u32(out, idx.len() as u32);
            put_u32s(out, idx);
            false
        }
        WireMsg::Ping { seq } => {
            out.push(TAG_PING);
            put_u32(out, *seq);
            false
        }
        WireMsg::Pong { seq } => {
            out.push(TAG_PONG);
            put_u32(out, *seq);
            false
        }
        WireMsg::Resume { rank, step } => {
            out.push(TAG_RESUME);
            put_u32(out, *rank);
            out.extend_from_slice(&step.to_le_bytes());
            false
        }
        WireMsg::JobChunk { job, level, bucket, vals } => {
            out.push(TAG_JOB_DENSE);
            put_u32(out, *job);
            out.push(*level);
            put_u32(out, *bucket);
            put_u32(out, vals.len() as u32);
            put_f32s(out, vals);
            false
        }
        WireMsg::JobSparse { job, bucket, grad } => {
            out.push(TAG_JOB_SPARSE);
            put_u32(out, *job);
            put_u32(out, *bucket);
            put_u32(out, grad.dim as u32);
            put_u32(out, grad.indices.len() as u32);
            put_u32s(out, &grad.indices);
            put_f32s(out, &grad.values);
            false
        }
        WireMsg::SubmitJob { spec } => {
            out.push(TAG_SUBMIT_JOB);
            put_str(out, spec);
            false
        }
        WireMsg::JobAccepted { job, queue_pos } => {
            out.push(TAG_JOB_ACCEPTED);
            put_u32(out, *job);
            put_u32(out, *queue_pos);
            false
        }
        WireMsg::JobRejected { reason } => {
            out.push(TAG_JOB_REJECTED);
            put_str(out, reason);
            false
        }
        WireMsg::JobProgress { job, step, total } => {
            out.push(TAG_JOB_PROGRESS);
            put_u32(out, *job);
            put_u32(out, *step);
            put_u32(out, *total);
            false
        }
        WireMsg::JobDone { job, digest } => {
            out.push(TAG_JOB_DONE);
            put_u32(out, *job);
            put_str(out, digest);
            false
        }
        WireMsg::QueryStats { what } => {
            out.push(TAG_QUERY_STATS);
            out.push(*what);
            false
        }
        WireMsg::StatsReport { text } => {
            out.push(TAG_STATS_REPORT);
            put_str(out, text);
            false
        }
        WireMsg::CancelJob { job } => {
            out.push(TAG_CANCEL_JOB);
            put_u32(out, *job);
            false
        }
        WireMsg::JobCancelled { job, outcome } => {
            out.push(TAG_JOB_CANCELLED);
            put_u32(out, *job);
            out.push(*outcome);
            false
        }
    }
}

/// Encode `msg` as one full **raw** frame (header + v1 body),
/// preallocated exactly (dense ring chunks are multi-MB on big models —
/// no regrowth copies on the hot path). Packed/compressed encoding goes
/// through `codec::FrameCodec`, which also pools the output buffer.
pub fn encode(msg: &WireMsg) -> Vec<u8> {
    let mut out = Vec::with_capacity(frame_len(msg));
    out.extend_from_slice(&[0u8; 4]); // header patched below
    encode_body_into(msg, false, &mut out);
    let body_len = (out.len() - 4) as u32;
    out[..4].copy_from_slice(&body_len.to_le_bytes());
    out
}

/// Cursor over a frame body with checked little-endian reads.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "wire: truncated body (need {n} more bytes at offset {}, body is {})",
                    self.pos,
                    self.buf.len()
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn varint(&mut self) -> anyhow::Result<u32> {
        codec::read_varint_u32(self.buf, &mut self.pos)
    }

    fn index_deltas(&mut self, count: usize) -> anyhow::Result<Vec<u32>> {
        codec::read_index_deltas(self.buf, &mut self.pos, count)
    }

    /// Bulk-read `count` little-endian u32s (one bounds check, not one
    /// per element — ring payloads are hot-path, up to millions long).
    fn u32s(&mut self, count: usize) -> anyhow::Result<Vec<u32>> {
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Bulk-read `count` little-endian f32s.
    fn f32s(&mut self, count: usize) -> anyhow::Result<Vec<f32>> {
        let bytes = self.take(count * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Length-prefixed UTF-8 string field; the length is validated
    /// against the remaining body before any allocation.
    fn str_field(&mut self) -> anyhow::Result<String> {
        let len = self.u32()?;
        let len = check_count(self, len, 1, "string byte")?;
        let bytes = self.take(len)?;
        std::str::from_utf8(bytes)
            .map(|s| s.to_string())
            .map_err(|_| anyhow::anyhow!("wire: string field is not valid UTF-8"))
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "wire: {} trailing bytes after message",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// Check, before allocating, that a `count`-element array of
/// `elem_bytes`-byte elements can still fit in what remains of the body.
fn check_count(c: &Cursor<'_>, count: u32, elem_bytes: u64, what: &str) -> anyhow::Result<usize> {
    let need = count as u64 * elem_bytes;
    let have = (c.buf.len() - c.pos) as u64;
    anyhow::ensure!(
        need <= have,
        "wire: {what} count {count} needs {need} bytes but body has {have} left"
    );
    Ok(count as usize)
}

fn check_sparse_range(indices: &[u32], dim: usize) -> anyhow::Result<()> {
    if let Some(&last) = indices.last() {
        anyhow::ensure!(
            (last as usize) < dim,
            "wire: sparse index {last} out of range for dim {dim}"
        );
    }
    Ok(())
}

/// Split a compressed envelope (tag 7) into its algorithm, declared
/// decompressed size (validated against [`MAX_FRAME_BYTES`] **before**
/// the caller allocates anything), and compressed payload.
pub(crate) fn split_compressed(body: &[u8]) -> anyhow::Result<(codec::Algo, usize, &[u8])> {
    debug_assert_eq!(body.first(), Some(&TAG_COMPRESSED));
    let mut pos = 1usize;
    let algo_byte = *body
        .get(pos)
        .ok_or_else(|| anyhow::anyhow!("wire: truncated compressed envelope"))?;
    pos += 1;
    let algo = codec::Algo::from_byte(algo_byte)?;
    anyhow::ensure!(
        algo != codec::Algo::Raw,
        "wire: compressed envelope declaring the raw algorithm"
    );
    let raw_len = codec::read_varint_u32(body, &mut pos)? as usize;
    anyhow::ensure!(raw_len >= 1, "wire: compressed envelope declares an empty body");
    anyhow::ensure!(
        raw_len <= MAX_FRAME_BYTES,
        "wire: compressed envelope declares {raw_len} decompressed bytes, \
         over the {MAX_FRAME_BYTES}-byte cap"
    );
    Ok((algo, raw_len, &body[pos..]))
}

/// Decode one non-compressed frame body (tags 1-6). A compressed
/// envelope is rejected here — it may not nest; [`decode_body`] and
/// `FrameCodec::decode_body` unwrap exactly one layer.
pub(crate) fn decode_body_uncompressed(body: &[u8]) -> anyhow::Result<WireMsg> {
    let mut c = Cursor { buf: body, pos: 0 };
    let tag = c.u8()?;
    let msg = match tag {
        TAG_DENSE => {
            let bucket = c.u32()?;
            let count = c.u32()?;
            let count = check_count(&c, count, 4, "dense element")?;
            let vals = c.f32s(count)?;
            c.done()?;
            WireMsg::DenseChunk { bucket, vals }
        }
        TAG_DENSE_LVL => {
            let level = c.u8()?;
            let bucket = c.u32()?;
            let count = c.u32()?;
            let count = check_count(&c, count, 4, "dense element")?;
            let vals = c.f32s(count)?;
            c.done()?;
            WireMsg::DenseChunkLvl { level, bucket, vals }
        }
        TAG_SPARSE => {
            let bucket = c.u32()?;
            let dim = c.u32()? as usize;
            let nnz = c.u32()?;
            let nnz = check_count(&c, nnz, 8, "sparse nnz")?;
            let indices = c.u32s(nnz)?;
            let values = c.f32s(nnz)?;
            c.done()?;
            anyhow::ensure!(
                codec::strictly_increasing(&indices),
                "wire: sparse indices must be strictly increasing"
            );
            check_sparse_range(&indices, dim)?;
            WireMsg::Sparse {
                bucket,
                grad: SparseGrad::new(dim, indices, values),
            }
        }
        TAG_HELLO => {
            let rank = c.u32()?;
            let purpose = Purpose::from_byte(c.u8()?)?;
            // v1 peers predate the version field; classify them as v1
            let codec_version = if c.pos == c.buf.len() { 1 } else { c.u8()? };
            c.done()?;
            WireMsg::Hello { rank, purpose, codec: codec_version }
        }
        TAG_INDICES => {
            let n = c.u32()?;
            let n = check_count(&c, n, 4, "index")?;
            let idx = c.u32s(n)?;
            c.done()?;
            WireMsg::Indices(idx)
        }
        TAG_SPARSE_PACKED => {
            let bucket = c.u32()?;
            let dim = c.varint()? as usize;
            let nnz = c.varint()?;
            // every packed index costs >= 1 byte plus its 4-byte value
            let nnz = check_count(&c, nnz, 5, "packed sparse nnz")?;
            let indices = c.index_deltas(nnz)?;
            let values = c.f32s(nnz)?;
            c.done()?;
            check_sparse_range(&indices, dim)?;
            WireMsg::Sparse {
                bucket,
                grad: SparseGrad::new(dim, indices, values),
            }
        }
        TAG_INDICES_PACKED => {
            let n = c.varint()?;
            let n = check_count(&c, n, 1, "packed index")?;
            let idx = c.index_deltas(n)?;
            c.done()?;
            WireMsg::Indices(idx)
        }
        TAG_PING => {
            let seq = c.u32()?;
            c.done()?;
            WireMsg::Ping { seq }
        }
        TAG_PONG => {
            let seq = c.u32()?;
            c.done()?;
            WireMsg::Pong { seq }
        }
        TAG_RESUME => {
            let rank = c.u32()?;
            let step = c.u64()?;
            c.done()?;
            WireMsg::Resume { rank, step }
        }
        TAG_JOB_DENSE => {
            let job = c.u32()?;
            let level = c.u8()?;
            let bucket = c.u32()?;
            let count = c.u32()?;
            let count = check_count(&c, count, 4, "dense element")?;
            let vals = c.f32s(count)?;
            c.done()?;
            WireMsg::JobChunk { job, level, bucket, vals }
        }
        TAG_JOB_SPARSE => {
            let job = c.u32()?;
            let bucket = c.u32()?;
            let dim = c.u32()? as usize;
            let nnz = c.u32()?;
            let nnz = check_count(&c, nnz, 8, "sparse nnz")?;
            let indices = c.u32s(nnz)?;
            let values = c.f32s(nnz)?;
            c.done()?;
            anyhow::ensure!(
                codec::strictly_increasing(&indices),
                "wire: sparse indices must be strictly increasing"
            );
            check_sparse_range(&indices, dim)?;
            WireMsg::JobSparse {
                job,
                bucket,
                grad: SparseGrad::new(dim, indices, values),
            }
        }
        TAG_SUBMIT_JOB => {
            let spec = c.str_field()?;
            c.done()?;
            WireMsg::SubmitJob { spec }
        }
        TAG_JOB_ACCEPTED => {
            let job = c.u32()?;
            let queue_pos = c.u32()?;
            c.done()?;
            WireMsg::JobAccepted { job, queue_pos }
        }
        TAG_JOB_REJECTED => {
            let reason = c.str_field()?;
            c.done()?;
            WireMsg::JobRejected { reason }
        }
        TAG_JOB_PROGRESS => {
            let job = c.u32()?;
            let step = c.u32()?;
            let total = c.u32()?;
            c.done()?;
            WireMsg::JobProgress { job, step, total }
        }
        TAG_JOB_DONE => {
            let job = c.u32()?;
            let digest = c.str_field()?;
            c.done()?;
            WireMsg::JobDone { job, digest }
        }
        TAG_QUERY_STATS => {
            let what = c.u8()?;
            c.done()?;
            WireMsg::QueryStats { what }
        }
        TAG_STATS_REPORT => {
            let text = c.str_field()?;
            c.done()?;
            WireMsg::StatsReport { text }
        }
        TAG_CANCEL_JOB => {
            let job = c.u32()?;
            c.done()?;
            WireMsg::CancelJob { job }
        }
        TAG_JOB_CANCELLED => {
            let job = c.u32()?;
            let outcome = c.u8()?;
            c.done()?;
            WireMsg::JobCancelled { job, outcome }
        }
        TAG_COMPRESSED => anyhow::bail!("wire: nested compressed frame"),
        other => anyhow::bail!("wire: unknown message tag {other}"),
    };
    Ok(msg)
}

/// Decode one frame body (everything after the 4-byte length header),
/// unwrapping a compressed envelope if present. Convenience path that
/// stages decompression through a fresh buffer; the socket hot path
/// goes through `codec::FrameCodec::decode_body`, which pools it.
pub fn decode_body(body: &[u8]) -> anyhow::Result<WireMsg> {
    if body.first() == Some(&TAG_COMPRESSED) {
        let (_algo, raw_len, payload) = split_compressed(body)?;
        let mut staged = Vec::new();
        codec::lz_decompress_into(payload, &mut staged, raw_len)?;
        return decode_body_uncompressed(&staged);
    }
    decode_body_uncompressed(body)
}

/// Validate a frame header's body length.
pub(crate) fn check_body_len(len: u32) -> anyhow::Result<usize> {
    let len = len as usize;
    anyhow::ensure!(len >= 1, "wire: empty frame body");
    anyhow::ensure!(
        len <= MAX_FRAME_BYTES,
        "wire: frame body of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte cap"
    );
    Ok(len)
}

/// Write one framed message (no flush — callers own buffering policy).
/// The sender enforces the same [`MAX_FRAME_BYTES`] cap the receiver
/// does, so an oversized payload (e.g. a huge `--dim`) fails HERE with a
/// clear config error instead of surfacing on the peer as a misleading
/// "mis-framed stream" fault.
pub fn write_msg<W: Write>(w: &mut W, msg: &WireMsg) -> anyhow::Result<()> {
    let frame = encode(msg);
    anyhow::ensure!(
        frame.len() - 4 <= MAX_FRAME_BYTES,
        "outgoing frame body of {} bytes exceeds the {MAX_FRAME_BYTES}-byte wire cap \
         (payload too large for one frame — lower the dimension or chunk it)",
        frame.len() - 4
    );
    w.write_all(&frame)?;
    Ok(())
}

/// Read one framed message with blocking, exact-length reads.
pub fn read_msg<R: Read>(r: &mut R) -> anyhow::Result<WireMsg> {
    let mut header = [0u8; 4];
    r.read_exact(&mut header)?;
    let len = check_body_len(u32::from_le_bytes(header))?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body)
}

/// Incremental decoder for arbitrarily split reads: feed whatever bytes
/// arrived, collect whole messages. Frames split at any byte boundary —
/// inside the header, inside the body — reassemble identically.
///
/// After an error the stream is mis-framed beyond recovery; drop the
/// decoder (and the connection).
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Bytes buffered but not yet framed (for diagnostics).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    pub fn push(&mut self, bytes: &[u8]) -> anyhow::Result<Vec<WireMsg>> {
        self.push_frames(bytes)?
            .iter()
            .map(|body| decode_body(body))
            .collect()
    }

    /// Like [`FrameDecoder::push`], but yields whole frame **bodies**
    /// without decoding them — callers that own a pooled
    /// `codec::FrameCodec` (the heartbeat reader thread) decode through
    /// it so stats and staging buffers behave like the blocking path.
    pub fn push_frames(&mut self, bytes: &[u8]) -> anyhow::Result<Vec<Vec<u8>>> {
        self.buf.extend_from_slice(bytes);
        let mut out = Vec::new();
        loop {
            if self.buf.len() < 4 {
                break;
            }
            let len = check_body_len(u32::from_le_bytes([
                self.buf[0],
                self.buf[1],
                self.buf[2],
                self.buf[3],
            ]))?;
            if self.buf.len() < 4 + len {
                break;
            }
            out.push(self.buf[4..4 + len].to_vec());
            self.buf.drain(..4 + len);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::{
        Algo, AlgoChoice, CodecStats, FrameCodec, WireCodecConfig, WireCompression,
    };

    fn roundtrip(msg: WireMsg) {
        let frame = encode(&msg);
        let len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
        assert_eq!(len + 4, frame.len(), "header length must cover the body");
        assert_eq!(frame.len(), frame_len(&msg), "frame_len must match encode");
        assert_eq!(decode_body(&frame[4..]).unwrap(), msg);
        // and through the incremental decoder
        let mut d = FrameDecoder::new();
        assert_eq!(d.push(&frame).unwrap(), vec![msg]);
        assert_eq!(d.pending(), 0);
    }

    fn hello(rank: u32, purpose: Purpose) -> WireMsg {
        WireMsg::Hello { rank, purpose, codec: WIRE_CODEC_VERSION }
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(WireMsg::DenseChunk { bucket: 0, vals: vec![] });
        roundtrip(WireMsg::DenseChunk {
            bucket: 7,
            vals: vec![1.5, -0.0, f32::MIN, f32::MAX],
        });
        roundtrip(WireMsg::Sparse {
            bucket: 0,
            grad: SparseGrad::new(10, vec![0, 3, 9], vec![1.0, -2.0, 0.5]),
        });
        roundtrip(WireMsg::Sparse {
            bucket: u32::MAX,
            grad: SparseGrad::new(0, vec![], vec![]),
        });
        roundtrip(hello(7, Purpose::Ring));
        roundtrip(hello(0, Purpose::Star));
        roundtrip(WireMsg::Indices(vec![5, 1, 5, 0])); // codec-level: duplicates frame fine
        roundtrip(WireMsg::Indices(vec![]));
        roundtrip(WireMsg::Ping { seq: 0 });
        roundtrip(WireMsg::Ping { seq: u32::MAX });
        roundtrip(WireMsg::Pong { seq: 12345 });
        roundtrip(WireMsg::Resume { rank: 0, step: 0 });
        roundtrip(WireMsg::Resume { rank: 63, step: u64::MAX });
        roundtrip(hello(2, Purpose::Uplink));
        roundtrip(WireMsg::DenseChunkLvl { level: 1, bucket: 0, vals: vec![] });
        roundtrip(WireMsg::DenseChunkLvl {
            level: u8::MAX,
            bucket: u32::MAX,
            vals: vec![0.5, -1.25],
        });
        roundtrip(hello(9, Purpose::Client));
        roundtrip(WireMsg::JobChunk { job: 1, level: 0, bucket: 3, vals: vec![1.0, -2.5] });
        roundtrip(WireMsg::JobChunk { job: u32::MAX, level: 2, bucket: 0, vals: vec![] });
        roundtrip(WireMsg::JobSparse {
            job: 7,
            bucket: 2,
            grad: SparseGrad::new(16, vec![1, 8, 15], vec![0.5, -1.0, 2.0]),
        });
        roundtrip(WireMsg::SubmitJob { spec: "scheme=scalecom dim=96".into() });
        roundtrip(WireMsg::SubmitJob { spec: String::new() });
        roundtrip(WireMsg::JobAccepted { job: 4, queue_pos: 2 });
        roundtrip(WireMsg::JobRejected { reason: "queue full (depth 8/8)".into() });
        roundtrip(WireMsg::JobProgress { job: 4, step: 17, total: 50 });
        roundtrip(WireMsg::JobDone { job: 4, digest: "digest v1 workers=2\n".into() });
        roundtrip(WireMsg::QueryStats { what: 0 });
        roundtrip(WireMsg::QueryStats { what: 1 });
        roundtrip(WireMsg::StatsReport { text: "jobs: 0 queued".into() });
        roundtrip(WireMsg::CancelJob { job: 9 });
        roundtrip(WireMsg::JobCancelled { job: 9, outcome: 1 });
    }

    #[test]
    fn job_tags_survive_the_wire_and_stay_distinct_from_legacy_frames() {
        for job in [1u32, 42, u32::MAX] {
            let msg = WireMsg::JobChunk { job, level: 0, bucket: 5, vals: vec![3.0; 4] };
            let frame = encode(&msg);
            assert_eq!(frame[4], TAG_JOB_DENSE);
            match decode_body(&frame[4..]).unwrap() {
                WireMsg::JobChunk { job: got, bucket, .. } => {
                    assert_eq!((got, bucket), (job, 5));
                }
                other => panic!("{other:?}"),
            }
        }
        // a job-tagged frame never decodes as a legacy DenseChunk, and a
        // truncated one (missing the count) errors cleanly
        let body = vec![TAG_JOB_DENSE, 1, 0, 0, 0, 0, 0, 0, 0, 0];
        assert!(decode_body(&body).is_err());
        // JobSparse keeps the Sparse invariants: unsorted indices rejected
        let mut body = vec![TAG_JOB_SPARSE];
        body.extend_from_slice(&1u32.to_le_bytes()); // job
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.extend_from_slice(&8u32.to_le_bytes()); // dim
        body.extend_from_slice(&2u32.to_le_bytes()); // nnz
        for i in [3u32, 1] {
            body.extend_from_slice(&i.to_le_bytes());
        }
        for v in [1.0f32, 2.0] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn serve_string_fields_reject_lying_lengths_and_bad_utf8() {
        // declared length outruns the body — caught before allocation
        let mut body = vec![TAG_SUBMIT_JOB];
        body.extend_from_slice(&100u32.to_le_bytes());
        body.extend_from_slice(b"short");
        assert!(decode_body(&body).is_err());
        // invalid UTF-8 payload
        let mut body = vec![TAG_JOB_REJECTED];
        body.extend_from_slice(&2u32.to_le_bytes());
        body.extend_from_slice(&[0xFF, 0xFE]);
        assert!(decode_body(&body).is_err());
        // trailing bytes after a complete control frame
        let mut body = vec![TAG_CANCEL_JOB];
        body.extend_from_slice(&7u32.to_le_bytes());
        body.push(0);
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn serve_control_frames_are_never_compressed_or_packed() {
        let mut codec = FrameCodec::new(
            WireCodecConfig {
                mode: WireCompression::Full,
                min_bytes: 0,
                dense: AlgoChoice::Auto,
                sparse: AlgoChoice::Auto,
            },
            CodecStats::new(),
        );
        let mut frame = Vec::new();
        // even a large, highly compressible control body ships raw
        for (msg, tag) in [
            (WireMsg::SubmitJob { spec: "a".repeat(10_000) }, TAG_SUBMIT_JOB),
            (WireMsg::JobRejected { reason: "b".repeat(10_000) }, TAG_JOB_REJECTED),
            (WireMsg::JobDone { job: 1, digest: "c".repeat(10_000) }, TAG_JOB_DONE),
            (WireMsg::StatsReport { text: "d".repeat(10_000) }, TAG_STATS_REPORT),
            (WireMsg::JobAccepted { job: 1, queue_pos: 0 }, TAG_JOB_ACCEPTED),
            (WireMsg::JobProgress { job: 1, step: 2, total: 3 }, TAG_JOB_PROGRESS),
            (WireMsg::QueryStats { what: 0 }, TAG_QUERY_STATS),
            (WireMsg::CancelJob { job: 1 }, TAG_CANCEL_JOB),
            (WireMsg::JobCancelled { job: 1, outcome: 0 }, TAG_JOB_CANCELLED),
        ] {
            codec.encode_frame_into(&msg, &mut frame).unwrap();
            assert_eq!(frame[4], tag, "control frame must keep its raw tag");
            assert_eq!(decode_body(&frame[4..]).unwrap(), msg);
        }
        // the job-tagged payload frames, by contrast, MAY wear the
        // envelope — they are payload, not control
        let payload = WireMsg::JobChunk { job: 2, level: 0, bucket: 0, vals: vec![1.0; 50_000] };
        codec.encode_frame_into(&payload, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_COMPRESSED, "job payload compresses like dense");
        assert_eq!(decode_body(&frame[4..]).unwrap(), payload);
    }

    #[test]
    fn level_tags_survive_the_wire_and_stay_distinct_from_flat_frames() {
        for level in [0u8, 1, 7] {
            let msg = WireMsg::DenseChunkLvl { level, bucket: 3, vals: vec![2.0; 5] };
            let frame = encode(&msg);
            assert_eq!(frame[4], TAG_DENSE_LVL);
            assert_eq!(frame[5], level, "level byte leads the body");
            match decode_body(&frame[4..]).unwrap() {
                WireMsg::DenseChunkLvl { level: got, bucket, vals } => {
                    assert_eq!((got, bucket, vals.len()), (level, 3, 5));
                }
                other => panic!("{other:?}"),
            }
        }
        // a level-tagged frame never decodes as a flat DenseChunk, and a
        // truncated one (missing the count) errors cleanly
        let body = vec![TAG_DENSE_LVL, 1, 0, 0, 0, 0];
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn control_frames_reject_trailing_or_truncated_bodies() {
        // truncated Ping (2 of 4 seq bytes)
        assert!(decode_body(&[TAG_PING, 1, 2]).is_err());
        // trailing byte after a complete Pong
        let mut body = vec![TAG_PONG];
        body.extend_from_slice(&7u32.to_le_bytes());
        body.push(0);
        assert!(decode_body(&body).is_err());
        // Resume missing its step field
        let mut body = vec![TAG_RESUME];
        body.extend_from_slice(&1u32.to_le_bytes());
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn bucket_tags_survive_the_wire() {
        for bucket in [0u32, 1, 42, u32::MAX] {
            let frame = encode(&WireMsg::DenseChunk { bucket, vals: vec![1.0] });
            match decode_body(&frame[4..]).unwrap() {
                WireMsg::DenseChunk { bucket: got, .. } => assert_eq!(got, bucket),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn f32_payloads_are_bit_exact() {
        let vals = vec![f32::NAN, -0.0, 1e-42, f32::INFINITY];
        let frame = encode(&WireMsg::DenseChunk { bucket: 3, vals: vals.clone() });
        match decode_body(&frame[4..]).unwrap() {
            WireMsg::DenseChunk { bucket, vals: got } => {
                assert_eq!(bucket, 3);
                for (a, b) in vals.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bulk_le_writes_match_the_per_element_golden_path() {
        // the original encoder pushed one value at a time; the chunked
        // bulk path must be byte-identical to that golden layout
        fn golden_encode(msg: &WireMsg) -> Vec<u8> {
            let mut out = vec![0u8; 4];
            match msg {
                WireMsg::DenseChunk { bucket, vals } => {
                    out.push(TAG_DENSE);
                    out.extend_from_slice(&bucket.to_le_bytes());
                    out.extend_from_slice(&(vals.len() as u32).to_le_bytes());
                    for &v in vals {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                WireMsg::Sparse { bucket, grad } => {
                    out.push(TAG_SPARSE);
                    out.extend_from_slice(&bucket.to_le_bytes());
                    out.extend_from_slice(&(grad.dim as u32).to_le_bytes());
                    out.extend_from_slice(&(grad.indices.len() as u32).to_le_bytes());
                    for &i in &grad.indices {
                        out.extend_from_slice(&i.to_le_bytes());
                    }
                    for &v in &grad.values {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                _ => unreachable!(),
            }
            let body_len = (out.len() - 4) as u32;
            out[..4].copy_from_slice(&body_len.to_le_bytes());
            out
        }
        // sizes around the 256-element chunk boundary, plus a big one
        for n in [0usize, 1, 255, 256, 257, 511, 513, 10_000] {
            let vals: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 3.25).collect();
            let msg = WireMsg::DenseChunk { bucket: 9, vals };
            assert_eq!(encode(&msg), golden_encode(&msg), "dense n={n}");
        }
        let grad = SparseGrad::new(
            100_000,
            (0..700u32).map(|i| i * 141).collect(),
            (0..700).map(|i| i as f32 * -0.125).collect(),
        );
        let msg = WireMsg::Sparse { bucket: 2, grad };
        assert_eq!(encode(&msg), golden_encode(&msg), "sparse");
    }

    #[test]
    fn read_write_through_a_byte_stream() {
        let msgs = vec![
            WireMsg::Indices(vec![1, 2, 3]),
            WireMsg::DenseChunk { bucket: 1, vals: vec![0.25; 7] },
            hello(3, Purpose::Star),
        ];
        let mut stream = Vec::new();
        for m in &msgs {
            write_msg(&mut stream, m).unwrap();
        }
        let mut r = stream.as_slice();
        for m in &msgs {
            assert_eq!(&read_msg(&mut r).unwrap(), m);
        }
        assert!(read_msg(&mut r).is_err(), "clean EOF is an error, not a hang");
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut frame = u32::MAX.to_le_bytes().to_vec();
        frame.push(TAG_INDICES);
        let mut d = FrameDecoder::new();
        let err = d.push(&frame).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        assert!(read_msg(&mut frame.as_slice()).is_err());
    }

    #[test]
    fn zero_length_body_rejected() {
        let frame = 0u32.to_le_bytes();
        assert!(FrameDecoder::new().push(&frame).is_err());
    }

    #[test]
    fn mismatched_counts_rejected() {
        // dense count says 4 elements but body carries 1
        let mut body = vec![TAG_DENSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.extend_from_slice(&4u32.to_le_bytes());
        body.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_body(&body).is_err());
        // a dense frame truncated before the count field
        let mut body = vec![TAG_DENSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket only
        assert!(decode_body(&body).is_err());
        // trailing garbage after a complete message
        let mut body = vec![TAG_INDICES];
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(0xFF);
        assert!(decode_body(&body).is_err());
        // a packed sparse frame whose nnz outruns the body
        let mut body = vec![TAG_SPARSE_PACKED];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.push(100); // dim = 100
        body.push(50); // nnz = 50, but nothing follows
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn malformed_sparse_rejected() {
        // unsorted indices
        let mut body = vec![TAG_SPARSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.extend_from_slice(&8u32.to_le_bytes()); // dim
        body.extend_from_slice(&2u32.to_le_bytes()); // nnz
        for i in [3u32, 1] {
            body.extend_from_slice(&i.to_le_bytes());
        }
        for v in [1.0f32, 2.0] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        assert!(decode_body(&body).is_err());
        // index out of range for dim
        let mut body = vec![TAG_SPARSE];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.extend_from_slice(&2u32.to_le_bytes()); // dim
        body.extend_from_slice(&1u32.to_le_bytes()); // nnz
        body.extend_from_slice(&5u32.to_le_bytes());
        body.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_body(&body).is_err());
        // packed sparse index out of range for dim (delta stream is
        // structurally increasing, so range is the only check left)
        let mut body = vec![TAG_SPARSE_PACKED];
        body.extend_from_slice(&0u32.to_le_bytes()); // bucket
        body.push(2); // dim = 2
        body.push(1); // nnz = 1
        body.push(5); // index 5
        body.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn split_reads_reassemble() {
        let frame = encode(&WireMsg::Indices((0..50).collect()));
        for cut in 0..frame.len() {
            let mut d = FrameDecoder::new();
            let first = d.push(&frame[..cut]).unwrap();
            assert!(first.is_empty(), "cut={cut}: partial frame must not yield");
            let second = d.push(&frame[cut..]).unwrap();
            assert_eq!(second.len(), 1, "cut={cut}");
        }
    }

    #[test]
    fn legacy_hello_without_version_field_decodes_as_v1() {
        // a pre-codec peer sends rank + purpose only (5-byte body)
        let mut body = vec![TAG_HELLO];
        body.extend_from_slice(&3u32.to_le_bytes());
        body.push(1); // star
        assert_eq!(
            decode_body(&body).unwrap(),
            WireMsg::Hello { rank: 3, purpose: Purpose::Star, codec: 1 }
        );
        // and the current encoding carries our version byte
        let frame = encode(&hello(3, Purpose::Star));
        assert_eq!(frame[4 + 1 + 4 + 1], WIRE_CODEC_VERSION);
    }

    fn packed_codec(mode: WireCompression) -> FrameCodec {
        FrameCodec::new(WireCodecConfig::with_mode(mode), CodecStats::new())
    }

    #[test]
    fn packed_sparse_roundtrips_and_shrinks() {
        let grad = SparseGrad::new(
            1_000_000,
            (0..5000u32).map(|i| i * 199).collect(),
            (0..5000).map(|i| (i as f32).sin()).collect(),
        );
        let msg = WireMsg::Sparse { bucket: 4, grad };
        let mut codec = packed_codec(WireCompression::Delta);
        let mut frame = Vec::new();
        codec.encode_frame_into(&msg, &mut frame).unwrap();
        assert!(
            frame.len() < frame_len(&msg),
            "packed sparse must beat raw: {} vs {}",
            frame.len(),
            frame_len(&msg)
        );
        // decodable by the generic (stateless) path and the pooled path
        assert_eq!(decode_body(&frame[4..]).unwrap(), msg);
        assert_eq!(codec.decode_body(&frame[4..]).unwrap(), msg);
        let snap = codec.stats().snapshot();
        assert_eq!(snap.packed_frames, 1);
        assert!(snap.ratio() > 1.0, "{}", snap.summary());
    }

    #[test]
    fn packed_indices_roundtrip_and_unsorted_falls_back_to_raw() {
        let mut codec = packed_codec(WireCompression::Delta);
        let mut frame = Vec::new();
        let sorted = WireMsg::Indices((0..1000u32).map(|i| i * 3).collect());
        codec.encode_frame_into(&sorted, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_INDICES_PACKED);
        assert!(frame.len() < frame_len(&sorted));
        assert_eq!(decode_body(&frame[4..]).unwrap(), sorted);
        // duplicates/unsorted sets are not delta-representable: raw tag
        let unsorted = WireMsg::Indices(vec![5, 1, 5, 0]);
        codec.encode_frame_into(&unsorted, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_INDICES);
        assert_eq!(decode_body(&frame[4..]).unwrap(), unsorted);
    }

    #[test]
    fn off_mode_is_byte_identical_to_v1_encode() {
        let msgs = [
            WireMsg::DenseChunk { bucket: 1, vals: (0..300).map(|i| i as f32).collect() },
            WireMsg::Sparse {
                bucket: 0,
                grad: SparseGrad::new(100, vec![1, 50, 99], vec![0.5, -1.0, 2.0]),
            },
            WireMsg::Indices(vec![2, 4, 6]),
            hello(1, Purpose::Ring),
        ];
        let mut codec = packed_codec(WireCompression::Off);
        let mut frame = Vec::new();
        for msg in &msgs {
            codec.encode_frame_into(msg, &mut frame).unwrap();
            assert_eq!(frame, encode(msg), "{msg:?}");
        }
    }

    #[test]
    fn compressed_envelope_roundtrips_compressible_bodies() {
        // a constant dense chunk is highly compressible
        let msg = WireMsg::DenseChunk { bucket: 0, vals: vec![1.0; 100_000] };
        let mut codec = packed_codec(WireCompression::Full);
        let mut frame = Vec::new();
        codec.encode_frame_into(&msg, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_COMPRESSED);
        assert!(
            frame.len() * 10 < frame_len(&msg),
            "constant chunk must shrink >10x, got {} of {}",
            frame.len(),
            frame_len(&msg)
        );
        assert_eq!(decode_body(&frame[4..]).unwrap(), msg);
        assert_eq!(codec.decode_body(&frame[4..]).unwrap(), msg);
        let snap = codec.stats().snapshot();
        assert_eq!(snap.algo(Algo::Lz2).enc_frames, 1);
        assert_eq!(snap.algo(Algo::Lz2).dec_frames, 1, "only the pooled decode books stats");
    }

    #[test]
    fn incompressible_bodies_fall_back_to_raw_tags() {
        // pseudo-random mantissas: the probe or guard must ship raw
        let mut x: u32 = 0x1234_5678;
        let vals: Vec<f32> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                f32::from_bits((x & 0x3F7F_FFFF) | 0x3F00_0000)
            })
            .collect();
        let msg = WireMsg::DenseChunk { bucket: 0, vals };
        let mut codec = packed_codec(WireCompression::Full);
        let mut frame = Vec::new();
        codec.encode_frame_into(&msg, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_DENSE, "high-entropy body must not wear the envelope");
        assert_eq!(frame.len(), frame_len(&msg));
        let snap = codec.stats().snapshot();
        assert_eq!(snap.sample_skips + snap.guard_fallbacks, 1);
        assert_eq!(decode_body(&frame[4..]).unwrap(), msg);
    }

    #[test]
    fn per_scheme_override_pins_the_algorithm() {
        let cfg = WireCodecConfig {
            mode: WireCompression::Full,
            min_bytes: 64,
            dense: AlgoChoice::Force(Algo::Lz1),
            sparse: AlgoChoice::Force(Algo::Raw),
        };
        let mut codec = FrameCodec::new(cfg, CodecStats::new());
        let mut frame = Vec::new();
        let dense = WireMsg::DenseChunk { bucket: 0, vals: vec![0.0; 50_000] };
        codec.encode_frame_into(&dense, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_COMPRESSED);
        assert_eq!(frame[5], Algo::Lz1.to_byte(), "dense forced to lz1");
        // sparse pinned to raw: delta-packed but never enveloped
        let sparse = WireMsg::Sparse {
            bucket: 0,
            grad: SparseGrad::new(100_000, (0..9000u32).map(|i| i * 11).collect(), vec![0.0; 9000]),
        };
        codec.encode_frame_into(&sparse, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_SPARSE_PACKED);
    }

    #[test]
    fn zip_bomb_declared_size_rejected_before_allocation() {
        // an envelope declaring (MAX_FRAME_BYTES + 1) decompressed bytes
        let mut body = vec![TAG_COMPRESSED, Algo::Lz1.to_byte()];
        crate::comm::codec::put_varint_u32(&mut body, (MAX_FRAME_BYTES + 1) as u32);
        body.extend_from_slice(&[0u8; 16]);
        let err = decode_body(&body).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        let mut codec = packed_codec(WireCompression::Full);
        assert!(codec.decode_body(&body).is_err());
        // ... and one lying about its size within the cap is caught by
        // the decompressor's exact-length check
        let mut table = Vec::new();
        let mut comp = Vec::new();
        crate::comm::codec::lz_compress_into(&[9u8; 500], &mut comp, &mut table, Algo::Lz1);
        let mut body = vec![TAG_COMPRESSED, Algo::Lz1.to_byte()];
        crate::comm::codec::put_varint_u32(&mut body, 400); // lies: it's 500
        body.extend_from_slice(&comp);
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn nested_compressed_envelope_rejected() {
        // compress a valid compressed frame body and wrap it again
        let inner_msg = WireMsg::DenseChunk { bucket: 0, vals: vec![2.5; 10_000] };
        let mut codec = packed_codec(WireCompression::Full);
        let mut frame = Vec::new();
        codec.encode_frame_into(&inner_msg, &mut frame).unwrap();
        assert_eq!(frame[4], TAG_COMPRESSED);
        let inner_body = &frame[4..];
        let mut table = Vec::new();
        let mut comp = Vec::new();
        crate::comm::codec::lz_compress_into(inner_body, &mut comp, &mut table, Algo::Lz1);
        let mut nested = vec![TAG_COMPRESSED, Algo::Lz1.to_byte()];
        crate::comm::codec::put_varint_u32(&mut nested, inner_body.len() as u32);
        nested.extend_from_slice(&comp);
        let err = decode_body(&nested).unwrap_err();
        assert!(err.to_string().contains("nested"), "{err}");
    }

    #[test]
    fn hello_is_never_compressed_or_packed() {
        let mut codec = FrameCodec::new(
            WireCodecConfig {
                mode: WireCompression::Full,
                min_bytes: 0,
                dense: AlgoChoice::Auto,
                sparse: AlgoChoice::Auto,
            },
            CodecStats::new(),
        );
        let mut frame = Vec::new();
        codec.encode_frame_into(&hello(2, Purpose::Ring), &mut frame).unwrap();
        assert_eq!(frame[4], TAG_HELLO, "the rendezvous must stay v1-parsable");
        // liveness/recovery control frames share the contract: tiny and
        // latency-bound, they must never wear the envelope either
        for msg in [
            WireMsg::Ping { seq: 9 },
            WireMsg::Pong { seq: 9 },
            WireMsg::Resume { rank: 1, step: 7 },
        ] {
            codec.encode_frame_into(&msg, &mut frame).unwrap();
            assert!((TAG_PING..=TAG_RESUME).contains(&frame[4]), "raw control tag, got {}", frame[4]);
            assert_eq!(decode_body(&frame[4..]).unwrap(), msg);
        }
    }
}
