//! Layer-aligned gradient buckets: the partition the bucketed exchange
//! schedules over.
//!
//! DGC (Lin et al., 2018) and the systems study of Agarwal et al. (2021)
//! both observe that sparsified compression only pays off in wall-clock
//! when the gradient exchange is **bucketed** — groups of layers reduced
//! as soon as backprop produces them, overlapping communication with the
//! rest of the backward pass. A [`BucketPlan`] carves the flat gradient
//! vector into contiguous, **layer-aligned** buckets by greedy
//! size-capped grouping over a [`LayerPartition`] (`--bucket-bytes`):
//! consecutive layers are packed into a bucket until adding the next
//! layer would exceed the byte cap; a single layer larger than the cap
//! gets a bucket of its own.
//!
//! Layer alignment is what makes bucketing **semantics-free**: the §4
//! per-layer rate rule (`select_layered`) already applies the compressor
//! independently per layer, so selecting per bucket — each bucket running
//! `select_layered` over its own layer span — produces exactly the same
//! index sets as the monolithic pass. The determinism contract
//! (`rust/tests/backend_parity.rs`) holds bucketed runs to that:
//! selections and byte accounting exact per bucket, gather reductions
//! bit-identical, ring f32 values within the usual reduction-order
//! tolerance.
//!
//! Invariants (checked by [`BucketPlan::check`] /
//! [`BucketPlan::check_aligned`], property-tested below): buckets tile
//! the gradient exactly — no gap, no overlap, every layer wholly inside
//! exactly one bucket.

use crate::compress::rate::LayerSlice;
use crate::compress::LayerPartition;

/// One contiguous bucket of the flat gradient vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bucket {
    /// Position in the plan (== the wire-level bucket tag).
    pub id: usize,
    /// First coordinate of the bucket in the flat vector.
    pub offset: usize,
    /// Number of coordinates.
    pub len: usize,
    /// Half-open range of layer indices (into the source
    /// `LayerPartition`) this bucket covers.
    pub layers: (usize, usize),
}

impl Bucket {
    /// The bucket's span in the flat vector.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.len
    }
}

/// A layer-aligned partition of the gradient vector into buckets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketPlan {
    dim: usize,
    buckets: Vec<Bucket>,
}

impl BucketPlan {
    /// Trivial plan: the whole vector in one bucket (the monolithic
    /// exchange — `step_bucketed` falls back to `step`).
    pub fn single(dim: usize) -> BucketPlan {
        Self::from_partition(&LayerPartition::single(dim), 0)
    }

    /// Greedy size-capped grouping: walk the layers in order, close the
    /// current bucket whenever adding the next layer would push it past
    /// `bucket_bytes` (4 bytes per f32 coordinate). `bucket_bytes == 0`
    /// means unbounded — one bucket over everything.
    pub fn from_partition(partition: &LayerPartition, bucket_bytes: usize) -> BucketPlan {
        assert!(
            !partition.layers.is_empty(),
            "bucket plan needs at least one layer"
        );
        let cap_elems = if bucket_bytes == 0 {
            usize::MAX
        } else {
            (bucket_bytes / 4).max(1)
        };
        let mut buckets: Vec<Bucket> = Vec::new();
        let mut start_layer = 0usize;
        let mut offset = 0usize;
        let mut len = 0usize;
        for (i, l) in partition.layers.iter().enumerate() {
            if len > 0 && len + l.len > cap_elems {
                buckets.push(Bucket {
                    id: buckets.len(),
                    offset,
                    len,
                    layers: (start_layer, i),
                });
                start_layer = i;
                offset += len;
                len = 0;
            }
            len += l.len;
        }
        buckets.push(Bucket {
            id: buckets.len(),
            offset,
            len,
            layers: (start_layer, partition.layers.len()),
        });
        let plan = BucketPlan {
            dim: partition.total_len(),
            buckets,
        };
        plan.check().expect("greedy grouping tiles by construction");
        plan
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// True for the trivial one-bucket plan (monolithic exchange).
    pub fn is_single(&self) -> bool {
        self.buckets.len() == 1
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    pub fn bucket(&self, b: usize) -> &Bucket {
        &self.buckets[b]
    }

    /// Structural invariant: buckets tile `[0, dim)` exactly — ids
    /// sequential, offsets consecutive, every bucket non-empty, no gap,
    /// no overlap — and layer ranges are consecutive.
    pub fn check(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.buckets.is_empty(), "bucket plan has no buckets");
        let mut expect_offset = 0usize;
        let mut expect_layer = 0usize;
        for (i, b) in self.buckets.iter().enumerate() {
            anyhow::ensure!(b.id == i, "bucket {i} carries id {}", b.id);
            anyhow::ensure!(
                b.offset == expect_offset,
                "bucket {i} offset {} != running total {expect_offset} (gap or overlap)",
                b.offset
            );
            anyhow::ensure!(b.len > 0, "bucket {i} is empty");
            let (lo, hi) = b.layers;
            anyhow::ensure!(
                lo == expect_layer && hi > lo,
                "bucket {i} layer range [{lo}, {hi}) not consecutive after {expect_layer}"
            );
            expect_offset += b.len;
            expect_layer = hi;
        }
        anyhow::ensure!(
            expect_offset == self.dim,
            "buckets cover {expect_offset} of {} coordinates",
            self.dim
        );
        Ok(())
    }

    /// Alignment invariant against the source partition: every bucket's
    /// span is exactly the concatenation of its layer range — i.e. every
    /// layer lies wholly inside exactly one bucket.
    pub fn check_aligned(&self, partition: &LayerPartition) -> anyhow::Result<()> {
        self.check()?;
        anyhow::ensure!(
            self.dim == partition.total_len(),
            "plan dim {} != partition dim {}",
            self.dim,
            partition.total_len()
        );
        let n_layers = partition.layers.len();
        for b in &self.buckets {
            let (lo, hi) = b.layers;
            anyhow::ensure!(
                hi <= n_layers,
                "bucket {} references layer {hi} of a {n_layers}-layer partition",
                b.id
            );
            let span: usize = partition.layers[lo..hi].iter().map(|l| l.len).sum();
            anyhow::ensure!(
                partition.layers[lo].offset == b.offset && span == b.len,
                "bucket {} span [{}, {}) misaligned with layers [{lo}, {hi})",
                b.id,
                b.offset,
                b.offset + b.len
            );
        }
        anyhow::ensure!(
            self.buckets.last().map(|b| b.layers.1) == Some(n_layers),
            "plan does not cover every layer"
        );
        Ok(())
    }

    /// Bucket `b`'s slice of a layered selection config: its layers with
    /// offsets rebased to the bucket start, plus the matching per-layer
    /// budgets. Running `select_layered` over this sub-config yields
    /// exactly the monolithic pass's selections for these layers (the
    /// compressors are pure functions of `(step, views, k)`).
    pub fn bucket_config(
        &self,
        b: usize,
        partition: &LayerPartition,
        ks: &[usize],
    ) -> (LayerPartition, Vec<usize>) {
        assert_eq!(
            ks.len(),
            partition.layers.len(),
            "one budget per layer of the source partition"
        );
        let bucket = &self.buckets[b];
        let (lo, hi) = bucket.layers;
        assert!(
            hi <= partition.layers.len(),
            "bucket plan built from a different partition"
        );
        let layers: Vec<LayerSlice> = partition.layers[lo..hi]
            .iter()
            .map(|l| LayerSlice {
                name: l.name.clone(),
                offset: l.offset - bucket.offset,
                len: l.len,
                flops_per_sample: l.flops_per_sample,
                compress: l.compress,
            })
            .collect();
        (LayerPartition::from_layers(layers), ks[lo..hi].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    fn layer(name: &str, offset: usize, len: usize) -> LayerSlice {
        LayerSlice {
            name: name.into(),
            offset,
            len,
            flops_per_sample: 0.0,
            compress: true,
        }
    }

    fn partition_of(lens: &[usize]) -> LayerPartition {
        let mut layers = Vec::new();
        let mut off = 0;
        for (i, &len) in lens.iter().enumerate() {
            layers.push(layer(&format!("l{i}"), off, len));
            off += len;
        }
        LayerPartition::from_layers(layers)
    }

    #[test]
    fn single_plan_is_one_bucket_over_everything() {
        let p = BucketPlan::single(100);
        assert!(p.is_single());
        assert_eq!(p.num_buckets(), 1);
        assert_eq!(p.dim(), 100);
        assert_eq!(p.bucket(0).range(), 0..100);
        p.check().unwrap();
    }

    #[test]
    fn greedy_grouping_respects_the_byte_cap() {
        // layers of 10 elements = 40 bytes each; cap 100 bytes = 25 elems
        // → two layers per bucket
        let p = partition_of(&[10, 10, 10, 10, 10]);
        let plan = BucketPlan::from_partition(&p, 100);
        assert_eq!(plan.num_buckets(), 3);
        assert_eq!(plan.bucket(0).range(), 0..20);
        assert_eq!(plan.bucket(1).range(), 20..40);
        assert_eq!(plan.bucket(2).range(), 40..50);
        plan.check_aligned(&p).unwrap();
    }

    #[test]
    fn oversized_layer_gets_its_own_bucket_never_split() {
        let p = partition_of(&[4, 1000, 4]);
        let plan = BucketPlan::from_partition(&p, 64);
        // layer boundaries are never crossed: the big layer is one bucket
        assert_eq!(plan.num_buckets(), 3);
        assert_eq!(plan.bucket(1).len, 1000);
        plan.check_aligned(&p).unwrap();
    }

    #[test]
    fn zero_cap_means_one_bucket() {
        let p = partition_of(&[7, 9, 11]);
        let plan = BucketPlan::from_partition(&p, 0);
        assert!(plan.is_single());
        assert_eq!(plan.bucket(0).layers, (0, 3));
        plan.check_aligned(&p).unwrap();
    }

    #[test]
    fn bucket_config_rebases_offsets_and_slices_budgets() {
        let p = partition_of(&[8, 8, 16]);
        let ks = vec![2usize, 3, 4];
        let plan = BucketPlan::from_partition(&p, 64); // 16 elems per bucket
        assert_eq!(plan.num_buckets(), 2);
        let (sub, sub_ks) = plan.bucket_config(1, &p, &ks);
        assert_eq!(sub.layers.len(), 1);
        assert_eq!(sub.layers[0].offset, 0);
        assert_eq!(sub.layers[0].len, 16);
        assert_eq!(sub_ks, vec![4]);
        let (sub0, sub0_ks) = plan.bucket_config(0, &p, &ks);
        assert_eq!(sub0.layers.len(), 2);
        assert_eq!(sub0.total_len(), 16);
        assert_eq!(sub0_ks, vec![2, 3]);
    }

    #[test]
    fn check_rejects_gaps_overlaps_and_misalignment() {
        let mut plan = BucketPlan::from_partition(&partition_of(&[10, 10]), 40);
        assert_eq!(plan.num_buckets(), 2);
        plan.buckets[1].offset = 11; // gap
        assert!(plan.check().is_err());
        plan.buckets[1].offset = 10;
        plan.check().unwrap();
        // aligned against the wrong partition
        let other = partition_of(&[5, 15]);
        assert!(plan.check_aligned(&other).is_err());
    }

    #[test]
    fn bucket_partitioning_tiles_the_gradient_exactly() {
        // The satellite property: for ANY layer partition and ANY byte
        // cap, buckets tile the gradient with no gap/overlap, stay
        // layer-aligned, and every layer lands wholly in exactly one
        // bucket.
        check("bucket plan tiles exactly", 120, |g| {
            let n_layers = g.usize_in(1..=12);
            let lens: Vec<usize> = (0..n_layers).map(|_| g.usize_in(1..=64)).collect();
            let p = partition_of(&lens);
            let bucket_bytes = g.usize_in(0..=512);
            let plan = BucketPlan::from_partition(&p, bucket_bytes);
            plan.check().expect("structural tiling");
            plan.check_aligned(&p).expect("layer alignment");
            // every coordinate covered exactly once
            let mut covered = vec![0u8; p.total_len()];
            for b in plan.buckets() {
                for c in covered[b.range()].iter_mut() {
                    *c += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "gap or overlap");
            // every layer inside exactly one bucket
            for l in &p.layers {
                let holders = plan
                    .buckets()
                    .iter()
                    .filter(|b| b.offset <= l.offset && l.offset + l.len <= b.offset + b.len)
                    .count();
                assert_eq!(holders, 1, "layer '{}' split across buckets", l.name);
            }
            // the byte cap is respected whenever a bucket has > 1 layer
            if bucket_bytes > 0 {
                for b in plan.buckets() {
                    let (lo, hi) = b.layers;
                    if hi - lo > 1 {
                        assert!(
                            b.len * 4 <= bucket_bytes.max(4),
                            "multi-layer bucket {} exceeds the cap",
                            b.id
                        );
                    }
                }
            }
        });
    }
}
