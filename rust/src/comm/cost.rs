//! Cost records for fabric operations.

use crate::comm::codec::CodecSnapshot;

/// Cost of one collective operation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CommCost {
    pub op: &'static str,
    /// Bytes one worker sends (max over workers — sync SGD waits for the
    /// slowest).
    pub bytes_up_per_worker: usize,
    /// Bytes one worker receives.
    pub bytes_down_per_worker: usize,
    /// Bytes crossing the bottleneck link (PS port / busiest ring port).
    pub bottleneck_bytes: usize,
    /// Modeled wall time of the collective.
    pub time_s: f64,
    /// Serialized message count on the critical path (latency charges).
    pub hops: usize,
}

/// Min/mean/max of the heartbeat round-trips measured so far (socket
/// links with `--heartbeat-ms` only; all zero otherwise). Nanoseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct RttSnapshot {
    pub count: u64,
    pub min_ns: u64,
    pub mean_ns: u64,
    pub max_ns: u64,
}

impl RttSnapshot {
    pub fn min_secs(&self) -> f64 {
        self.min_ns as f64 * 1e-9
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean_ns as f64 * 1e-9
    }

    pub fn max_secs(&self) -> f64 {
        self.max_ns as f64 * 1e-9
    }
}

/// Accumulated statistics over a run.
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    pub ops: Vec<CommCost>,
    /// Wire entropy-codec counters (socket backend only; stays at its
    /// default for the in-process mesh and the modeled fabric, whose
    /// byte accounting is pre-codec by design).
    pub codec: CodecSnapshot,
    /// Heartbeat round-trip stats (socket backend with heartbeats only).
    pub rtt: RttSnapshot,
}

impl CommStats {
    pub fn record(&mut self, c: CommCost) {
        self.ops.push(c);
    }

    pub fn last_cost(&self) -> &CommCost {
        self.ops.last().expect("no fabric ops recorded")
    }

    pub fn total_time_s(&self) -> f64 {
        self.ops.iter().map(|c| c.time_s).sum()
    }

    pub fn total_bytes_up(&self) -> usize {
        self.ops.iter().map(|c| c.bytes_up_per_worker).sum()
    }

    pub fn total_bytes_down(&self) -> usize {
        self.ops.iter().map(|c| c.bytes_down_per_worker).sum()
    }

    pub fn total_bottleneck_bytes(&self) -> usize {
        self.ops.iter().map(|c| c.bottleneck_bytes).sum()
    }

    pub fn reset(&mut self) {
        self.ops.clear();
        self.codec = CodecSnapshot::default();
        self.rtt = RttSnapshot::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let mut s = CommStats::default();
        s.record(CommCost {
            op: "a",
            bytes_up_per_worker: 10,
            bytes_down_per_worker: 20,
            bottleneck_bytes: 30,
            time_s: 1.0,
            hops: 2,
        });
        s.record(CommCost {
            op: "b",
            bytes_up_per_worker: 1,
            bytes_down_per_worker: 2,
            bottleneck_bytes: 3,
            time_s: 0.5,
            hops: 1,
        });
        assert_eq!(s.total_bytes_up(), 11);
        assert_eq!(s.total_bytes_down(), 22);
        assert_eq!(s.total_bottleneck_bytes(), 33);
        assert_eq!(s.total_time_s(), 1.5);
        assert_eq!(s.last_cost().op, "b");
        s.reset();
        assert!(s.ops.is_empty());
    }

    #[test]
    #[should_panic(expected = "no fabric ops")]
    fn last_cost_panics_when_empty() {
        let s = CommStats::default();
        let _ = s.last_cost();
    }
}
