//! The fabric: functional collectives + cost model.

use crate::comm::cost::{CommCost, CommStats};
use crate::compress::SparseGrad;

/// Interconnect topology. The paper presents Algorithm 1 against a
/// parameter server "for simplicity" and notes CLT-k "can naturally be
/// extended to ring all-reduce" (Remark 3) — both are modeled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    ParameterServer,
    Ring,
}

impl Topology {
    pub fn parse(s: &str) -> anyhow::Result<Topology> {
        match s {
            "ps" | "parameter-server" => Ok(Topology::ParameterServer),
            "ring" => Ok(Topology::Ring),
            other => anyhow::bail!("unknown topology '{other}' (expected ps|ring)"),
        }
    }
}

/// Fault injection for the failure tests: synchronous SGD must fail
/// loudly, never silently average a partial set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    None,
    /// Worker `w`'s contribution is dropped starting at op index `op`.
    DropWorker { worker: usize, from_op: usize },
}

#[derive(Debug, Clone)]
pub struct FabricConfig {
    pub workers: usize,
    pub topology: Topology,
    /// Per-link bandwidth in GB/s (paper evaluates 32 and 64 GBps).
    pub bandwidth_gbps: f64,
    /// Per-hop latency in microseconds.
    pub latency_us: f64,
    pub fault: FaultSpec,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            workers: 4,
            topology: Topology::ParameterServer,
            bandwidth_gbps: 32.0,
            latency_us: 1.0,
            fault: FaultSpec::None,
        }
    }
}

/// Wire-shape summary of one gather of per-worker sparse gradients —
/// everything the analytic cost model needs, separated from the payloads
/// so both backends (sequential loop / threaded root) can produce it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GatherStats {
    /// number of workers that contributed
    pub contributions: usize,
    /// largest single upload (sync SGD waits for the slowest worker)
    pub max_wire_bytes: usize,
    /// total ingress at the reducing server
    pub total_wire_bytes: usize,
    /// nnz of the union of all index sets (the build-up payload)
    pub union_nnz: usize,
}

/// The gather path's root reduction: sum the contributions into a dense
/// accumulator **in worker order**, then scale by 1/n — plus the
/// wire-shape summary the cost model charges. This is THE definition of
/// the gather arithmetic: the sequential fabric
/// ([`Fabric::sparse_gather_avg`]), the staged comm lanes
/// (`comm::parallel`), and the multi-process socket driver
/// (`runtime::socket`) all call it, so their results are bit-identical
/// by construction. Panics (via `SparseGrad::add_into`) if a
/// contribution's dim differs from `dim` — callers on untrusted inputs
/// (the wire) must validate dims first.
pub fn reduce_gathered(sparses: &[SparseGrad], dim: usize) -> (Vec<f32>, GatherStats) {
    let n = sparses.len();
    assert!(n >= 1, "gather reduction over no contributions");
    let gs = GatherStats::from_sparses(sparses);
    let mut acc = vec![0.0f32; dim];
    for s in sparses {
        s.add_into(&mut acc);
    }
    let inv = 1.0 / n as f32;
    acc.iter_mut().for_each(|v| *v *= inv);
    (acc, gs)
}

impl GatherStats {
    pub fn from_sparses(sparses: &[SparseGrad]) -> GatherStats {
        let union_nnz = {
            let mut all: Vec<u32> =
                sparses.iter().flat_map(|s| s.indices.iter().copied()).collect();
            all.sort_unstable();
            all.dedup();
            all.len()
        };
        GatherStats {
            contributions: sparses.len(),
            max_wire_bytes: sparses.iter().map(|s| s.wire_bytes()).max().unwrap_or(0),
            total_wire_bytes: sparses.iter().map(|s| s.wire_bytes()).sum(),
            union_nnz,
        }
    }
}

/// Simulated fabric. All collectives are synchronous over `workers`
/// participants; inputs are slices indexed by worker id.
pub struct Fabric {
    cfg: FabricConfig,
    stats: CommStats,
    op_counter: usize,
}

impl Fabric {
    pub fn new(cfg: FabricConfig) -> Self {
        assert!(cfg.workers >= 1, "fabric needs at least one worker");
        assert!(cfg.bandwidth_gbps > 0.0);
        Fabric {
            cfg,
            stats: CommStats::default(),
            op_counter: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.cfg.workers
    }

    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats.reset();
    }

    /// Install the wire entropy-codec counters of the real transport
    /// (socket backend) into this fabric's stats. The analytic byte
    /// ledger stays pre-codec; the snapshot reports what the wire
    /// actually shipped.
    pub fn update_codec_stats(&mut self, snapshot: crate::comm::codec::CodecSnapshot) {
        self.stats.codec = snapshot;
    }

    /// Install the heartbeat RTT stats measured by the socket liveness
    /// monitors (zero when the transport has no heartbeat links).
    pub fn update_rtt_stats(&mut self, snapshot: crate::comm::cost::RttSnapshot) {
        self.stats.rtt = snapshot;
    }

    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    fn time_for(&self, bottleneck_bytes: usize, hops: usize) -> f64 {
        let bw = self.cfg.bandwidth_gbps * 1e9; // bytes/s
        self.cfg.latency_us * 1e-6 * hops as f64 + bottleneck_bytes as f64 / bw
    }

    fn check_contribution(&mut self, n_given: usize, op: &'static str) {
        self.op_counter += 1;
        if let FaultSpec::DropWorker { worker, from_op } = self.cfg.fault {
            if self.op_counter > from_op {
                panic!(
                    "fabric fault: worker {worker} contribution missing in '{op}' \
                     (synchronous training cannot proceed with a partial set)"
                );
            }
        }
        assert_eq!(
            n_given, self.cfg.workers,
            "'{op}' got {n_given} contributions for a {}-worker fabric",
            self.cfg.workers
        );
    }

    fn record(
        &mut self,
        op: &'static str,
        up: usize,
        down: usize,
        bottleneck: usize,
        hops: usize,
    ) -> CommCost {
        let cost = CommCost {
            op,
            bytes_up_per_worker: up,
            bytes_down_per_worker: down,
            bottleneck_bytes: bottleneck,
            time_s: self.time_for(bottleneck, hops),
            hops,
        };
        self.stats.record(cost.clone());
        cost
    }

    // ------------------------------------------------------------------
    // Analytic cost entry points
    //
    // The cost of a collective is a pure function of its shape (worker
    // count, payload size, topology), not of who executed it. These
    // `record_*` methods charge that cost — plus the synchronous-SGD
    // contribution/fault checks — without performing the reduction, so
    // the threaded backend (`runtime::threaded`), which executes the op
    // on worker threads via channel collectives, books *identical*
    // `CommStats` to the sequential methods below.
    // ------------------------------------------------------------------

    /// Charge one dense all-reduce over `dim`-element f32 gradients.
    pub fn record_dense_allreduce(&mut self, n_given: usize, dim: usize) -> CommCost {
        self.check_contribution(n_given, "dense_allreduce");
        let n = self.cfg.workers;
        let bytes = dim * 4;
        match self.cfg.topology {
            Topology::ParameterServer => {
                // Server port carries n uploads then n downloads.
                self.record("dense_allreduce", bytes, bytes, 2 * n * bytes, 2)
            }
            Topology::Ring => {
                // Standard ring: each port moves 2·(n-1)/n · bytes.
                let per_port = 2 * bytes * (n - 1) / n.max(1);
                self.record("dense_allreduce", per_port, per_port, per_port, 2 * (n - 1))
            }
        }
    }

    /// Charge one shared-index sparse all-reduce of `k` coordinates.
    pub fn record_sparse_allreduce_shared(&mut self, n_given: usize, k: usize) -> CommCost {
        self.check_contribution(n_given, "sparse_allreduce_shared");
        let n = self.cfg.workers;
        // Index broadcast: leader sends k·4 bytes once (tree/multicast);
        // every follower receives k·4.
        let idx_bytes = k * 4;
        let val_bytes = k * 4;
        match self.cfg.topology {
            Topology::ParameterServer => {
                // up: indices (leader) + values (all); server reduces
                // in-place so the downlink carries only k values + the
                // shared indices.
                let up = idx_bytes + val_bytes;
                let down = idx_bytes + val_bytes;
                let bottleneck = n * val_bytes + idx_bytes // ingress
                    + n * (val_bytes + idx_bytes); // egress
                self.record("sparse_allreduce_shared", up, down, bottleneck, 3)
            }
            Topology::Ring => {
                let per_port = idx_bytes + 2 * val_bytes * (n - 1) / n.max(1);
                self.record(
                    "sparse_allreduce_shared",
                    per_port,
                    per_port,
                    per_port,
                    2 * (n - 1) + 1,
                )
            }
        }
    }

    /// Charge one gather of per-worker sparse gradients (gradient
    /// build-up: the downlink payload is the union nnz).
    pub fn record_sparse_gather(&mut self, gs: &GatherStats) -> CommCost {
        self.check_contribution(gs.contributions, "sparse_gather");
        let n = self.cfg.workers;
        let up = gs.max_wire_bytes;
        let down = gs.union_nnz * 8;
        match self.cfg.topology {
            Topology::ParameterServer => {
                let egress = n * down;
                self.record("sparse_gather", up, down, gs.total_wire_bytes + egress, 2)
            }
            Topology::Ring => {
                // Gather around the ring: accumulated sparse unions grow as
                // they travel; the busiest port carries ~the full union.
                let per_port = down + up;
                self.record("sparse_gather", per_port, per_port, per_port, n - 1)
            }
        }
    }

    // ------------------------------------------------------------------
    // Dense all-reduce (uncompressed baseline)
    // ------------------------------------------------------------------

    /// Average dense gradients across workers.
    pub fn dense_allreduce_avg(&mut self, grads: &[Vec<f32>]) -> Vec<f32> {
        let n = grads.len();
        assert!(n >= 1, "dense_allreduce over no gradients");
        let dim = grads[0].len();
        assert!(grads.iter().all(|g| g.len() == dim), "dim mismatch");
        self.record_dense_allreduce(n, dim);
        let mut out = vec![0.0f32; dim];
        for g in grads {
            for (o, &v) in out.iter_mut().zip(g) {
                *o += v;
            }
        }
        let inv = 1.0 / n as f32;
        out.iter_mut().for_each(|v| *v *= inv);
        out
    }

    // ------------------------------------------------------------------
    // ScaleCom path: shared-index sparse all-reduce
    // ------------------------------------------------------------------

    /// Reduce sparse gradients whose index sets are identical (the
    /// commutative CLT-k case) and return the *averaged* sparse gradient.
    ///
    /// `leader` is the worker whose index set was broadcast; the index
    /// broadcast cost (k·4 bytes, O(1) in n — §5 "cost of index
    /// communication") is charged here.
    pub fn sparse_allreduce_shared(
        &mut self,
        sparses: &[SparseGrad],
        leader: usize,
    ) -> SparseGrad {
        let n = sparses.len();
        assert!(n >= 1, "sparse_allreduce over no gradients");
        assert!(leader < n, "leader {leader} out of range");
        let idx = &sparses[leader].indices;
        for (w, s) in sparses.iter().enumerate() {
            assert_eq!(
                &s.indices, idx,
                "worker {w} index set differs from leader — not a commutative reduce"
            );
        }
        let k = idx.len();
        self.record_sparse_allreduce_shared(n, k);
        let mut values = vec![0.0f32; k];
        for s in sparses {
            for (v, &x) in values.iter_mut().zip(&s.values) {
                *v += x;
            }
        }
        let inv = 1.0 / n as f32;
        values.iter_mut().for_each(|v| *v *= inv);
        SparseGrad::new(sparses[0].dim, idx.clone(), values)
    }

    // ------------------------------------------------------------------
    // Local top-k path: gather (gradient build-up)
    // ------------------------------------------------------------------

    /// Gather per-worker sparse gradients (distinct index sets), reduce on
    /// the server, and return the averaged result as a *dense* vector.
    /// The reduced vector's nnz is the union of all index sets — this is
    /// the Fig 1(a) build-up: downloads grow O(n).
    pub fn sparse_gather_avg(&mut self, sparses: &[SparseGrad]) -> Vec<f32> {
        let n = sparses.len();
        assert!(n >= 1, "sparse_gather over no gradients");
        let dim = sparses[0].dim;
        assert!(sparses.iter().all(|s| s.dim == dim));
        let (acc, gs) = reduce_gathered(sparses, dim);
        self.record_sparse_gather(&gs);
        acc
    }

    // ------------------------------------------------------------------
    // Primitives used by gTop-k and index distribution
    // ------------------------------------------------------------------

    /// Broadcast `bytes` from one worker to all others (tree).
    pub fn broadcast_bytes(&mut self, bytes: usize) -> CommCost {
        self.check_contribution(self.cfg.workers, "broadcast");
        let n = self.cfg.workers;
        let hops = (usize::BITS - (n.max(1) - 1).leading_zeros()) as usize;
        match self.cfg.topology {
            Topology::ParameterServer => self.record("broadcast", bytes, bytes, n * bytes, 2),
            Topology::Ring => self.record("broadcast", bytes, bytes, bytes, hops.max(1)),
        }
    }

    /// gTop-k exchange: log2(n) rounds of pairwise sparse exchanges of
    /// ~k entries each (cost only; the merge math lives in the scheme).
    pub fn gtopk_exchange(&mut self, k: usize) -> CommCost {
        self.check_contribution(self.cfg.workers, "gtopk_exchange");
        let n = self.cfg.workers;
        let rounds = (usize::BITS - (n.max(1) - 1).leading_zeros()) as usize;
        let per_round = k * 8;
        let up = rounds * per_round;
        self.record("gtopk_exchange", up, up, up, rounds.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;
    use crate::util::floats::allclose;

    fn cfg(n: usize, topo: Topology) -> FabricConfig {
        FabricConfig {
            workers: n,
            topology: topo,
            bandwidth_gbps: 32.0,
            latency_us: 1.0,
            fault: FaultSpec::None,
        }
    }

    #[test]
    fn dense_allreduce_averages() {
        let mut f = Fabric::new(cfg(2, Topology::ParameterServer));
        let out = f.dense_allreduce_avg(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(out, vec![2.0, 3.0]);
        assert_eq!(f.stats().last_cost().bytes_up_per_worker, 8);
    }

    #[test]
    fn ring_dense_cheaper_per_port_than_ps_bottleneck() {
        let g: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0f32; 1000]).collect();
        let mut ps = Fabric::new(cfg(8, Topology::ParameterServer));
        let mut ring = Fabric::new(cfg(8, Topology::Ring));
        ps.dense_allreduce_avg(&g);
        ring.dense_allreduce_avg(&g);
        assert!(
            ring.stats().last_cost().bottleneck_bytes
                < ps.stats().last_cost().bottleneck_bytes
        );
    }

    #[test]
    fn shared_sparse_reduce_matches_dense_on_mask() {
        check("sparse reduce == dense reduce on mask", 80, |g| {
            let n = g.usize_in(2..=8);
            let dim = g.usize_in(8..=256);
            let k = g.usize_in(1..=dim);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
            let idx = crate::util::select::top_k_indices_by_magnitude(&grads[0], k);
            let sparses: Vec<SparseGrad> = grads
                .iter()
                .map(|w| SparseGrad::gather_from(w, &idx))
                .collect();
            let mut f = Fabric::new(cfg(n, Topology::ParameterServer));
            let sparse_avg = f.sparse_allreduce_shared(&sparses, 0);
            let dense_avg = {
                let mut f2 = Fabric::new(cfg(n, Topology::ParameterServer));
                f2.dense_allreduce_avg(&grads)
            };
            let expect: Vec<f32> = idx.iter().map(|&i| dense_avg[i as usize]).collect();
            if let Err(i) = allclose(&sparse_avg.values, &expect, 1e-4, 1e-5) {
                panic!("mismatch at {i}");
            }
        });
    }

    #[test]
    fn gather_avg_matches_manual_union() {
        let a = SparseGrad::new(5, vec![0, 2], vec![2.0, 4.0]);
        let b = SparseGrad::new(5, vec![2, 3], vec![2.0, 6.0]);
        let mut f = Fabric::new(cfg(2, Topology::ParameterServer));
        let avg = f.sparse_gather_avg(&[a, b]);
        assert_eq!(avg, vec![1.0, 0.0, 3.0, 3.0, 0.0]);
        // union nnz = 3 → per-worker download 24 bytes
        assert_eq!(f.stats().last_cost().bytes_down_per_worker, 24);
    }

    #[test]
    #[should_panic(expected = "index set differs")]
    fn shared_reduce_rejects_divergent_indices() {
        let a = SparseGrad::new(4, vec![0], vec![1.0]);
        let b = SparseGrad::new(4, vec![1], vec![1.0]);
        let mut f = Fabric::new(cfg(2, Topology::ParameterServer));
        let _ = f.sparse_allreduce_shared(&[a, b], 0);
    }

    #[test]
    #[should_panic(expected = "fabric fault")]
    fn fault_injection_fails_loudly() {
        let mut f = Fabric::new(FabricConfig {
            fault: FaultSpec::DropWorker {
                worker: 1,
                from_op: 0,
            },
            ..cfg(2, Topology::ParameterServer)
        });
        let _ = f.dense_allreduce_avg(&[vec![1.0], vec![2.0]]);
    }

    #[test]
    #[should_panic(expected = "contributions")]
    fn wrong_worker_count_rejected() {
        let mut f = Fabric::new(cfg(3, Topology::ParameterServer));
        let _ = f.dense_allreduce_avg(&[vec![1.0], vec![2.0]]);
    }

    #[test]
    fn index_broadcast_cost_constant_in_n() {
        // §5: index communication is O(1) w.r.t. worker count per worker.
        let k = 1000;
        let mut costs = Vec::new();
        for n in [4usize, 16, 64] {
            let ix: Vec<u32> = (0..k as u32).collect();
            let sp: Vec<SparseGrad> = (0..n)
                .map(|_| SparseGrad::new(100_000, ix.clone(), vec![1.0; k]))
                .collect();
            let mut f = Fabric::new(cfg(n, Topology::Ring));
            let _ = f.sparse_allreduce_shared(&sp, 0);
            costs.push(f.stats().last_cost().bytes_down_per_worker);
        }
        // Ring per-port cost approaches 2·k·4 + idx as n grows; must not
        // scale linearly (stay within 2x across 16x more workers).
        assert!(costs[2] < costs[0] * 2);
    }

    #[test]
    fn time_model_latency_plus_bandwidth() {
        let mut f = Fabric::new(FabricConfig {
            workers: 2,
            topology: Topology::ParameterServer,
            bandwidth_gbps: 1.0, // 1e9 B/s
            latency_us: 100.0,
            fault: FaultSpec::None,
        });
        let c = f.broadcast_bytes(1_000_000_000); // 1 GB through 2 workers
        // bottleneck = 2 GB → 2 s, plus 2 hops · 100 us
        assert!((c.time_s - 2.0002).abs() < 1e-6, "time={}", c.time_s);
    }

    #[test]
    fn gtopk_exchange_scales_log_n() {
        let mut f4 = Fabric::new(cfg(4, Topology::Ring));
        let mut f16 = Fabric::new(cfg(16, Topology::Ring));
        let c4 = f4.gtopk_exchange(100);
        let c16 = f16.gtopk_exchange(100);
        assert_eq!(c4.bytes_up_per_worker * 2, c16.bytes_up_per_worker);
    }
}
