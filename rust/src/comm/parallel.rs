//! Message-passing collectives for the threaded backend.
//!
//! The sequential backend executes every collective as a loop on the
//! calling thread. This module provides the concurrent counterpart: each
//! worker runs on its own OS thread and exchanges data over `mpsc`
//! channels wired into two fixed topologies:
//!
//!   - a **ring** (each worker owns one sender to its right neighbor and
//!     one receiver from its left) carrying the commutative reduce —
//!     standard reduce-scatter + all-gather, the algorithm Remark 3 says
//!     CLT-k "can naturally be extended to";
//!   - a **star** (workers → root) carrying the gather that
//!     non-commutative schemes (local top-k) are forced into.
//!
//! Message counts mirror the analytic `CommCost` model: a ring all-reduce
//! moves 2·(n−1) chunk messages of ≈len/n elements per port, exactly the
//! `2·bytes·(n−1)/n` per-port term `Fabric` charges.
//!
//! ## Determinism contract
//!
//! Every receiver has exactly one producer and channels are FIFO, so the
//! dataflow — and therefore every floating-point reduction order — is a
//! pure function of (n, payload), independent of OS scheduling. Repeated
//! threaded runs are bit-identical. Against the *sequential* backend the
//! reduction order differs (ring order is a rotation per chunk, the
//! sequential loop always sums worker 0..n), so f32 sums may differ by
//! rounding; `rust/tests/backend_parity.rs` pins the tolerance
//! (rtol 1e-5, atol 1e-6). Index sets, byte accounting, and `CommStats`
//! match exactly.

use crate::compress::SparseGrad;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Execution backend for the coordination step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Single-threaded loops over workers (the reference semantics).
    #[default]
    Sequential,
    /// Thread-per-worker engine with channel collectives.
    Threaded,
}

impl Backend {
    pub fn parse(s: &str) -> anyhow::Result<Backend> {
        match s {
            "sequential" | "seq" => Ok(Backend::Sequential),
            "threaded" | "thr" => Ok(Backend::Threaded),
            other => {
                anyhow::bail!("unknown backend '{other}' (expected sequential|threaded)")
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Sequential => "sequential",
            Backend::Threaded => "threaded",
        }
    }
}

/// Shared bench-CLI helper: resolve a `--backend <name>` argument into
/// the set of backends to run — both when the flag is absent, so every
/// bench compares them side by side by default.
pub fn backends_from_args(args: &[String]) -> Vec<Backend> {
    match args.iter().position(|a| a == "--backend") {
        Some(i) => {
            let value = args
                .get(i + 1)
                .expect("--backend requires a value (sequential|threaded)");
            vec![Backend::parse(value).expect("--backend sequential|threaded")]
        }
        None => vec![Backend::Sequential, Backend::Threaded],
    }
}

/// One worker's endpoints in a unidirectional ring of `n` workers.
pub struct RingNode {
    pub id: usize,
    pub n: usize,
    tx_right: Sender<Vec<f32>>,
    rx_left: Receiver<Vec<f32>>,
}

/// Build the ring: channel `i` carries messages worker `i` → `(i+1)%n`.
pub fn ring(n: usize) -> Vec<RingNode> {
    assert!(n >= 1);
    let mut txs = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<Vec<f32>>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    txs.into_iter()
        .enumerate()
        .map(|(id, tx_right)| RingNode {
            id,
            n,
            tx_right,
            rx_left: rxs[(id + n - 1) % n].take().expect("ring wiring"),
        })
        .collect()
}

/// Balanced chunk boundaries: chunk `c` covers `[c*len/n, (c+1)*len/n)`.
fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    (0..n).map(|c| (c * len / n, (c + 1) * len / n)).collect()
}

impl RingNode {
    /// Ring all-reduce; `finish` is applied to this worker's fully-reduced
    /// chunk between the reduce-scatter and all-gather phases (e.g. the
    /// 1/n averaging scale).
    fn allreduce_with(&self, buf: &mut [f32], finish: impl Fn(&mut [f32])) {
        let n = self.n;
        if n == 1 {
            finish(buf);
            return;
        }
        let bounds = chunk_bounds(buf.len(), n);
        // Reduce-scatter: after step s, the chunk received from the left
        // holds s+2 contributions; after n-1 steps worker w owns the
        // complete sum of chunk (w+1)%n.
        for s in 0..n - 1 {
            let send_c = (self.id + n - s) % n;
            let recv_c = (self.id + n - s - 1) % n;
            let (lo, hi) = bounds[send_c];
            self.tx_right.send(buf[lo..hi].to_vec()).expect("ring send");
            let incoming = self.rx_left.recv().expect("ring recv");
            let (lo, hi) = bounds[recv_c];
            debug_assert_eq!(hi - lo, incoming.len());
            for (b, v) in buf[lo..hi].iter_mut().zip(&incoming) {
                *b += v;
            }
        }
        let (lo, hi) = bounds[(self.id + 1) % n];
        finish(&mut buf[lo..hi]);
        // All-gather: circulate the completed chunks.
        for s in 0..n - 1 {
            let send_c = (self.id + 1 + n - s) % n;
            let recv_c = (self.id + n - s) % n;
            let (lo, hi) = bounds[send_c];
            self.tx_right.send(buf[lo..hi].to_vec()).expect("ring send");
            let incoming = self.rx_left.recv().expect("ring recv");
            let (lo, hi) = bounds[recv_c];
            debug_assert_eq!(hi - lo, incoming.len());
            buf[lo..hi].copy_from_slice(&incoming);
        }
    }

    /// In-place sum-all-reduce over all ring participants.
    pub fn allreduce_sum(&self, buf: &mut [f32]) {
        self.allreduce_with(buf, |_| {});
    }

    /// In-place average-all-reduce (sum then scale by 1/n, with the scale
    /// applied once per chunk on its owning worker — the same `*= 1/n as
    /// f32` the sequential fabric performs).
    pub fn allreduce_avg(&self, buf: &mut [f32]) {
        let inv = 1.0 / self.n as f32;
        self.allreduce_with(buf, |chunk| {
            chunk.iter_mut().for_each(|v| *v *= inv);
        });
    }
}

/// One worker's endpoint in a gather star rooted at worker 0.
pub struct StarNode {
    pub id: usize,
    pub n: usize,
    /// workers 1..n: channel to the root
    to_root: Option<Sender<SparseGrad>>,
    /// root only: one receiver per worker 1..n, in worker order
    from_workers: Option<Vec<Receiver<SparseGrad>>>,
}

/// Build the star: a dedicated channel from every worker to worker 0, so
/// the root drains contributions in worker order regardless of scheduling.
pub fn star(n: usize) -> Vec<StarNode> {
    assert!(n >= 1);
    let mut senders = Vec::with_capacity(n.saturating_sub(1));
    let mut receivers = Vec::with_capacity(n.saturating_sub(1));
    for _ in 1..n {
        let (tx, rx) = channel();
        senders.push(Some(tx));
        receivers.push(rx);
    }
    (0..n)
        .map(|id| StarNode {
            id,
            n,
            to_root: if id == 0 {
                None
            } else {
                senders[id - 1].take()
            },
            from_workers: if id == 0 { Some(receivers.drain(..).collect()) } else { None },
        })
        .collect()
}

impl StarNode {
    /// Gather every worker's sparse gradient at the root. Returns
    /// `Some(contributions)` on the root — ordered by worker id, the
    /// root's own first — and `None` on the other workers.
    pub fn gather(&self, contribution: SparseGrad) -> Option<Vec<SparseGrad>> {
        match &self.from_workers {
            Some(rxs) => {
                let mut all = Vec::with_capacity(self.n);
                all.push(contribution);
                for rx in rxs {
                    all.push(rx.recv().expect("gather recv"));
                }
                Some(all)
            }
            None => {
                self.to_root
                    .as_ref()
                    .expect("non-root star node has a root sender")
                    .send(contribution)
                    .expect("gather send");
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::floats::allclose;
    use crate::util::rng::Rng;

    /// Run `f(node, w)` on one thread per ring node, returning results in
    /// worker order.
    fn on_ring<T: Send>(
        n: usize,
        f: impl Fn(&RingNode, usize) -> T + Sync,
    ) -> Vec<T> {
        let nodes = ring(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|node| {
                    let f = &f;
                    s.spawn(move || f(&node, node.id))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker")).collect()
        })
    }

    #[test]
    fn ring_allreduce_sums_across_lengths_and_ns() {
        for n in [1usize, 2, 3, 4, 7, 8] {
            for len in [0usize, 1, 2, n.saturating_sub(1), n, 3 * n + 1, 100] {
                let mut rng = Rng::new((n * 1000 + len) as u64);
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut v = vec![0.0f32; len];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    })
                    .collect();
                let mut expect = vec![0.0f32; len];
                for v in &inputs {
                    for (e, &x) in expect.iter_mut().zip(v) {
                        *e += x;
                    }
                }
                let inputs_ref = &inputs;
                let results = on_ring(n, |node, w| {
                    let mut buf = inputs_ref[w].clone();
                    node.allreduce_sum(&mut buf);
                    buf
                });
                for (w, r) in results.iter().enumerate() {
                    if let Err(i) = allclose(r, &expect, 1e-5, 1e-5) {
                        panic!("n={n} len={len} worker {w} coord {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn ring_allreduce_avg_divides_by_n() {
        let n = 4;
        let results = on_ring(n, |node, w| {
            let mut buf = vec![(w + 1) as f32; 8];
            node.allreduce_avg(&mut buf);
            buf
        });
        // avg of 1,2,3,4 = 2.5 everywhere, on every worker
        for r in &results {
            assert!(r.iter().all(|&v| (v - 2.5).abs() < 1e-6), "{r:?}");
        }
    }

    #[test]
    fn ring_is_deterministic_across_runs() {
        let run = || {
            on_ring(5, |node, w| {
                let mut buf: Vec<f32> = (0..31)
                    .map(|i| ((w * 31 + i) as f32 * 0.7).sin())
                    .collect();
                node.allreduce_avg(&mut buf);
                buf
            })
        };
        let a = run();
        let b = run();
        // bit-identical, not just close: the dataflow fixes the fp order
        assert_eq!(a, b);
    }

    #[test]
    fn star_gathers_in_worker_order() {
        let n = 6;
        let nodes = star(n);
        let gathered = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|node| {
                    s.spawn(move || {
                        let sg = SparseGrad::new(
                            8,
                            vec![node.id as u32],
                            vec![node.id as f32],
                        );
                        node.gather(sg)
                    })
                })
                .collect();
            handles
                .into_iter()
                .filter_map(|h| h.join().expect("worker"))
                .next()
                .expect("root result")
        });
        assert_eq!(gathered.len(), n);
        for (w, sg) in gathered.iter().enumerate() {
            assert_eq!(sg.indices, vec![w as u32], "order must follow worker id");
        }
    }

    #[test]
    fn backends_from_args_resolves_filter_or_both() {
        let to = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<String>>();
        assert_eq!(
            backends_from_args(&to(&["bench", "--quick"])),
            vec![Backend::Sequential, Backend::Threaded]
        );
        assert_eq!(
            backends_from_args(&to(&["bench", "--backend", "threaded"])),
            vec![Backend::Threaded]
        );
        assert_eq!(
            backends_from_args(&to(&["bench", "--backend", "seq"])),
            vec![Backend::Sequential]
        );
    }

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("sequential").unwrap(), Backend::Sequential);
        assert_eq!(Backend::parse("seq").unwrap(), Backend::Sequential);
        assert_eq!(Backend::parse("threaded").unwrap(), Backend::Threaded);
        assert!(Backend::parse("gpu").is_err());
        assert_eq!(Backend::Threaded.label(), "threaded");
        assert_eq!(Backend::default(), Backend::Sequential);
    }

    #[test]
    fn single_worker_ring_is_identity_for_sum() {
        let results = on_ring(1, |node, _| {
            let mut buf = vec![1.5f32, -2.0];
            node.allreduce_sum(&mut buf);
            buf
        });
        assert_eq!(results[0], vec![1.5, -2.0]);
    }
}
