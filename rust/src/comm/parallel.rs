//! Message-passing collectives for the threaded backend.
//!
//! The sequential backend executes every collective as a loop on the
//! calling thread. This module provides the concurrent counterpart: each
//! worker runs on its own OS thread and exchanges data over `mpsc`
//! channels wired into two fixed topologies:
//!
//!   - a **ring** (each worker owns one sender to its right neighbor and
//!     one receiver from its left) carrying the commutative reduce —
//!     standard reduce-scatter + all-gather, the algorithm Remark 3 says
//!     CLT-k "can naturally be extended to";
//!   - a **star** (workers → root) carrying the gather that
//!     non-commutative schemes (local top-k) are forced into.
//!
//! Message counts mirror the analytic `CommCost` model: a ring all-reduce
//! moves 2·(n−1) chunk messages of ≈len/n elements per port, exactly the
//! `2·bytes·(n−1)/n` per-port term `Fabric` charges.
//!
//! ## Determinism contract
//!
//! Every receiver has exactly one producer and channels are FIFO, so the
//! dataflow — and therefore every floating-point reduction order — is a
//! pure function of (n, payload), independent of OS scheduling. Repeated
//! threaded runs are bit-identical. Against the *sequential* backend the
//! reduction order differs (ring order is a rotation per chunk, the
//! sequential loop always sums worker 0..n), so f32 sums may differ by
//! rounding; `rust/tests/backend_parity.rs` pins the tolerance
//! (rtol 1e-5, atol 1e-6). Index sets, byte accounting, and `CommStats`
//! match exactly.

use crate::comm::GatherStats;
use crate::compress::SparseGrad;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Execution backend for the coordination step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Single-threaded loops over workers (the reference semantics).
    #[default]
    Sequential,
    /// Thread-per-worker engine with channel collectives; threads are
    /// scoped per step.
    Threaded,
    /// Persistent worker pool (spawned once per run) that double-buffers
    /// steps: step t+1's EF-gradient/selection compute overlaps step t's
    /// in-flight collective (`runtime::pipelined`).
    Pipelined,
    /// The pipelined pool with its lane internals swapped for a real TCP
    /// transport: every ring/star hop crosses a loopback socket through
    /// the `comm::wire` framing codec (`comm::socket`). Same staged
    /// `submit`/`wait` seam, same determinism contract; multi-process
    /// rings are launched per-node via `scalecom node`
    /// (`runtime::socket`).
    Socket,
}

impl Backend {
    /// Every selectable backend, in documentation order. The single
    /// source of truth for bench CLIs and the label/parse round-trip.
    pub const ALL: [Backend; 4] = [
        Backend::Sequential,
        Backend::Threaded,
        Backend::Pipelined,
        Backend::Socket,
    ];

    pub fn parse(s: &str) -> anyhow::Result<Backend> {
        match s {
            "sequential" | "seq" => Ok(Backend::Sequential),
            "threaded" | "thr" => Ok(Backend::Threaded),
            "pipelined" | "pipe" => Ok(Backend::Pipelined),
            "socket" | "sock" => Ok(Backend::Socket),
            other => {
                anyhow::bail!(
                    "unknown backend '{other}' (expected sequential|threaded|pipelined|socket)"
                )
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Sequential => "sequential",
            Backend::Threaded => "threaded",
            Backend::Pipelined => "pipelined",
            Backend::Socket => "socket",
        }
    }

    /// Backends that run on the persistent worker pool — lane-owned
    /// memories, staged collectives, `step_overlapped` lookahead
    /// (`runtime::pipelined::WorkerPool`).
    pub fn is_pooled(&self) -> bool {
        matches!(self, Backend::Pipelined | Backend::Socket)
    }
}

/// Shared bench-CLI helper: resolve a `--backend <name>` argument into
/// the set of backends to run — all of `Backend::ALL` when the flag is
/// absent, so every bench compares them side by side by default.
pub fn backends_from_args(args: &[String]) -> Vec<Backend> {
    match args.iter().position(|a| a == "--backend") {
        Some(i) => {
            let value = args
                .get(i + 1)
                .expect("--backend requires a value (sequential|threaded|pipelined|socket)");
            vec![Backend::parse(value).expect("--backend sequential|threaded|pipelined|socket")]
        }
        None => Backend::ALL.to_vec(),
    }
}

/// One worker's endpoints in a unidirectional ring of `n` workers.
pub struct RingNode {
    pub id: usize,
    pub n: usize,
    tx_right: Sender<Vec<f32>>,
    rx_left: Receiver<Vec<f32>>,
}

/// Build the ring: channel `i` carries messages worker `i` → `(i+1)%n`.
pub fn ring(n: usize) -> Vec<RingNode> {
    assert!(n >= 1);
    let mut txs = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<Vec<f32>>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    txs.into_iter()
        .enumerate()
        .map(|(id, tx_right)| RingNode {
            id,
            n,
            tx_right,
            rx_left: rxs[(id + n - 1) % n].take().expect("ring wiring"),
        })
        .collect()
}

/// Balanced chunk boundaries: chunk `c` covers `[c*len/n, (c+1)*len/n)`.
/// Public because the virtual-time simulator (`simnet`) replays the ring
/// schedule message-for-message and must charge the exact chunk sizes
/// the real collective moves.
pub fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    (0..n).map(|c| (c * len / n, (c + 1) * len / n)).collect()
}

/// Reduce-scatter round `s` of the ring schedule: the `(send, recv)`
/// chunk ids for worker `id`. One definition shared by the executing
/// collective ([`ring_allreduce_generic`]) and the `simnet` replay, so
/// the simulated timeline charges exactly the messages the real ring
/// moves.
pub fn reduce_scatter_round(id: usize, n: usize, s: usize) -> (usize, usize) {
    ((id + n - s) % n, (id + n - s - 1) % n)
}

/// All-gather round `s` of the ring schedule (see
/// [`reduce_scatter_round`]).
pub fn all_gather_round(id: usize, n: usize, s: usize) -> (usize, usize) {
    ((id + 1 + n - s) % n, (id + n - s) % n)
}

/// Validate a hierarchical group size against the worker count — the
/// single definition shared by the executable topologies
/// ([`hier_ring`], `comm::socket`, `runtime::socket`) and the simnet
/// `hier` profile, so simulation and execution accept exactly the same
/// configurations and reject the rest with the same remedy.
///
/// `group_size` 0 or 1 selects the flat ring and is always valid; a
/// hierarchical group size must divide `n` evenly and leave at least two
/// groups for the leader ring.
pub fn validate_group_size(n: usize, group_size: usize) -> anyhow::Result<()> {
    if group_size <= 1 {
        return Ok(());
    }
    anyhow::ensure!(
        n % group_size == 0,
        "group size {group_size} does not divide {n} workers evenly; \
         pick a divisor of {n}, or 0 for the flat ring"
    );
    anyhow::ensure!(
        n / group_size >= 2,
        "group size {group_size} leaves a single group at {n} workers — \
         the leader ring needs at least 2 groups; \
         pick a group size of at most {}, or 0 for the flat ring",
        n / 2
    );
    Ok(())
}

/// Multi-level CLT-k leader election: decompose step `t`'s flat cyclic
/// leader (`t % n`, ScaleCom's build-up-free rotation) into
/// `(group, member)` coordinates of the two-level topology. The flat
/// leader id is preserved — `group * group_size + member == t % n` — so
/// hierarchical runs select exactly the indices the flat ring selects,
/// with no per-level state to build up.
pub fn hier_leader(t: u64, n: usize, group_size: usize) -> (usize, usize) {
    assert!(n >= 1 && group_size >= 1);
    let leader = (t % n as u64) as usize;
    (leader / group_size, leader % group_size)
}

/// The ring all-reduce schedule, generic over how a chunk crosses to the
/// neighbor — the transport seam. The channel mesh (`RingNode`) and the
/// TCP mesh (`comm::socket::SocketRingNode`) both run exactly this code,
/// so their chunk schedules — and therefore every f32 reduction order —
/// are identical by construction, not by parallel maintenance.
///
/// `finish` is applied to this worker's fully-reduced chunk between the
/// reduce-scatter and all-gather phases (e.g. the 1/n averaging scale).
pub(crate) fn ring_allreduce_generic(
    id: usize,
    n: usize,
    buf: &mut [f32],
    finish: &dyn Fn(&mut [f32]),
    send: &mut dyn FnMut(&[f32]) -> anyhow::Result<()>,
    recv: &mut dyn FnMut() -> anyhow::Result<Vec<f32>>,
) -> anyhow::Result<()> {
    if n == 1 {
        finish(buf);
        return Ok(());
    }
    let bounds = chunk_bounds(buf.len(), n);
    // Zero-width chunks (len < n) move no message: the send is skipped
    // here and the matching recv is skipped on the neighbor — chunk c is
    // zero-width for every worker, so both sides agree round by round and
    // the schedule's round count is unchanged. No empty f32 frame ever
    // crosses a channel or the socket wire, and the simnet replay charges
    // the same (zero) bytes.
    //
    // Reduce-scatter: after step s, the chunk received from the left
    // holds s+2 contributions; after n-1 steps worker w owns the
    // complete sum of chunk (w+1)%n.
    for s in 0..n - 1 {
        let (send_c, recv_c) = reduce_scatter_round(id, n, s);
        let (lo, hi) = bounds[send_c];
        if hi > lo {
            send(&buf[lo..hi])?;
        }
        let (lo, hi) = bounds[recv_c];
        if hi > lo {
            let incoming = recv()?;
            anyhow::ensure!(
                hi - lo == incoming.len(),
                "ring chunk size mismatch: expected {}, got {} (peer out of sync)",
                hi - lo,
                incoming.len()
            );
            for (b, v) in buf[lo..hi].iter_mut().zip(&incoming) {
                *b += v;
            }
        }
    }
    let (lo, hi) = bounds[(id + 1) % n];
    finish(&mut buf[lo..hi]);
    // All-gather: circulate the completed chunks.
    for s in 0..n - 1 {
        let (send_c, recv_c) = all_gather_round(id, n, s);
        let (lo, hi) = bounds[send_c];
        if hi > lo {
            send(&buf[lo..hi])?;
        }
        let (lo, hi) = bounds[recv_c];
        if hi > lo {
            let incoming = recv()?;
            anyhow::ensure!(
                hi - lo == incoming.len(),
                "ring chunk size mismatch: expected {}, got {} (peer out of sync)",
                hi - lo,
                incoming.len()
            );
            buf[lo..hi].copy_from_slice(&incoming);
        }
    }
    Ok(())
}

impl RingNode {
    /// Ring all-reduce; `finish` is applied to this worker's fully-reduced
    /// chunk between the reduce-scatter and all-gather phases (e.g. the
    /// 1/n averaging scale).
    pub(crate) fn allreduce_with(&self, buf: &mut [f32], finish: impl Fn(&mut [f32])) {
        let mut send = |chunk: &[f32]| -> anyhow::Result<()> {
            self.tx_right
                .send(chunk.to_vec())
                .map_err(|_| anyhow::anyhow!("ring send: right neighbor gone"))
        };
        let mut recv = || -> anyhow::Result<Vec<f32>> {
            self.rx_left
                .recv()
                .map_err(|_| anyhow::anyhow!("ring recv: left neighbor gone"))
        };
        ring_allreduce_generic(self.id, self.n, buf, &finish, &mut send, &mut recv)
            .expect("channel ring failed (every endpoint lives in-process)");
    }

    /// In-place sum-all-reduce over all ring participants.
    pub fn allreduce_sum(&self, buf: &mut [f32]) {
        self.allreduce_with(buf, |_| {});
    }

    /// In-place average-all-reduce (sum then scale by 1/n, with the scale
    /// applied once per chunk on its owning worker — the same `*= 1/n as
    /// f32` the sequential fabric performs).
    pub fn allreduce_avg(&self, buf: &mut [f32]) {
        let inv = 1.0 / self.n as f32;
        self.allreduce_with(buf, |chunk| {
            chunk.iter_mut().for_each(|v| *v *= inv);
        });
    }

    /// Raw hop along the ring's right edge. The hierarchical exchange's
    /// broadcast leg reuses the intra-group links for the finished
    /// result, so no extra channels exist outside the two rings.
    pub(crate) fn send_right(&self, v: Vec<f32>) {
        self.tx_right
            .send(v)
            .expect("ring send: right neighbor gone (in-process mesh)");
    }

    /// Raw hop from the ring's left edge (see [`RingNode::send_right`]).
    pub(crate) fn recv_left(&self) -> Vec<f32> {
        self.rx_left
            .recv()
            .expect("ring recv: left neighbor gone (in-process mesh)")
    }
}

/// One worker's endpoints in a two-level ring-of-rings of `n` workers
/// split into `n / group_size` groups: an intra-group ring over the
/// group's members, plus — on the group leader (member 0) — an uplink
/// ring over the per-group leaders. The hierarchical all-reduce runs
///
///   1. intra-group ring all-reduce (sum): reduce-scatter + all-gather
///      over the member ring, so every member holds the group sum;
///   2. leader ring all-reduce over the uplink, `finish` applied once
///      per chunk on its owning leader (the 1/n scale);
///   3. broadcast of the finished buffer down the group chain (leader →
///      member 1 → … → member m−1 over the intra right links).
///
/// Both levels run [`ring_allreduce_generic`], so the two-level chunk
/// schedule is the flat helpers composed — exactly what simnet's `hier`
/// profile replays.
pub struct HierRingNode {
    /// Global worker id in `0..n`.
    pub id: usize,
    pub n: usize,
    pub group_size: usize,
    /// Intra-group ring; its `id` is this worker's member index.
    intra: RingNode,
    /// Leader ring over the uplink (member 0 only); its `id` is the
    /// group index.
    up: Option<RingNode>,
}

/// Build the channel-backed two-level mesh: one intra ring per group of
/// `group_size` consecutive workers, one uplink ring over the group
/// leaders (workers `0, group_size, 2·group_size, …`).
pub fn hier_ring(n: usize, group_size: usize) -> anyhow::Result<Vec<HierRingNode>> {
    validate_group_size(n, group_size)?;
    anyhow::ensure!(
        group_size >= 2,
        "hier_ring: group size {group_size} selects the flat ring — build `ring({n})` instead"
    );
    let m = group_size;
    let ngroups = n / m;
    let mut uplink: Vec<Option<RingNode>> = ring(ngroups).into_iter().map(Some).collect();
    let mut out = Vec::with_capacity(n);
    for grp in 0..ngroups {
        for (j, intra) in ring(m).into_iter().enumerate() {
            out.push(HierRingNode {
                id: grp * m + j,
                n,
                group_size: m,
                intra,
                up: if j == 0 { uplink[grp].take() } else { None },
            });
        }
    }
    Ok(out)
}

impl HierRingNode {
    pub(crate) fn allreduce_with(&self, buf: &mut [f32], finish: impl Fn(&mut [f32])) {
        // Phase 1: intra-group sum — every member ends with the group sum.
        self.intra.allreduce_sum(buf);
        // Phase 2: leader ring over the uplink carries the group sums;
        // `finish` lands exactly once per chunk, on its owning leader.
        if let Some(up) = &self.up {
            up.allreduce_with(buf, &finish);
        }
        // Phase 3: the finished result flows down the group chain. A
        // zero-length buffer moved no chunks above and moves no
        // broadcast either.
        if buf.is_empty() {
            return;
        }
        if self.up.is_some() {
            self.intra.send_right(buf.to_vec());
        } else {
            let incoming = self.intra.recv_left();
            buf.copy_from_slice(&incoming);
            if self.intra.id + 1 < self.group_size {
                self.intra.send_right(incoming);
            }
        }
    }

    /// In-place sum-all-reduce over all `n` workers.
    pub fn allreduce_sum(&self, buf: &mut [f32]) {
        self.allreduce_with(buf, |_| {});
    }

    /// In-place average-all-reduce (the leader ring applies the global
    /// 1/n scale once per chunk).
    pub fn allreduce_avg(&self, buf: &mut [f32]) {
        let inv = 1.0 / self.n as f32;
        self.allreduce_with(buf, |chunk| {
            chunk.iter_mut().for_each(|v| *v *= inv);
        });
    }
}

/// One worker's endpoint in a gather star rooted at worker 0.
pub struct StarNode {
    pub id: usize,
    pub n: usize,
    /// workers 1..n: channel to the root
    to_root: Option<Sender<SparseGrad>>,
    /// root only: one receiver per worker 1..n, in worker order
    from_workers: Option<Vec<Receiver<SparseGrad>>>,
}

/// Build the star: a dedicated channel from every worker to worker 0, so
/// the root drains contributions in worker order regardless of scheduling.
pub fn star(n: usize) -> Vec<StarNode> {
    assert!(n >= 1);
    let mut senders = Vec::with_capacity(n.saturating_sub(1));
    let mut receivers = Vec::with_capacity(n.saturating_sub(1));
    for _ in 1..n {
        let (tx, rx) = channel();
        senders.push(Some(tx));
        receivers.push(rx);
    }
    (0..n)
        .map(|id| StarNode {
            id,
            n,
            to_root: if id == 0 {
                None
            } else {
                senders[id - 1].take()
            },
            from_workers: if id == 0 { Some(receivers.drain(..).collect()) } else { None },
        })
        .collect()
}

impl StarNode {
    /// Gather every worker's sparse gradient at the root. Returns
    /// `Some(contributions)` on the root — ordered by worker id, the
    /// root's own first — and `None` on the other workers.
    pub fn gather(&self, contribution: SparseGrad) -> Option<Vec<SparseGrad>> {
        match &self.from_workers {
            Some(rxs) => {
                let mut all = Vec::with_capacity(self.n);
                all.push(contribution);
                for rx in rxs {
                    all.push(rx.recv().expect("gather recv"));
                }
                Some(all)
            }
            None => {
                self.to_root
                    .as_ref()
                    .expect("non-root star node has a root sender")
                    .send(contribution)
                    .expect("gather send");
                None
            }
        }
    }
}

// ----------------------------------------------------------------------
// Staged (non-blocking) collectives for the pipelined backend
// ----------------------------------------------------------------------

/// One collective's payload, submitted per worker to its comm lane.
/// Every worker of a step must carry the same job kind and tags.
/// Monolithic collectives use bucket 0; the bucketed exchange submits
/// one tagged job set per bucket and the lanes multiplex them — FIFO per
/// lane, so per-bucket collectives complete in submission order, and on
/// the socket transport every wire frame carries the tag (verified on
/// receive) so interleaved buckets can never mix.
///
/// `job` is the serve-plane tenant tag: one-shot runs use job 0 (which
/// keeps legacy framing byte-identical on the wire), while the serve
/// daemon stamps each admitted job's id so concurrent jobs sharing the
/// mesh can never consume each other's frames — exactly the bucket-tag
/// contract, one level up.
pub enum CommJob {
    /// In-place ring all-reduce **average** of this worker's buffer.
    RingAvg { job: u32, bucket: u32, buf: Vec<f32> },
    /// Star-gather this worker's sparse contribution; the root reduces
    /// in worker order (the exact `Fabric::sparse_gather_avg` arithmetic).
    Gather { job: u32, bucket: u32, sparse: SparseGrad },
}

/// Completion of one staged collective, delivered by the root lane in
/// submission order, echoing the submission's job and bucket tags.
#[derive(Debug)]
pub enum CollectiveResult {
    /// Ring all-reduce: the fully reduced (averaged) buffer.
    Reduced { job: u32, bucket: u32, vals: Vec<f32> },
    /// Star gather: root-reduced dense average + the wire-shape summary
    /// for the analytic cost model.
    Gathered {
        job: u32,
        bucket: u32,
        vals: Vec<f32>,
        stats: GatherStats,
    },
    /// The collective failed on a lane (socket transport only: a dead or
    /// mis-framed peer). The channel mesh cannot produce this.
    Failed(String),
}

/// What the lane mesh is made of. The collectives, the staged
/// `submit`/`wait` seam, and the determinism contract are identical —
/// only the bytes' carrier changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LaneTransport {
    /// In-process mpsc channels (backends `threaded` / `pipelined`).
    #[default]
    Channel,
    /// Loopback TCP sockets through the `comm::wire` codec (backend
    /// `socket`): every hop pays real framing + kernel round-trips. The
    /// payload carries this mesh's entropy-codec configuration.
    Socket(crate::comm::codec::WireCodecConfig),
}

/// A lane's ring endpoint on either transport and either topology.
enum LaneRing {
    Channel(RingNode),
    ChannelHier(HierRingNode),
    Socket(crate::comm::socket::SocketRingNode),
    SocketHier(crate::comm::socket::SocketHierRingNode),
}

impl LaneRing {
    fn allreduce_avg(&mut self, job: u32, bucket: u32, buf: &mut [f32]) -> anyhow::Result<()> {
        match self {
            // The channel mesh needs no tags: each edge is a dedicated
            // FIFO channel, so in-flight buckets cannot interleave out
            // of order by construction.
            LaneRing::Channel(r) => {
                r.allreduce_avg(buf);
                Ok(())
            }
            LaneRing::ChannelHier(r) => {
                r.allreduce_avg(buf);
                Ok(())
            }
            // The socket mesh stamps (and verifies) the tags on every
            // frame — see `comm::wire`. The hierarchical mesh adds a
            // level tag so intra-group and uplink streams can never mix;
            // job tags keep concurrent serve tenants apart the same way.
            LaneRing::Socket(r) => r.allreduce_avg_tagged(job, bucket, buf),
            LaneRing::SocketHier(r) => r.allreduce_avg_tagged(job, bucket, buf),
        }
    }
}

/// A lane's star endpoint on either transport.
enum LaneStar {
    Channel(StarNode),
    Socket(crate::comm::socket::SocketStarNode),
}

impl LaneStar {
    fn gather(
        &mut self,
        job: u32,
        bucket: u32,
        sg: SparseGrad,
    ) -> anyhow::Result<Option<Vec<SparseGrad>>> {
        match self {
            LaneStar::Channel(s) => Ok(s.gather(sg)),
            LaneStar::Socket(s) => s.gather_tagged(job, bucket, sg),
        }
    }
}

/// Persistent staged-collective engine: one long-lived comm thread per
/// worker, each owning its ring and star endpoints for the whole run
/// (PR 1's scoped engine rebuilt the channel mesh every step). Jobs
/// execute FIFO per lane; because each mesh channel has a single
/// producer, a lane may already be sending step t+1's chunks while a
/// neighbor is still reducing step t — receivers drain messages in step
/// order, so in-flight steps never mix. The dataflow (and therefore
/// every f32 reduction order) stays a pure function of (n, payloads):
/// the `comm::parallel` determinism contract is unchanged.
///
/// `submit` returns immediately (the non-blocking half of the handle);
/// `wait` blocks for the oldest in-flight collective's result.
pub struct CommLanes {
    jobs: Vec<Sender<CommJob>>,
    results: Receiver<CollectiveResult>,
    threads: Vec<JoinHandle<()>>,
    /// Shared entropy-codec counters of the socket mesh (`None` on the
    /// channel transport, which ships no bytes).
    codec: Option<crate::comm::codec::CodecStats>,
}

impl CommLanes {
    pub fn new(n: usize) -> CommLanes {
        Self::with_transport(n, LaneTransport::Channel)
            .expect("the channel mesh needs no OS resources and cannot fail")
    }

    /// Build the lane mesh on the chosen transport with the flat ring
    /// topology. `Socket` binds one loopback TCP pair per mesh edge
    /// (ephemeral ports), which can fail if the OS refuses the sockets.
    pub fn with_transport(n: usize, transport: LaneTransport) -> anyhow::Result<CommLanes> {
        Self::with_topology(n, transport, 0)
    }

    /// Build the lane mesh on the chosen transport and ring topology:
    /// `group_size` 0 (or 1) runs the flat ring, >= 2 runs the two-level
    /// ring-of-rings ([`hier_ring`] / `comm::socket::local_hier_ring`).
    /// The star gather stays single-level — only the dense ring
    /// collective is hierarchical.
    pub fn with_topology(
        n: usize,
        transport: LaneTransport,
        group_size: usize,
    ) -> anyhow::Result<CommLanes> {
        assert!(n >= 1, "comm lanes need at least one worker");
        validate_group_size(n, group_size)?;
        let hier = group_size >= 2;
        let mut codec = None;
        let (rings, stars): (Vec<LaneRing>, Vec<LaneStar>) = match transport {
            LaneTransport::Channel => (
                if hier {
                    hier_ring(n, group_size)?
                        .into_iter()
                        .map(LaneRing::ChannelHier)
                        .collect()
                } else {
                    ring(n).into_iter().map(LaneRing::Channel).collect()
                },
                star(n).into_iter().map(LaneStar::Channel).collect(),
            ),
            LaneTransport::Socket(wire_cfg) => {
                let timeout = crate::comm::socket::default_timeout()?;
                let stats = crate::comm::codec::CodecStats::new();
                let rings = if hier {
                    crate::comm::socket::local_hier_ring(
                        n, group_size, timeout, wire_cfg, &stats,
                    )?
                    .into_iter()
                    .map(LaneRing::SocketHier)
                    .collect()
                } else {
                    crate::comm::socket::local_ring(n, timeout, wire_cfg, &stats)?
                        .into_iter()
                        .map(LaneRing::Socket)
                        .collect()
                };
                let mesh = (
                    rings,
                    crate::comm::socket::local_star(n, timeout, wire_cfg, &stats)?
                        .into_iter()
                        .map(LaneStar::Socket)
                        .collect(),
                );
                codec = Some(stats);
                mesh
            }
        };
        let (root_tx, results) = channel();
        let mut jobs = Vec::with_capacity(n);
        let mut threads = Vec::with_capacity(n);
        for (w, (ring_node, star_node)) in rings.into_iter().zip(stars).enumerate() {
            let (tx, rx) = channel::<CommJob>();
            // Worker 0 roots both topologies (exactly like the scoped
            // engine), so it alone reports results.
            let root = (w == 0).then(|| root_tx.clone());
            threads.push(std::thread::spawn(move || {
                comm_lane_loop(ring_node, star_node, rx, root)
            }));
            jobs.push(tx);
        }
        Ok(CommLanes {
            jobs,
            results,
            threads,
            codec,
        })
    }

    pub fn workers(&self) -> usize {
        self.jobs.len()
    }

    /// Roll up the socket mesh's entropy-codec counters. Default
    /// (all-zero) snapshot on the channel transport.
    pub fn codec_snapshot(&self) -> crate::comm::codec::CodecSnapshot {
        self.codec
            .as_ref()
            .map(|s| s.snapshot())
            .unwrap_or_default()
    }

    /// Launch one collective: one job per worker, all the same kind.
    /// Returns as soon as the jobs are enqueued — the exchange runs on
    /// the lane threads while the caller computes.
    pub fn submit(&self, jobs: Vec<CommJob>) {
        let _sp = crate::obs::span(crate::obs::Category::LaneSubmit);
        assert_eq!(jobs.len(), self.jobs.len(), "one job per worker");
        for (tx, job) in self.jobs.iter().zip(jobs) {
            tx.send(job).expect("comm lane send");
        }
    }

    /// A clone of worker `w`'s job queue, for embedding inside a worker
    /// thread that forwards its own jobs (the pipelined pool).
    pub fn job_sender(&self, w: usize) -> Sender<CommJob> {
        self.jobs[w].clone()
    }

    /// Block until the oldest in-flight collective completes.
    pub fn wait(&self) -> CollectiveResult {
        let _sp = crate::obs::span(crate::obs::Category::LaneWait);
        self.results.recv().expect("comm lane result")
    }
}

impl Drop for CommLanes {
    fn drop(&mut self) {
        // Dropping the job senders ends each lane loop; external
        // `job_sender` clones (pool compute lanes) must be dropped by
        // their owners first — `WorkerPool::drop` joins its compute
        // threads before dropping its `CommLanes`.
        self.jobs.clear();
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

fn comm_lane_loop(
    mut ring_node: LaneRing,
    mut star_node: LaneStar,
    rx: Receiver<CommJob>,
    root: Option<Sender<CollectiveResult>>,
) {
    while let Ok(next) = rx.recv() {
        let outcome: anyhow::Result<Option<CollectiveResult>> = match next {
            CommJob::RingAvg { job, bucket, mut buf } => ring_node
                .allreduce_avg(job, bucket, &mut buf)
                .map(|()| Some(CollectiveResult::Reduced { job, bucket, vals: buf })),
            CommJob::Gather { job, bucket, sparse } => {
                let dim = sparse.dim;
                star_node.gather(job, bucket, sparse).map(|gathered| {
                    gathered.map(|all| {
                        // One shared definition of the gather arithmetic
                        // (worker-order root reduction) for every backend.
                        let (acc, gs) = crate::comm::fabric::reduce_gathered(&all, dim);
                        CollectiveResult::Gathered {
                            job,
                            bucket,
                            vals: acc,
                            stats: gs,
                        }
                    })
                })
            }
        };
        match outcome {
            Ok(Some(result)) => {
                if let Some(tx) = &root {
                    let _ = tx.send(result);
                }
            }
            Ok(None) => {} // non-root gather participant
            Err(e) => {
                // A socket lane lost a peer (or saw garbage): report once
                // if we root the mesh, then stop — the stream is
                // mis-framed beyond recovery. Closing our endpoints
                // propagates EOFs around the ring so every lane halts
                // within one read timeout instead of hanging.
                if let Some(tx) = &root {
                    let _ = tx.send(CollectiveResult::Failed(format!("{e:#}")));
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::floats::allclose;
    use crate::util::rng::Rng;

    /// Run `f(node, w)` on one thread per ring node, returning results in
    /// worker order.
    fn on_ring<T: Send>(
        n: usize,
        f: impl Fn(&RingNode, usize) -> T + Sync,
    ) -> Vec<T> {
        let nodes = ring(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|node| {
                    let f = &f;
                    s.spawn(move || f(&node, node.id))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker")).collect()
        })
    }

    #[test]
    fn ring_allreduce_sums_across_lengths_and_ns() {
        for n in [1usize, 2, 3, 4, 7, 8] {
            for len in [0usize, 1, 2, n.saturating_sub(1), n, 3 * n + 1, 100] {
                let mut rng = Rng::new((n * 1000 + len) as u64);
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut v = vec![0.0f32; len];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    })
                    .collect();
                let mut expect = vec![0.0f32; len];
                for v in &inputs {
                    for (e, &x) in expect.iter_mut().zip(v) {
                        *e += x;
                    }
                }
                let inputs_ref = &inputs;
                let results = on_ring(n, |node, w| {
                    let mut buf = inputs_ref[w].clone();
                    node.allreduce_sum(&mut buf);
                    buf
                });
                for (w, r) in results.iter().enumerate() {
                    if let Err(i) = allclose(r, &expect, 1e-5, 1e-5) {
                        panic!("n={n} len={len} worker {w} coord {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn ring_allreduce_avg_divides_by_n() {
        let n = 4;
        let results = on_ring(n, |node, w| {
            let mut buf = vec![(w + 1) as f32; 8];
            node.allreduce_avg(&mut buf);
            buf
        });
        // avg of 1,2,3,4 = 2.5 everywhere, on every worker
        for r in &results {
            assert!(r.iter().all(|&v| (v - 2.5).abs() < 1e-6), "{r:?}");
        }
    }

    #[test]
    fn ring_is_deterministic_across_runs() {
        let run = || {
            on_ring(5, |node, w| {
                let mut buf: Vec<f32> = (0..31)
                    .map(|i| ((w * 31 + i) as f32 * 0.7).sin())
                    .collect();
                node.allreduce_avg(&mut buf);
                buf
            })
        };
        let a = run();
        let b = run();
        // bit-identical, not just close: the dataflow fixes the fp order
        assert_eq!(a, b);
    }

    #[test]
    fn star_gathers_in_worker_order() {
        let n = 6;
        let nodes = star(n);
        let gathered = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|node| {
                    s.spawn(move || {
                        let sg = SparseGrad::new(
                            8,
                            vec![node.id as u32],
                            vec![node.id as f32],
                        );
                        node.gather(sg)
                    })
                })
                .collect();
            handles
                .into_iter()
                .filter_map(|h| h.join().expect("worker"))
                .next()
                .expect("root result")
        });
        assert_eq!(gathered.len(), n);
        for (w, sg) in gathered.iter().enumerate() {
            assert_eq!(sg.indices, vec![w as u32], "order must follow worker id");
        }
    }

    #[test]
    fn backends_from_args_resolves_filter_or_all() {
        let to = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<String>>();
        assert_eq!(
            backends_from_args(&to(&["bench", "--quick"])),
            Backend::ALL.to_vec()
        );
        assert_eq!(
            backends_from_args(&to(&["bench", "--backend", "threaded"])),
            vec![Backend::Threaded]
        );
        assert_eq!(
            backends_from_args(&to(&["bench", "--backend", "seq"])),
            vec![Backend::Sequential]
        );
        assert_eq!(
            backends_from_args(&to(&["bench", "--backend", "pipelined"])),
            vec![Backend::Pipelined]
        );
        assert_eq!(
            backends_from_args(&to(&["bench", "--backend", "socket"])),
            vec![Backend::Socket]
        );
    }

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("sequential").unwrap(), Backend::Sequential);
        assert_eq!(Backend::parse("seq").unwrap(), Backend::Sequential);
        assert_eq!(Backend::parse("threaded").unwrap(), Backend::Threaded);
        assert_eq!(Backend::parse("pipe").unwrap(), Backend::Pipelined);
        assert_eq!(Backend::parse("socket").unwrap(), Backend::Socket);
        assert_eq!(Backend::parse("sock").unwrap(), Backend::Socket);
        assert!(Backend::parse("gpu").is_err());
        assert_eq!(Backend::Threaded.label(), "threaded");
        assert_eq!(Backend::Socket.label(), "socket");
        assert_eq!(Backend::default(), Backend::Sequential);
        assert!(Backend::Socket.is_pooled() && Backend::Pipelined.is_pooled());
        assert!(!Backend::Sequential.is_pooled() && !Backend::Threaded.is_pooled());
    }

    #[test]
    fn every_backend_label_roundtrips_through_parse() {
        // Benches route --backend through `Backend::parse`; every label a
        // bench can print must parse back to the same variant.
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.label()).unwrap(), b, "{}", b.label());
        }
    }

    #[test]
    fn comm_lanes_ring_avg_matches_scoped_ring() {
        for n in [1usize, 2, 3, 8] {
            let len = 41;
            let mut rng = Rng::new(n as u64 + 77);
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0.0f32; len];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            // scoped reference (the threaded engine's path)
            let inputs_ref = &inputs;
            let expect = on_ring(n, |node, w| {
                let mut buf = inputs_ref[w].clone();
                node.allreduce_avg(&mut buf);
                (node.id == 0).then_some(buf)
            })
            .into_iter()
            .flatten()
            .next()
            .expect("ring root");
            // staged lanes
            let lanes = CommLanes::new(n);
            lanes.submit(
                inputs
                    .iter()
                    .map(|v| CommJob::RingAvg { job: 0, bucket: 0, buf: v.clone() })
                    .collect(),
            );
            match lanes.wait() {
                CollectiveResult::Reduced { job, bucket, vals } => {
                    // same ring, same chunk schedule → bit-identical
                    assert_eq!((job, bucket), (0, 0));
                    assert_eq!(vals, expect, "n={n}");
                }
                other => panic!("expected ring result, got {other:?}"),
            }
        }
    }

    #[test]
    fn comm_lanes_pipeline_two_steps_in_flight_stay_ordered() {
        // Submit two collectives back-to-back before waiting: the mesh
        // channels carry both steps' chunks concurrently, and results
        // must come back in submission order with correct values.
        let n = 4;
        let step = |bucket: u32, base: f32| -> Vec<CommJob> {
            (0..n)
                .map(|w| CommJob::RingAvg {
                    job: 0,
                    bucket,
                    buf: vec![base + w as f32; 16],
                })
                .collect()
        };
        let lanes = CommLanes::new(n);
        lanes.submit(step(3, 1.0)); // avg of 1,2,3,4 = 2.5
        lanes.submit(step(4, 10.0)); // avg of 10,11,12,13 = 11.5
        for (want_bucket, expect) in [(3u32, 2.5f32), (4, 11.5)] {
            match lanes.wait() {
                CollectiveResult::Reduced { job: _, bucket, vals } => {
                    assert_eq!(bucket, want_bucket, "results echo submission tags in order");
                    assert!(vals.iter().all(|&x| (x - expect).abs() < 1e-6), "{vals:?}");
                }
                other => panic!("expected ring result, got {other:?}"),
            }
        }
    }

    #[test]
    fn comm_lanes_gather_is_bit_identical_to_fabric() {
        use crate::comm::{Fabric, FabricConfig};
        let n = 5;
        let dim = 32;
        let mut rng = Rng::new(21);
        let sparses: Vec<SparseGrad> = (0..n)
            .map(|w| {
                let mut vals = vec![0.0f32; 4];
                rng.fill_normal(&mut vals, 1.0);
                let idx: Vec<u32> = (0..4u32).map(|i| i * 3 + w as u32).collect();
                SparseGrad::new(dim, idx, vals)
            })
            .collect();
        let lanes = CommLanes::new(n);
        lanes.submit(
            sparses
                .iter()
                .map(|s| CommJob::Gather { job: 0, bucket: 0, sparse: s.clone() })
                .collect(),
        );
        let (avg, gs) = match lanes.wait() {
            CollectiveResult::Gathered { job, bucket, vals, stats } => {
                assert_eq!((job, bucket), (0, 0));
                (vals, stats)
            }
            other => panic!("expected gather result, got {other:?}"),
        };
        let mut fabric = Fabric::new(FabricConfig {
            workers: n,
            ..FabricConfig::default()
        });
        let expect = fabric.sparse_gather_avg(&sparses);
        assert_eq!(avg, expect);
        assert_eq!(gs, GatherStats::from_sparses(&sparses));
    }

    #[test]
    fn socket_lanes_match_channel_lanes_bit_for_bit() {
        // Same staged seam, same chunk schedule, bit-exact wire: the two
        // transports must be indistinguishable on both collective kinds.
        for n in [1usize, 2, 4] {
            let dim = 33;
            let mut rng = Rng::new(n as u64 + 5);
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0.0f32; dim];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            let sparses: Vec<SparseGrad> = (0..n)
                .map(|w| {
                    SparseGrad::new(
                        dim,
                        vec![w as u32, (w + n) as u32],
                        vec![1.0 + w as f32, -0.5],
                    )
                })
                .collect();
            let chan = CommLanes::new(n);
            let sock = CommLanes::with_transport(
                n,
                LaneTransport::Socket(crate::comm::codec::WireCodecConfig::default()),
            )
            .expect("loopback socket mesh");
            for lanes in [&chan, &sock] {
                lanes.submit(
                    inputs
                        .iter()
                        .map(|v| CommJob::RingAvg { job: 7, bucket: 2, buf: v.clone() })
                        .collect(),
                );
                lanes.submit(
                    sparses
                        .iter()
                        .map(|s| CommJob::Gather { job: 7, bucket: 5, sparse: s.clone() })
                        .collect(),
                );
            }
            match (chan.wait(), sock.wait()) {
                (
                    CollectiveResult::Reduced { job: ja, bucket: ba, vals: a },
                    CollectiveResult::Reduced { job: jb, bucket: bb, vals: b },
                ) => {
                    assert_eq!((ja, jb), (7, 7), "ring job tags n={n}");
                    assert_eq!((ba, bb), (2, 2), "ring tags n={n}");
                    assert_eq!(a, b, "ring n={n}");
                }
                other => panic!("expected two ring results, got {other:?}"),
            }
            match (chan.wait(), sock.wait()) {
                (
                    CollectiveResult::Gathered { job: ja, bucket: ba, vals: a, stats: ga },
                    CollectiveResult::Gathered { job: jb, bucket: bb, vals: b, stats: gb },
                ) => {
                    assert_eq!((ja, jb), (7, 7), "gather job tags n={n}");
                    assert_eq!((ba, bb), (5, 5), "gather tags n={n}");
                    assert_eq!(a, b, "gather n={n}");
                    assert_eq!(ga, gb, "gather stats n={n}");
                }
                other => panic!("expected two gather results, got {other:?}"),
            }
        }
    }

    #[test]
    fn socket_lanes_interleave_two_jobs_without_crosstalk() {
        // Two tenants alternating on one socket mesh: every result must
        // echo its submission's job tag in FIFO order, and values from
        // one job must never leak into the other. Job 0 rides the legacy
        // frames, job 9 the v5 job-tagged frames — same mesh, same step.
        let n = 3;
        let lanes = CommLanes::with_transport(
            n,
            LaneTransport::Socket(crate::comm::codec::WireCodecConfig::default()),
        )
        .expect("loopback socket mesh");
        for round in 0..3u32 {
            for (job, base) in [(0u32, 1.0f32), (9, 100.0)] {
                lanes.submit(
                    (0..n)
                        .map(|w| CommJob::RingAvg {
                            job,
                            bucket: round,
                            buf: vec![base + w as f32; 8],
                        })
                        .collect(),
                );
            }
        }
        for round in 0..3u32 {
            for (want_job, expect) in [(0u32, 2.0f32), (9, 101.0)] {
                match lanes.wait() {
                    CollectiveResult::Reduced { job, bucket, vals } => {
                        assert_eq!((job, bucket), (want_job, round));
                        assert!(
                            vals.iter().all(|&x| (x - expect).abs() < 1e-6),
                            "job {want_job} round {round}: {vals:?}"
                        );
                    }
                    other => panic!("expected ring result, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn single_worker_ring_is_identity_for_sum() {
        let results = on_ring(1, |node, _| {
            let mut buf = vec![1.5f32, -2.0];
            node.allreduce_sum(&mut buf);
            buf
        });
        assert_eq!(results[0], vec![1.5, -2.0]);
    }

    #[test]
    fn group_size_validation_accepts_flat_and_exact_tilings() {
        for (n, g) in [(1, 0), (4, 0), (4, 1), (4, 2), (8, 2), (8, 4), (16, 4), (64, 8)] {
            validate_group_size(n, g).unwrap_or_else(|e| panic!("n={n} g={g}: {e:#}"));
        }
    }

    #[test]
    fn group_size_validation_rejects_bad_tilings_with_a_remedy() {
        // does not divide
        let e = format!("{:#}", validate_group_size(12, 8).unwrap_err());
        assert!(e.contains("does not divide"), "{e}");
        assert!(e.contains("flat ring"), "remedy named: {e}");
        // a single group: no leader ring
        let e = format!("{:#}", validate_group_size(4, 4).unwrap_err());
        assert!(e.contains("single group"), "{e}");
        assert!(e.contains("at least 2 groups"), "{e}");
        // trivially degenerate
        assert!(validate_group_size(3, 2).is_err());
    }

    #[test]
    fn hier_leader_preserves_the_flat_cyclic_rotation() {
        let (n, g) = (8usize, 4usize);
        for t in 0..20u64 {
            let (grp, member) = hier_leader(t, n, g);
            assert_eq!(grp * g + member, (t % n as u64) as usize, "t={t}");
            assert!(grp < n / g && member < g);
        }
        // flat group size 1: member is always 0, group is the leader
        assert_eq!(hier_leader(5, 4, 1), (1, 0));
    }

    /// Run `f(node, w)` on one thread per hier node, results in worker
    /// order.
    fn on_hier<T: Send>(
        n: usize,
        g: usize,
        f: impl Fn(&HierRingNode, usize) -> T + Sync,
    ) -> Vec<T> {
        let nodes = hier_ring(n, g).expect("valid tiling");
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|node| {
                    let f = &f;
                    s.spawn(move || f(&node, node.id))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker")).collect()
        })
    }

    #[test]
    fn hier_ring_allreduce_matches_the_flat_sum_across_shapes() {
        for (n, g) in [(4usize, 2usize), (8, 2), (8, 4), (16, 4)] {
            for len in [0usize, 1, 3, g - 1, n - 1, n, 4 * n + 3] {
                let mut rng = Rng::new((n * 100 + g * 10 + len) as u64);
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut v = vec![0.0f32; len];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    })
                    .collect();
                let mut expect = vec![0.0f32; len];
                for v in &inputs {
                    for (e, &x) in expect.iter_mut().zip(v) {
                        *e += x;
                    }
                }
                let inputs_ref = &inputs;
                let results = on_hier(n, g, |node, w| {
                    let mut buf = inputs_ref[w].clone();
                    node.allreduce_sum(&mut buf);
                    buf
                });
                for (w, r) in results.iter().enumerate() {
                    if let Err(i) = allclose(r, &expect, 1e-5, 1e-5) {
                        panic!("n={n} g={g} len={len} worker {w} coord {i}");
                    }
                }
                // every worker ends bit-identical: the broadcast copies
                // the leader's buffer verbatim
                for r in &results[1..] {
                    assert_eq!(r, &results[0], "n={n} g={g} len={len}");
                }
            }
        }
    }

    #[test]
    fn hier_ring_avg_divides_by_global_n() {
        let (n, g) = (8, 4);
        let results = on_hier(n, g, |node, w| {
            let mut buf = vec![(w + 1) as f32; 12];
            node.allreduce_avg(&mut buf);
            buf
        });
        // avg of 1..=8 = 4.5 on every worker
        for r in &results {
            assert!(r.iter().all(|&v| (v - 4.5).abs() < 1e-5), "{r:?}");
        }
    }

    #[test]
    fn hier_ring_is_deterministic_across_runs() {
        let run = || {
            on_hier(8, 2, |node, w| {
                let mut buf: Vec<f32> = (0..29)
                    .map(|i| ((w * 29 + i) as f32 * 0.3).cos())
                    .collect();
                node.allreduce_avg(&mut buf);
                buf
            })
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn hier_ring_rejects_invalid_tilings() {
        assert!(hier_ring(12, 8).is_err());
        assert!(hier_ring(4, 4).is_err(), "single group has no leader ring");
        assert!(hier_ring(8, 1).is_err(), "flat sizes belong to ring()");
    }

    #[test]
    fn hier_lanes_match_flat_lanes_within_tolerance() {
        // Same data through the flat and hierarchical channel lanes: the
        // reduction *order* differs (per-group first), so values agree to
        // the backend-parity tolerance, not bitwise.
        let (n, g) = (8usize, 2usize);
        let len = 37;
        let mut rng = Rng::new(99);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let flat = CommLanes::new(n);
        let hier = CommLanes::with_topology(n, LaneTransport::Channel, g).expect("hier lanes");
        for lanes in [&flat, &hier] {
            lanes.submit(
                inputs
                    .iter()
                    .map(|v| CommJob::RingAvg { job: 0, bucket: 1, buf: v.clone() })
                    .collect(),
            );
        }
        match (flat.wait(), hier.wait()) {
            (
                CollectiveResult::Reduced { vals: a, .. },
                CollectiveResult::Reduced { vals: b, .. },
            ) => {
                if let Err(i) = allclose(&a, &b, 1e-5, 1e-6) {
                    panic!("flat vs hier diverge at coord {i}");
                }
            }
            other => panic!("expected two ring results, got {other:?}"),
        }
    }

    #[test]
    fn lanes_reject_a_bad_group_size() {
        let err = CommLanes::with_topology(6, LaneTransport::Channel, 4).unwrap_err();
        assert!(format!("{err:#}").contains("does not divide"), "{err:#}");
    }
}
