//! TCP transport for the collectives: the same ring / star dataflow as
//! the mpsc mesh in `comm::parallel`, with every hop crossing a real
//! socket through the `comm::wire` framing codec.
//!
//! Two deployment shapes share this module:
//!
//! - **loopback mesh** ([`local_ring`] / [`local_star`]): all endpoints
//!   live in one process, wired over `127.0.0.1` socket pairs — the lane
//!   internals behind `Backend::Socket` (see `CommLanes::with_transport`);
//! - **multi-process mesh** ([`form_mesh`]): one process per worker,
//!   rendezvousing over a static peer list (`runtime::socket` drives it).
//!
//! ## Design notes
//!
//! - **No send-side blocking (but bounded memory).** A ring step has
//!   every node sending and receiving at once; if sends wrote to the
//!   socket on the caller's thread, n full kernel buffers could deadlock
//!   the ring. Every outgoing link therefore owns a writer thread
//!   ([`FramedSender`]) fed by a queue, so the staged (pipelined)
//!   driving mode works unchanged over sockets. The queue is **bounded**
//!   ([`DEFAULT_SEND_QUEUE_FRAMES`]): a healthy mesh never comes close
//!   to the bound, a slow peer gets backpressure (bounded wait), and a
//!   stalled peer trips it into a clean latched lane fault — surfaced as
//!   `CollectiveResult::Failed` by the lane — instead of silent
//!   unbounded memory growth.
//! - **Entropy codec.** Each endpoint owns a `codec::FrameCodec`
//!   (configured by the mesh-wide `WireCodecConfig`) with pooled
//!   encode/decode buffers: multi-MB dense chunks re-use the same
//!   staging allocations frame after frame. The rendezvous `Hello`
//!   carries `wire::WIRE_CODEC_VERSION`, and a peer too old to decode
//!   packed frames is rejected at handshake with a clear error.
//! - **Bounded waiting.** Every receiver carries a read timeout and
//!   every sender's stream a write timeout ([`default_timeout`],
//!   override with `SCALECOM_SOCKET_TIMEOUT_SECS`), and a killed peer
//!   surfaces as EOF/reset immediately: a fault — dead *or* wedged
//!   peer — ends a collective with a clean `anyhow` error, never a
//!   hang. Errors propagate around the ring as EOFs, so every surviving
//!   node fails within one timeout.
//! - **Bit-identical reduction.** The ring schedule is literally the
//!   same code as the channel mesh (`ring_allreduce_generic`), and f32
//!   payloads travel as raw IEEE-754 bits, so socket-backend results are
//!   bit-identical to the pipelined backend's and sit inside the same
//!   parity contract vs sequential (rtol 1e-5 / atol 1e-6 on ring f32).

use crate::comm::codec::{CodecStats, FrameCodec, WireCodecConfig};
use crate::comm::cost::RttSnapshot;
use crate::comm::parallel::ring_allreduce_generic;
use crate::comm::wire::{self, Purpose, WireMsg};
use crate::compress::SparseGrad;
use crate::obs;
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Read/rendezvous timeout: `SCALECOM_SOCKET_TIMEOUT_SECS` (integer
/// seconds, >= 1) or 30 s when unset. Bounds every blocking socket
/// wait, so a wedged peer becomes a clean error instead of a hang.
/// A *set but invalid* value (0, negative, non-numeric) is a hard error
/// rather than a silent fallback: an operator who typed the variable
/// meant it, and a typo quietly becoming "30 seconds" (or 0 becoming
/// "fail every read instantly") is exactly the kind of config drift
/// multi-host deployments cannot debug.
pub fn default_timeout() -> anyhow::Result<Duration> {
    let raw = std::env::var("SCALECOM_SOCKET_TIMEOUT_SECS").ok();
    parse_timeout_secs(raw.as_deref())
}

/// The pure parse behind [`default_timeout`] (`None` = variable unset).
pub fn parse_timeout_secs(raw: Option<&str>) -> anyhow::Result<Duration> {
    match raw {
        None => Ok(Duration::from_secs(30)),
        Some(s) => {
            let secs: u64 = s.trim().parse().map_err(|_| {
                anyhow::anyhow!(
                    "SCALECOM_SOCKET_TIMEOUT_SECS must be a whole number of \
                     seconds >= 1, got '{s}'"
                )
            })?;
            anyhow::ensure!(
                secs >= 1,
                "SCALECOM_SOCKET_TIMEOUT_SECS must be >= 1 second (0 would \
                 fail every socket wait instantly), got '{s}'"
            );
            Ok(Duration::from_secs(secs))
        }
    }
}

// ----------------------------------------------------------------------
// Heartbeat RTT accounting
// ----------------------------------------------------------------------

/// Process-global heartbeat round-trip accumulator. The Ping/Pong seq
/// exchange (wire v3) already round-trips on every heartbeat link; the
/// liveness monitors feed the measured RTTs here, and the coordinator /
/// serve snapshot paths pull [`rtt_snapshot`] into `CommStats.rtt` and
/// the `/metrics` gauge. Process-global on purpose: links come and go
/// (reconnect, mesh teardown) and the monitors are deep inside the
/// sender machinery — a shared atomic accumulator needs no plumbing
/// through the mesh constructors and costs four relaxed adds per pong.
struct GlobalRtt {
    count: AtomicU64,
    sum_ns: AtomicU64,
    min_ns: AtomicU64,
    max_ns: AtomicU64,
}

static RTT: GlobalRtt = GlobalRtt {
    count: AtomicU64::new(0),
    sum_ns: AtomicU64::new(0),
    min_ns: AtomicU64::new(u64::MAX),
    max_ns: AtomicU64::new(0),
};

fn rtt_record_ns(ns: u64) {
    RTT.count.fetch_add(1, Ordering::Relaxed);
    RTT.sum_ns.fetch_add(ns, Ordering::Relaxed);
    RTT.min_ns.fetch_min(ns, Ordering::Relaxed);
    RTT.max_ns.fetch_max(ns, Ordering::Relaxed);
}

/// Min/mean/max of every heartbeat RTT measured in this process so far.
pub fn rtt_snapshot() -> RttSnapshot {
    let count = RTT.count.load(Ordering::Relaxed);
    if count == 0 {
        return RttSnapshot::default();
    }
    RttSnapshot {
        count,
        min_ns: RTT.min_ns.load(Ordering::Relaxed),
        mean_ns: RTT.sum_ns.load(Ordering::Relaxed) / count,
        max_ns: RTT.max_ns.load(Ordering::Relaxed),
    }
}

// ----------------------------------------------------------------------
// Framed endpoints
// ----------------------------------------------------------------------

/// Queue bound of a [`FramedSender`]: frames a link may hold undrained
/// before sends start waiting (and, past the queue timeout, fault). A
/// healthy collective keeps a handful of frames in flight per link;
/// hundreds queued means the peer stopped draining.
pub const DEFAULT_SEND_QUEUE_FRAMES: usize = 1024;

/// Shared state of a [`FramedSender`]'s bounded queue. One mutex guards
/// the queue, the shutdown bit, and the error latch together so a fault
/// latched by any thread (writer, liveness monitor, or a timed-out
/// `send`) is observed atomically with the queue state.
struct SendState {
    q: VecDeque<WireMsg>,
    /// Set by `Drop`: the writer drains what is queued, then exits.
    closed: bool,
    /// First fault on this link (write error, heartbeat loss, queue
    /// stall). Once set, every `send` fails fast with it.
    err: Option<String>,
}

struct SendShared {
    state: Mutex<SendState>,
    /// Signaled when the writer pops (room for senders) or a fault lands.
    not_full: Condvar,
    /// Signaled when a sender pushes, a fault lands, or `Drop` closes.
    not_empty: Condvar,
}

impl SendShared {
    /// Latch `e` as this link's fault (first writer wins) and wake every
    /// thread parked on either condition.
    fn latch(&self, e: String) {
        let mut st = self.state.lock().expect("sender queue state");
        if st.err.is_none() {
            st.err = Some(e);
        }
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Non-blocking enqueue for the liveness thread's pings: skipped
    /// when the queue is full (a backed-up link is about to fault on its
    /// own) or the link already faulted.
    fn try_push(&self, cap: usize, msg: WireMsg) {
        let mut st = self.state.lock().expect("sender queue state");
        if st.err.is_none() && !st.closed && st.q.len() < cap {
            st.q.push_back(msg);
            self.not_empty.notify_one();
        }
    }
}

/// Framed sender: messages are handed to a dedicated writer thread over
/// a **bounded** queue. The writer owns a [`FrameCodec`] and one frame
/// staging buffer, so encoding (packing, optional byte compression)
/// happens off the collective's thread with zero per-frame allocation.
/// A write failure is latched and reported by the next `send`; dropping
/// the sender flushes what was queued and joins the thread. The stream
/// gets a **write timeout** so a stalled-but-alive peer (full receive
/// buffer, wedged host) errors the writer thread out instead of
/// blocking it forever — without it, `Drop`'s join could hang the node
/// and break the bounded-waiting contract.
///
/// `send` does not block on a healthy mesh; with the queue at its bound
/// it **parks on a condvar** (no busy-spin — a multi-MB frame draining
/// at link speed costs zero CPU on the blocked sender) until the writer
/// pops, the link faults, or the queue timeout expires, which latches a
/// clean fault that names the stall instead of accumulating frames
/// without limit.
///
/// With a heartbeat configured ([`FramedSender::with_heartbeat`]), a
/// liveness thread additionally enqueues a `Ping` every interval and
/// reads the peer's `Pong`s off the reverse direction of the same TCP
/// stream; no pong for 2× the interval latches a heartbeat fault, so a
/// dead or wedged peer surfaces within a bounded window even while this
/// node is between collectives (not blocked in any read).
pub struct FramedSender {
    shared: Arc<SendShared>,
    writer: Option<JoinHandle<()>>,
    liveness: Option<JoinHandle<()>>,
    /// Stops the liveness thread (checked on every read-timeout tick).
    stop: Arc<AtomicBool>,
    queue_cap: usize,
    queue_timeout: Duration,
}

impl FramedSender {
    pub fn new(
        stream: TcpStream,
        write_timeout: Duration,
        codec: FrameCodec,
    ) -> anyhow::Result<FramedSender> {
        FramedSender::build(
            stream,
            write_timeout,
            codec,
            DEFAULT_SEND_QUEUE_FRAMES,
            write_timeout,
            None,
        )
    }

    /// [`FramedSender::new`] with explicit queue bound and queue-full
    /// wait (tests shrink both to trip the bound quickly).
    pub fn with_queue(
        stream: TcpStream,
        write_timeout: Duration,
        codec: FrameCodec,
        queue_cap: usize,
        queue_timeout: Duration,
    ) -> anyhow::Result<FramedSender> {
        FramedSender::build(stream, write_timeout, codec, queue_cap, queue_timeout, None)
    }

    /// [`FramedSender::new`] plus the heartbeat liveness monitor:
    /// `interval` between pings, detection within 2× `interval` of pong
    /// silence.
    pub fn with_heartbeat(
        stream: TcpStream,
        write_timeout: Duration,
        codec: FrameCodec,
        interval: Duration,
    ) -> anyhow::Result<FramedSender> {
        FramedSender::build(
            stream,
            write_timeout,
            codec,
            DEFAULT_SEND_QUEUE_FRAMES,
            write_timeout,
            Some(interval),
        )
    }

    fn build(
        stream: TcpStream,
        write_timeout: Duration,
        mut codec: FrameCodec,
        queue_cap: usize,
        queue_timeout: Duration,
        heartbeat: Option<Duration>,
    ) -> anyhow::Result<FramedSender> {
        assert!(queue_cap >= 1, "a zero-capacity send queue would rendezvous");
        stream.set_write_timeout(Some(write_timeout.max(Duration::from_millis(1))))?;
        let shared = Arc::new(SendShared {
            state: Mutex::new(SendState {
                q: VecDeque::new(),
                closed: false,
                err: None,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        });
        let stop = Arc::new(AtomicBool::new(false));

        let liveness = match heartbeat {
            Some(interval) => {
                let interval = interval.max(Duration::from_millis(1));
                let monitor = stream
                    .try_clone()
                    .map_err(|e| anyhow::anyhow!("clone stream for heartbeat monitor: {e}"))?;
                Some(spawn_sender_liveness(
                    monitor,
                    shared.clone(),
                    stop.clone(),
                    interval,
                    queue_cap,
                )?)
            }
            None => None,
        };

        let wshared = shared.clone();
        let writer = std::thread::spawn(move || {
            let mut w = BufWriter::new(stream);
            let mut frame = Vec::new();
            loop {
                let msg = {
                    let _qw = obs::span(obs::Category::QueueWait);
                    let mut st = wshared.state.lock().expect("sender queue state");
                    loop {
                        if st.err.is_some() {
                            return;
                        }
                        if let Some(m) = st.q.pop_front() {
                            wshared.not_full.notify_all();
                            break m;
                        }
                        if st.closed {
                            return;
                        }
                        st = wshared.not_empty.wait(st).expect("sender queue state");
                    }
                };
                let encoded = {
                    let _enc = obs::span(obs::Category::CodecEncode);
                    codec.encode_frame_into(&msg, &mut frame)
                };
                let res = encoded.and_then(|()| {
                    let _ww = obs::span(obs::Category::WireWrite);
                    w.write_all(&frame)
                        .and_then(|()| w.flush())
                        .map_err(anyhow::Error::from)
                });
                if let Err(e) = res {
                    wshared.latch(format!("{e:#}"));
                    return;
                }
            }
        });
        Ok(FramedSender {
            shared,
            writer: Some(writer),
            liveness,
            stop,
            queue_cap,
            queue_timeout,
        })
    }

    /// The link's latched fault, if any (write error, heartbeat loss,
    /// queue stall). Lets callers observe a dead link without sending.
    pub fn fault(&self) -> Option<String> {
        self.shared.state.lock().expect("sender queue state").err.clone()
    }

    /// Queue one message. Does not block while the queue has room;
    /// fails if the writer thread has already hit a socket error (e.g.
    /// the peer died), the heartbeat monitor declared the peer dead, or
    /// the queue stays full past the queue timeout (receiver stopped
    /// draining). Waits park on a condvar — no polling.
    pub fn send(&self, msg: WireMsg) -> anyhow::Result<()> {
        let deadline = Instant::now() + self.queue_timeout;
        let mut st = self.shared.state.lock().expect("sender queue state");
        loop {
            if let Some(e) = &st.err {
                anyhow::bail!("socket send failed: {e}");
            }
            if st.closed {
                anyhow::bail!("socket writer thread exited (peer closed?)");
            }
            if st.q.len() < self.queue_cap {
                st.q.push_back(msg);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                let e = format!(
                    "send queue full: peer has not drained {} queued frames within \
                     {:?} (stalled receiver)",
                    self.queue_cap, self.queue_timeout
                );
                st.err = Some(e.clone());
                drop(st);
                self.shared.not_full.notify_all();
                self.shared.not_empty.notify_all();
                anyhow::bail!("socket send failed: {e}");
            }
            let (guard, _) = self
                .shared
                .not_full
                .wait_timeout(st, deadline - now)
                .expect("sender queue state");
            st = guard;
        }
    }
}

impl Drop for FramedSender {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("sender queue state");
            st.closed = true; // writer drains the queue, then exits
        }
        self.shared.not_empty.notify_all();
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
        if let Some(h) = self.liveness.take() {
            let _ = h.join();
        }
    }
}

/// The sender-side heartbeat loop: enqueue a `Ping` every `interval`,
/// read `Pong`s off the reverse direction of the data stream, and latch
/// a fault when pong silence exceeds 2× `interval`. EOF or a reset on
/// the reverse read latches immediately — a SIGKILLed peer is detected
/// at the next tick, not after the grace window.
fn spawn_sender_liveness(
    monitor: TcpStream,
    shared: Arc<SendShared>,
    stop: Arc<AtomicBool>,
    interval: Duration,
    queue_cap: usize,
) -> anyhow::Result<JoinHandle<()>> {
    // Wake at least every interval/2 so ping cadence and the stop flag
    // are both honored promptly.
    monitor.set_read_timeout(Some((interval / 2).max(Duration::from_millis(1))))?;
    Ok(std::thread::spawn(move || {
        let mut monitor = monitor;
        let grace = interval * 2;
        let mut dec = wire::FrameDecoder::new();
        let mut tmp = [0u8; 4096];
        let mut seq: u32 = 0;
        let mut next_ping = Instant::now();
        let mut last_pong = Instant::now();
        // Send instants of the pings still awaiting their pong, oldest
        // first, for the RTT measurement. Bounded: a ping whose pong
        // never arrives (skipped enqueue, overloaded peer) ages out.
        let mut in_flight: VecDeque<(u32, Instant)> = VecDeque::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            if Instant::now() >= next_ping {
                shared.try_push(queue_cap, WireMsg::Ping { seq });
                if in_flight.len() >= 64 {
                    in_flight.pop_front();
                }
                in_flight.push_back((seq, Instant::now()));
                seq = seq.wrapping_add(1);
                next_ping = Instant::now() + interval;
            }
            match monitor.read(&mut tmp) {
                Ok(0) => {
                    shared.latch("peer closed the connection (EOF on heartbeat channel)".into());
                    return;
                }
                Ok(k) => match dec.push(&tmp[..k]) {
                    Ok(msgs) => {
                        for m in &msgs {
                            if let WireMsg::Pong { seq: pong_seq } = m {
                                last_pong = Instant::now();
                                if let Some(pos) =
                                    in_flight.iter().position(|(s, _)| s == pong_seq)
                                {
                                    rtt_record_ns(
                                        in_flight[pos].1.elapsed().as_nanos() as u64
                                    );
                                    // The peer answers in order: earlier
                                    // pings without a pong are lost.
                                    in_flight.drain(..=pos);
                                }
                            }
                        }
                    }
                    Err(e) => {
                        shared.latch(format!("mis-framed heartbeat channel: {e:#}"));
                        return;
                    }
                },
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(e) => {
                    shared.latch(format!("heartbeat channel read failed: {e}"));
                    return;
                }
            }
            if last_pong.elapsed() > grace {
                shared.latch(format!(
                    "peer dead (heartbeat): no pong for {:?} (> {grace:?} = 2x the \
                     {interval:?} heartbeat interval)",
                    last_pong.elapsed()
                ));
                return;
            }
        }
    }))
}

/// Blocking framed receiver with a read timeout. Owns a [`FrameCodec`]
/// and one body staging buffer, reused across frames — a stream of
/// multi-MB dense chunks costs zero per-frame allocation for the wire
/// bytes (the decoded payload vectors are owned by the messages).
///
/// With a heartbeat configured ([`FramedReceiver::with_heartbeat`]) the
/// stream is instead owned by a dedicated reader thread that decodes
/// continuously, answers the peer's `Ping`s with `Pong`s on the reverse
/// direction of the stream (so the peer's liveness monitor sees this
/// node alive even while it is busy computing), and latches a fault
/// when the peer goes silent for 2× the interval — the peer pings every
/// interval, so silence past the grace window means it is dead or
/// wedged. `recv` then drains the reader's bounded channel.
pub struct FramedReceiver {
    timeout: Duration,
    inner: ReceiverImpl,
}

enum ReceiverImpl {
    Direct {
        r: BufReader<TcpStream>,
        codec: FrameCodec,
        body: Vec<u8>,
    },
    Threaded {
        rx: std::sync::mpsc::Receiver<anyhow::Result<WireMsg>>,
        stop: Arc<AtomicBool>,
        /// Clone used only to shut the socket down on drop, unblocking
        /// the reader thread immediately.
        shutdown: TcpStream,
        thread: Option<JoinHandle<()>>,
    },
}

impl FramedReceiver {
    pub fn new(
        stream: TcpStream,
        timeout: Duration,
        codec: FrameCodec,
    ) -> anyhow::Result<FramedReceiver> {
        stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1))))?;
        Ok(FramedReceiver {
            timeout,
            inner: ReceiverImpl::Direct {
                r: BufReader::new(stream),
                codec,
                body: Vec::new(),
            },
        })
    }

    /// [`FramedReceiver::new`] plus the heartbeat responder/monitor:
    /// the peer pings every `interval`; this side answers pongs and
    /// declares the peer dead after 2× `interval` of silence.
    pub fn with_heartbeat(
        stream: TcpStream,
        timeout: Duration,
        codec: FrameCodec,
        interval: Duration,
    ) -> anyhow::Result<FramedReceiver> {
        let interval = interval.max(Duration::from_millis(1));
        let shutdown = stream
            .try_clone()
            .map_err(|e| anyhow::anyhow!("clone stream for receiver shutdown: {e}"))?;
        // Wake at least every interval/2: answer pings promptly, notice
        // silence within the grace window, honor the stop flag.
        stream.set_read_timeout(Some((interval / 2).max(Duration::from_millis(1))))?;
        stream.set_write_timeout(Some(timeout.max(Duration::from_millis(1))))?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = std::sync::mpsc::sync_channel(DEFAULT_SEND_QUEUE_FRAMES);
        let rstop = stop.clone();
        let thread = std::thread::spawn(move || {
            receiver_loop(stream, codec, tx, rstop, interval);
        });
        Ok(FramedReceiver {
            timeout,
            inner: ReceiverImpl::Threaded {
                rx,
                stop,
                shutdown,
                thread: Some(thread),
            },
        })
    }

    fn recv_inner(&mut self) -> anyhow::Result<WireMsg> {
        match &mut self.inner {
            ReceiverImpl::Direct { r, codec, body } => {
                let mut header = [0u8; 4];
                r.read_exact(&mut header)?;
                let len = wire::check_body_len(u32::from_le_bytes(header))?;
                body.clear();
                body.resize(len, 0);
                {
                    // Body bytes are in flight once the header arrived —
                    // the header wait itself is idle time, not wire time.
                    let _rr = obs::span(obs::Category::WireRead);
                    r.read_exact(body)?;
                }
                let _cd = obs::span(obs::Category::CodecDecode);
                codec.decode_body(body)
            }
            ReceiverImpl::Threaded { rx, .. } => match rx.recv_timeout(self.timeout) {
                Ok(res) => res,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    anyhow::bail!("no frame within the read timeout")
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("reader thread exited (link fault already reported)")
                }
            },
        }
    }

    pub fn recv(&mut self) -> anyhow::Result<WireMsg> {
        use anyhow::Context;
        self.recv_inner().with_context(|| {
            format!(
                "socket read failed (peer dead, stalled past the {:?} timeout, \
                 or mis-framed)",
                self.timeout
            )
        })
    }
}

impl Drop for FramedReceiver {
    fn drop(&mut self) {
        if let ReceiverImpl::Threaded { stop, shutdown, thread, .. } = &mut self.inner {
            stop.store(true, Ordering::Relaxed);
            let _ = shutdown.shutdown(std::net::Shutdown::Both);
            if let Some(h) = thread.take() {
                let _ = h.join();
            }
        }
    }
}

/// The heartbeat-mode reader loop: decode every arriving frame, answer
/// pings in-line, forward data frames, and track peer silence.
fn receiver_loop(
    mut stream: TcpStream,
    mut codec: FrameCodec,
    tx: std::sync::mpsc::SyncSender<anyhow::Result<WireMsg>>,
    stop: Arc<AtomicBool>,
    interval: Duration,
) {
    let grace = interval * 2;
    let mut dec = wire::FrameDecoder::new();
    let mut tmp = [0u8; 64 * 1024];
    let mut last_frame = Instant::now();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                let _ = tx.send(Err(anyhow::anyhow!("peer closed the connection (EOF)")));
                return;
            }
            Ok(k) => {
                last_frame = Instant::now();
                // Raw frame reassembly only — decode through the pooled
                // codec so packed/compressed frames and stats behave
                // exactly like the direct path.
                let frames = match dec.push_frames(&tmp[..k]) {
                    Ok(f) => f,
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        return;
                    }
                };
                for body in frames {
                    let decoded = {
                        let _cd = obs::span(obs::Category::CodecDecode);
                        codec.decode_body(&body)
                    };
                    match decoded {
                        Ok(WireMsg::Ping { seq }) => {
                            if let Err(e) = wire::write_msg(&mut stream, &WireMsg::Pong { seq })
                            {
                                let _ = tx.send(Err(anyhow::anyhow!(
                                    "pong write failed (link dead): {e:#}"
                                )));
                                return;
                            }
                        }
                        Ok(msg) => {
                            if tx.send(Ok(msg)).is_err() {
                                return; // receiver dropped
                            }
                        }
                        Err(e) => {
                            let _ = tx.send(Err(e));
                            return;
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if last_frame.elapsed() > grace {
                    let _ = tx.send(Err(anyhow::anyhow!(
                        "peer dead (heartbeat): no frames for {:?} (> {grace:?} = 2x \
                         the {interval:?} heartbeat interval)",
                        last_frame.elapsed()
                    )));
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Err(anyhow::anyhow!("socket read failed: {e}")));
                return;
            }
        }
    }
}

// ----------------------------------------------------------------------
// Ring / star nodes over sockets
// ----------------------------------------------------------------------

/// One worker's endpoints in a unidirectional TCP ring — the socket
/// counterpart of `comm::parallel::RingNode`, with fallible collectives.
/// For `n == 1` both links are absent and every collective degenerates
/// to the local finish.
pub struct SocketRingNode {
    pub id: usize,
    pub n: usize,
    /// Hierarchy level of this ring's dense traffic. Level 0 (flat rings
    /// and intra-group rings) frames chunks as the legacy `DenseChunk` —
    /// byte-identical to wire codec v3 — while uplink rings (level >= 1)
    /// carry the level tag next to the bucket id (`DenseChunkLvl`), so a
    /// frame that strays across levels is rejected at the tag, not
    /// silently reduced into the wrong collective.
    level: u8,
    tx_right: Option<FramedSender>,
    rx_left: Option<FramedReceiver>,
}

/// Send on a ring node's right link. A free function (not a method) so
/// the ring collective can borrow the send and receive halves of one
/// node simultaneously — the single definition of the link's error
/// wording for both the collectives and the index broadcast.
fn ring_send(tx: &Option<FramedSender>, id: usize, n: usize, msg: WireMsg) -> anyhow::Result<()> {
    use anyhow::Context;
    tx.as_ref()
        .expect("n > 1 ring has a right link")
        .send(msg)
        .with_context(|| format!("ring node {id}/{n}: send to right neighbor"))
}

/// Receive from a ring node's left link (counterpart of [`ring_send`]).
fn ring_recv(rx: &mut Option<FramedReceiver>, id: usize, n: usize) -> anyhow::Result<WireMsg> {
    use anyhow::Context;
    rx.as_mut()
        .expect("n > 1 ring has a left link")
        .recv()
        .with_context(|| format!("ring node {id}/{n}: recv from left neighbor"))
}

impl SocketRingNode {
    pub fn new(
        id: usize,
        n: usize,
        tx_right: Option<FramedSender>,
        rx_left: Option<FramedReceiver>,
    ) -> SocketRingNode {
        assert!(id < n);
        assert_eq!(tx_right.is_some(), n > 1, "right link iff n > 1");
        assert_eq!(rx_left.is_some(), n > 1, "left link iff n > 1");
        SocketRingNode {
            id,
            n,
            level: 0,
            tx_right,
            rx_left,
        }
    }

    /// Re-tag this ring at a hierarchy level. Uplink rings run at level
    /// >= 1 and frame their dense chunks as `DenseChunkLvl` (wire codec
    /// v4); level 0 keeps the legacy `DenseChunk` framing byte-for-byte.
    pub fn at_level(mut self, level: u8) -> SocketRingNode {
        self.level = level;
        self
    }

    fn send_right(&self, msg: WireMsg) -> anyhow::Result<()> {
        ring_send(&self.tx_right, self.id, self.n, msg)
    }

    fn recv_left(&mut self) -> anyhow::Result<WireMsg> {
        ring_recv(&mut self.rx_left, self.id, self.n)
    }

    fn allreduce_with(
        &mut self,
        job: u32,
        bucket: u32,
        buf: &mut [f32],
        finish: impl Fn(&mut [f32]),
    ) -> anyhow::Result<()> {
        let (id, n, level) = (self.id, self.n, self.level);
        let tx = &self.tx_right;
        let rx = &mut self.rx_left;
        let mut send = |chunk: &[f32]| -> anyhow::Result<()> {
            let vals = chunk.to_vec();
            // Job 0 (every one-shot run) keeps the legacy framing
            // byte-for-byte; serve tenants (job >= 1) wrap every chunk
            // in the v5 job-stamped frame.
            let msg = if job != 0 {
                WireMsg::JobChunk { job, level, bucket, vals }
            } else if level == 0 {
                WireMsg::DenseChunk { bucket, vals }
            } else {
                WireMsg::DenseChunkLvl { level, bucket, vals }
            };
            ring_send(tx, id, n, msg)
        };
        let mut recv = || -> anyhow::Result<Vec<f32>> {
            // Several per-bucket collectives can be in flight on one
            // stream (the bucketed exchange); a tag mismatch means the
            // peer is executing a different collective — mis-framed
            // beyond recovery, fail at frame one. The level tag guards
            // the same way across hierarchy levels, and the job tag
            // across serve tenants sharing the mesh.
            match ring_recv(rx, id, n)? {
                WireMsg::JobChunk { job: got_job, level: got_lvl, bucket: got, vals }
                    if job != 0 =>
                {
                    anyhow::ensure!(
                        got_job == job,
                        "ring node {id}/{n}: job tag mismatch: executing job \
                         {job} but received a chunk for job {got_job} (peer out of sync)"
                    );
                    anyhow::ensure!(
                        got_lvl == level,
                        "ring node {id}/{n}: level tag mismatch: executing level \
                         {level} but received a chunk for level {got_lvl} (peer out of sync)"
                    );
                    anyhow::ensure!(
                        got == bucket,
                        "ring node {id}/{n}: bucket tag mismatch: executing bucket \
                         {bucket} but received a chunk for bucket {got} (peer out of sync)"
                    );
                    Ok(vals)
                }
                WireMsg::DenseChunk { bucket: got, vals } if job == 0 && level == 0 => {
                    anyhow::ensure!(
                        got == bucket,
                        "ring node {id}/{n}: bucket tag mismatch: executing bucket \
                         {bucket} but received a chunk for bucket {got} (peer out of sync)"
                    );
                    Ok(vals)
                }
                WireMsg::DenseChunkLvl { level: got_lvl, bucket: got, vals }
                    if job == 0 && level >= 1 =>
                {
                    anyhow::ensure!(
                        got_lvl == level,
                        "ring node {id}/{n}: level tag mismatch: executing level \
                         {level} but received a chunk for level {got_lvl} (peer out of sync)"
                    );
                    anyhow::ensure!(
                        got == bucket,
                        "ring node {id}/{n}: bucket tag mismatch: executing bucket \
                         {bucket} but received a chunk for bucket {got} (peer out of sync)"
                    );
                    Ok(vals)
                }
                other => anyhow::bail!(
                    "ring node {id}/{n}: expected a job-{job} level-{level} dense chunk, \
                     got {other:?} (peer out of sync)"
                ),
            }
        };
        ring_allreduce_generic(id, n, buf, &finish, &mut send, &mut recv)
    }

    /// In-place sum-all-reduce (same chunk schedule as the channel ring).
    pub fn allreduce_sum(&mut self, buf: &mut [f32]) -> anyhow::Result<()> {
        self.allreduce_with(0, 0, buf, |_| {})
    }

    /// In-place average-all-reduce (scale applied once per chunk on its
    /// owning worker — identical arithmetic to the channel ring).
    /// Monolithic collectives carry bucket tag 0.
    pub fn allreduce_avg(&mut self, buf: &mut [f32]) -> anyhow::Result<()> {
        self.allreduce_avg_bucket(0, buf)
    }

    /// Bucket-tagged average-all-reduce: every wire frame carries
    /// `bucket`, and arriving chunks are verified against it, so the
    /// per-bucket collectives of a bucketed step interleave safely on
    /// the stream. One-shot runs are job 0 (legacy framing).
    pub fn allreduce_avg_bucket(&mut self, bucket: u32, buf: &mut [f32]) -> anyhow::Result<()> {
        self.allreduce_avg_tagged(0, bucket, buf)
    }

    /// Job- and bucket-tagged average-all-reduce: serve tenants stamp
    /// their job id on every frame (v5 `JobChunk`) so concurrent jobs
    /// multiplexed onto one lane mesh can never mix streams — the same
    /// mis-framed-stream contract as the bucket tag, one level up.
    pub fn allreduce_avg_tagged(
        &mut self,
        job: u32,
        bucket: u32,
        buf: &mut [f32],
    ) -> anyhow::Result<()> {
        let inv = 1.0 / self.n as f32;
        self.allreduce_with(job, bucket, buf, |chunk| {
            chunk.iter_mut().for_each(|v| *v *= inv);
        })
    }

    /// Circulate the step leader's index set around the ring (n−1 hops).
    /// The leader passes `Some(indices)`; everyone else receives from the
    /// left and forwards right (unless the right neighbor *is* the
    /// leader). Returns the broadcast set on every node.
    pub fn broadcast_indices(
        &mut self,
        leader: usize,
        own: Option<&[u32]>,
    ) -> anyhow::Result<Vec<u32>> {
        assert!(leader < self.n, "leader {leader} out of range for n={}", self.n);
        if self.id == leader {
            let idx = own
                .expect("the broadcast leader must provide its index set")
                .to_vec();
            if self.n > 1 {
                self.send_right(WireMsg::Indices(idx.clone()))?;
            }
            Ok(idx)
        } else {
            let idx = match self.recv_left()? {
                WireMsg::Indices(v) => v,
                other => anyhow::bail!(
                    "ring node {}/{}: expected an index broadcast, got {other:?}",
                    self.id,
                    self.n
                ),
            };
            if (self.id + 1) % self.n != leader {
                self.send_right(WireMsg::Indices(idx.clone()))?;
            }
            Ok(idx)
        }
    }

    /// Ring min-reduce of every node's resume point — the membership-wide
    /// agreement of the reconnect-with-resume protocol. `own` encodes the
    /// next step this node could run from its newest snapshot (`0` = from
    /// scratch, `s + 1` = state after step `s` is restorable); after
    /// `n − 1` rounds of pass-the-minimum, every node holds the fleet-wide
    /// minimum — the earliest step any member must replay from. Sends are
    /// async (writer queues), so the rounds cannot deadlock.
    pub fn resume_min_reduce(&mut self, own: u64) -> anyhow::Result<u64> {
        let mut min = own;
        for _ in 0..self.n.saturating_sub(1) {
            self.send_right(WireMsg::Resume {
                rank: self.id as u32,
                step: min,
            })?;
            match self.recv_left()? {
                WireMsg::Resume { step, .. } => min = min.min(step),
                other => anyhow::bail!(
                    "ring node {}/{}: expected a resume frame, got {other:?}",
                    self.id,
                    self.n
                ),
            }
        }
        Ok(min)
    }
}

/// One worker's endpoints in the two-level ring-of-rings — the socket
/// counterpart of `comm::parallel::HierRingNode`, with the identical
/// three-phase dataflow (intra-group sum → leader ring with the finish
/// → chain broadcast down the group). Intra-group traffic stays on the
/// legacy level-0 `DenseChunk` framing; the uplink ring runs at level 1
/// and tags every frame (`DenseChunkLvl`, wire codec v4).
pub struct SocketHierRingNode {
    /// Global worker id in `0..n`.
    pub id: usize,
    pub n: usize,
    pub group_size: usize,
    /// Intra-group ring; its `id` is this worker's member index.
    intra: SocketRingNode,
    /// Leader ring over the uplink (member 0 only); its `id` is the
    /// group index and it runs at level 1.
    up: Option<SocketRingNode>,
}

impl SocketHierRingNode {
    fn allreduce_with(
        &mut self,
        job: u32,
        bucket: u32,
        buf: &mut [f32],
        finish: impl Fn(&mut [f32]),
    ) -> anyhow::Result<()> {
        // Phase 1: intra-group sum — every member ends with the group sum.
        self.intra.allreduce_with(job, bucket, buf, |_| {})?;
        // Phase 2: leader ring over the uplink carries the group sums;
        // `finish` lands exactly once per chunk, on its owning leader.
        if let Some(up) = &mut self.up {
            up.allreduce_with(job, bucket, buf, &finish)?;
        }
        // Phase 3: the finished result flows down the group chain
        // (leader → member 1 → … → member m−1 over the intra right
        // links). A zero-length buffer moved no chunks above and moves
        // no broadcast either. Serve tenants stamp the broadcast frames
        // with their job id exactly like the ring phases.
        if buf.is_empty() {
            return Ok(());
        }
        let bcast = |vals: Vec<f32>| -> WireMsg {
            if job != 0 {
                WireMsg::JobChunk { job, level: 0, bucket, vals }
            } else {
                WireMsg::DenseChunk { bucket, vals }
            }
        };
        if self.up.is_some() {
            self.intra.send_right(bcast(buf.to_vec()))?;
        } else {
            let (id, n, m) = (self.intra.id, self.intra.n, self.group_size);
            let incoming = match self.intra.recv_left()? {
                WireMsg::DenseChunk { bucket: got, vals } if job == 0 => {
                    anyhow::ensure!(
                        got == bucket,
                        "hier ring member {id}/{m}: bucket tag mismatch on the group \
                         broadcast: executing bucket {bucket} but received bucket {got} \
                         (peer out of sync)"
                    );
                    vals
                }
                WireMsg::JobChunk { job: got_job, level: 0, bucket: got, vals }
                    if job != 0 =>
                {
                    anyhow::ensure!(
                        got_job == job,
                        "hier ring member {id}/{m}: job tag mismatch on the group \
                         broadcast: executing job {job} but received job {got_job} \
                         (peer out of sync)"
                    );
                    anyhow::ensure!(
                        got == bucket,
                        "hier ring member {id}/{m}: bucket tag mismatch on the group \
                         broadcast: executing bucket {bucket} but received bucket {got} \
                         (peer out of sync)"
                    );
                    vals
                }
                other => anyhow::bail!(
                    "hier ring member {id}/{n}: expected the group broadcast, got {other:?}"
                ),
            };
            anyhow::ensure!(
                incoming.len() == buf.len(),
                "hier ring member {id}/{m}: group broadcast size mismatch: expected \
                 {} values, got {} (peer out of sync)",
                buf.len(),
                incoming.len()
            );
            buf.copy_from_slice(&incoming);
            if self.intra.id + 1 < self.group_size {
                self.intra.send_right(bcast(incoming))?;
            }
        }
        Ok(())
    }

    /// In-place sum-all-reduce over all `n` workers.
    pub fn allreduce_sum(&mut self, buf: &mut [f32]) -> anyhow::Result<()> {
        self.allreduce_with(0, 0, buf, |_| {})
    }

    /// In-place average-all-reduce (the leader ring applies the global
    /// 1/n scale once per chunk). Monolithic collectives carry bucket
    /// tag 0.
    pub fn allreduce_avg(&mut self, buf: &mut [f32]) -> anyhow::Result<()> {
        self.allreduce_avg_bucket(0, buf)
    }

    /// Bucket-tagged average-all-reduce (see
    /// [`SocketRingNode::allreduce_avg_bucket`] for the tagging
    /// rationale — here the tag additionally rides the uplink's level-1
    /// frames and the group broadcast). One-shot runs are job 0.
    pub fn allreduce_avg_bucket(&mut self, bucket: u32, buf: &mut [f32]) -> anyhow::Result<()> {
        self.allreduce_avg_tagged(0, bucket, buf)
    }

    /// Job- and bucket-tagged average-all-reduce across both levels (see
    /// [`SocketRingNode::allreduce_avg_tagged`]).
    pub fn allreduce_avg_tagged(
        &mut self,
        job: u32,
        bucket: u32,
        buf: &mut [f32],
    ) -> anyhow::Result<()> {
        let inv = 1.0 / self.n as f32;
        self.allreduce_with(job, bucket, buf, |chunk| {
            chunk.iter_mut().for_each(|v| *v *= inv);
        })
    }

    /// Broadcast the step leader's index set to every worker across both
    /// levels: the leader's own group circulates it on their intra ring,
    /// the group leaders carry it around the uplink ring, and the other
    /// groups flow it down from their group leader. Deterministic given
    /// `(leader, rank)`, so every node knows its role with no extra
    /// control traffic.
    pub fn broadcast_indices(
        &mut self,
        leader: usize,
        own: Option<&[u32]>,
    ) -> anyhow::Result<Vec<u32>> {
        assert!(leader < self.n, "leader {leader} out of range for n={}", self.n);
        let m = self.group_size;
        let (leader_grp, leader_member) = (leader / m, leader % m);
        let grp = self.id / m;
        let mut set: Option<Vec<u32>> = None;
        if grp == leader_grp {
            set = Some(self.intra.broadcast_indices(leader_member, own)?);
        }
        if let Some(up) = &mut self.up {
            set = Some(up.broadcast_indices(leader_grp, set.as_deref())?);
        }
        if grp != leader_grp {
            set = Some(self.intra.broadcast_indices(0, set.as_deref())?);
        }
        Ok(set.expect("every node is covered by one of the broadcast phases"))
    }

    /// Fleet-wide resume-point agreement across both levels: an
    /// intra-group min-reduce, an uplink min-reduce over the group
    /// leaders, then a second intra pass seeded with the leader's global
    /// minimum — which is <= every member's group minimum, so the
    /// group-wise min of the second pass IS the global minimum on every
    /// node. Reuses the flat `Resume` frames; no new wire message.
    pub fn resume_min_reduce(&mut self, own: u64) -> anyhow::Result<u64> {
        let group_min = self.intra.resume_min_reduce(own)?;
        let seeded = match &mut self.up {
            Some(up) => up.resume_min_reduce(group_min)?,
            None => group_min,
        };
        self.intra.resume_min_reduce(seeded)
    }
}

/// One worker's endpoint in a TCP gather star rooted at worker 0 — the
/// socket counterpart of `comm::parallel::StarNode`.
pub struct SocketStarNode {
    pub id: usize,
    pub n: usize,
    /// workers 1..n: link to the root
    to_root: Option<FramedSender>,
    /// root only: one receiver per worker 1..n, in worker order
    from_workers: Option<Vec<FramedReceiver>>,
}

impl SocketStarNode {
    pub fn new(
        id: usize,
        n: usize,
        to_root: Option<FramedSender>,
        from_workers: Option<Vec<FramedReceiver>>,
    ) -> SocketStarNode {
        assert!(id < n);
        if id == 0 {
            assert_eq!(
                from_workers.as_ref().map(|v| v.len()),
                Some(n - 1),
                "root holds one receiver per worker 1..n"
            );
            assert!(to_root.is_none());
        } else {
            assert!(to_root.is_some() && from_workers.is_none());
        }
        SocketStarNode {
            id,
            n,
            to_root,
            from_workers,
        }
    }

    /// Gather every worker's sparse gradient at the root, draining the
    /// per-worker links in worker order (the deterministic reduction
    /// order of the channel star). Returns `Some(contributions)` on the
    /// root, `None` on the other workers. Monolithic gathers carry
    /// bucket tag 0.
    pub fn gather(&mut self, contribution: SparseGrad) -> anyhow::Result<Option<Vec<SparseGrad>>> {
        self.gather_bucket(0, contribution)
    }

    /// Bucket-tagged gather (see [`SocketRingNode::allreduce_avg_bucket`]
    /// for the tagging rationale): the root verifies every arriving
    /// contribution against the bucket it is gathering. One-shot runs
    /// are job 0 (legacy `Sparse` framing).
    pub fn gather_bucket(
        &mut self,
        bucket: u32,
        contribution: SparseGrad,
    ) -> anyhow::Result<Option<Vec<SparseGrad>>> {
        self.gather_tagged(0, bucket, contribution)
    }

    /// Job- and bucket-tagged gather: serve tenants (job >= 1) frame
    /// contributions as v5 `JobSparse` and the root verifies the job id
    /// on every arrival — the mis-framed-stream contract of
    /// [`SocketRingNode::allreduce_avg_tagged`] on the star topology.
    pub fn gather_tagged(
        &mut self,
        job: u32,
        bucket: u32,
        contribution: SparseGrad,
    ) -> anyhow::Result<Option<Vec<SparseGrad>>> {
        use anyhow::Context;
        match &mut self.from_workers {
            Some(rxs) => {
                let mut all = Vec::with_capacity(self.n);
                all.push(contribution);
                for (i, rx) in rxs.iter_mut().enumerate() {
                    let msg = rx
                        .recv()
                        .with_context(|| format!("star root: gather from worker {}", i + 1))?;
                    match msg {
                        WireMsg::Sparse { bucket: got, grad } if job == 0 => {
                            anyhow::ensure!(
                                got == bucket,
                                "star root: bucket tag mismatch from worker {}: gathering \
                                 bucket {bucket} but received bucket {got} (peer out of sync)",
                                i + 1
                            );
                            all.push(grad);
                        }
                        WireMsg::JobSparse { job: got_job, bucket: got, grad } if job != 0 => {
                            anyhow::ensure!(
                                got_job == job,
                                "star root: job tag mismatch from worker {}: gathering \
                                 job {job} but received job {got_job} (peer out of sync)",
                                i + 1
                            );
                            anyhow::ensure!(
                                got == bucket,
                                "star root: bucket tag mismatch from worker {}: gathering \
                                 bucket {bucket} but received bucket {got} (peer out of sync)",
                                i + 1
                            );
                            all.push(grad);
                        }
                        other => anyhow::bail!(
                            "star root: expected a job-{job} sparse contribution from \
                             worker {}, got {other:?} (peer out of sync)",
                            i + 1
                        ),
                    }
                }
                Ok(Some(all))
            }
            None => {
                let msg = if job != 0 {
                    WireMsg::JobSparse {
                        job,
                        bucket,
                        grad: contribution,
                    }
                } else {
                    WireMsg::Sparse {
                        bucket,
                        grad: contribution,
                    }
                };
                self.to_root
                    .as_ref()
                    .expect("non-root star node has a root link")
                    .send(msg)
                    .with_context(|| format!("star worker {}: send to root", self.id))?;
                Ok(None)
            }
        }
    }
}

// ----------------------------------------------------------------------
// Loopback mesh (single process, Backend::Socket)
// ----------------------------------------------------------------------

/// One connected 127.0.0.1 stream pair: `(connect_side, accept_side)`.
fn loopback_pair() -> anyhow::Result<(TcpStream, TcpStream)> {
    use anyhow::Context;
    let listener =
        TcpListener::bind(("127.0.0.1", 0)).context("bind loopback listener (127.0.0.1:0)")?;
    let addr = listener.local_addr()?;
    let connect = TcpStream::connect(addr).context("connect loopback pair")?;
    let (accept, _) = listener.accept().context("accept loopback pair")?;
    connect.set_nodelay(true)?;
    accept.set_nodelay(true)?;
    Ok((connect, accept))
}

/// Build an in-process TCP ring: link `i` carries worker `i` →
/// `(i+1) % n`, exactly the channel mesh's wiring. Every endpoint gets
/// a [`FrameCodec`] configured by `wire_cfg`, all booking into the
/// shared `stats` handle.
pub fn local_ring(
    n: usize,
    timeout: Duration,
    wire_cfg: WireCodecConfig,
    stats: &CodecStats,
) -> anyhow::Result<Vec<SocketRingNode>> {
    assert!(n >= 1);
    if n == 1 {
        return Ok(vec![SocketRingNode::new(0, 1, None, None)]);
    }
    let mut senders: Vec<Option<FramedSender>> = Vec::with_capacity(n);
    let mut receivers: Vec<Option<FramedReceiver>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (w, r) = loopback_pair()?;
        senders.push(Some(FramedSender::new(
            w,
            timeout,
            FrameCodec::new(wire_cfg, stats.clone()),
        )?));
        receivers.push(Some(FramedReceiver::new(
            r,
            timeout,
            FrameCodec::new(wire_cfg, stats.clone()),
        )?));
    }
    Ok((0..n)
        .map(|id| {
            SocketRingNode::new(
                id,
                n,
                senders[id].take(),
                receivers[(id + n - 1) % n].take(),
            )
        })
        .collect())
}

/// Build the in-process two-level TCP mesh: one intra ring per group of
/// `group_size` consecutive workers, one level-1 uplink ring over the
/// group leaders (workers `0, group_size, 2·group_size, …`) — the
/// socket counterpart of `comm::parallel::hier_ring`, under the same
/// tiling validation.
pub fn local_hier_ring(
    n: usize,
    group_size: usize,
    timeout: Duration,
    wire_cfg: WireCodecConfig,
    stats: &CodecStats,
) -> anyhow::Result<Vec<SocketHierRingNode>> {
    crate::comm::parallel::validate_group_size(n, group_size)?;
    anyhow::ensure!(
        group_size >= 2,
        "local_hier_ring: group size {group_size} selects the flat ring — build \
         `local_ring({n})` instead"
    );
    let m = group_size;
    let ngroups = n / m;
    let mut uplink: Vec<Option<SocketRingNode>> = local_ring(ngroups, timeout, wire_cfg, stats)?
        .into_iter()
        .map(|r| Some(r.at_level(1)))
        .collect();
    let mut out = Vec::with_capacity(n);
    for grp in 0..ngroups {
        for (j, intra) in local_ring(m, timeout, wire_cfg, stats)?.into_iter().enumerate() {
            out.push(SocketHierRingNode {
                id: grp * m + j,
                n,
                group_size: m,
                intra,
                up: if j == 0 { uplink[grp].take() } else { None },
            });
        }
    }
    Ok(out)
}

/// Build an in-process TCP gather star rooted at worker 0.
pub fn local_star(
    n: usize,
    timeout: Duration,
    wire_cfg: WireCodecConfig,
    stats: &CodecStats,
) -> anyhow::Result<Vec<SocketStarNode>> {
    assert!(n >= 1);
    let mut to_root: Vec<Option<FramedSender>> = Vec::with_capacity(n.saturating_sub(1));
    let mut from_workers = Vec::with_capacity(n.saturating_sub(1));
    for _ in 1..n {
        let (w, r) = loopback_pair()?;
        to_root.push(Some(FramedSender::new(
            w,
            timeout,
            FrameCodec::new(wire_cfg, stats.clone()),
        )?));
        from_workers.push(FramedReceiver::new(
            r,
            timeout,
            FrameCodec::new(wire_cfg, stats.clone()),
        )?);
    }
    Ok((0..n)
        .map(|id| {
            if id == 0 {
                SocketStarNode::new(0, n, None, Some(std::mem::take(&mut from_workers)))
            } else {
                SocketStarNode::new(id, n, to_root[id - 1].take(), None)
            }
        })
        .collect())
}

// ----------------------------------------------------------------------
// Multi-process mesh (rendezvous over a static peer list)
// ----------------------------------------------------------------------

/// Connect to `addr`, retrying until `deadline` — peers of a ring may
/// start in any order, so early connects wait for late listeners.
///
/// Everything inside one attempt is retryable: resolution errors,
/// connect failures, *and* post-connect socket setup (`set_nodelay` can
/// fail transiently when the peer resets the fresh connection — that
/// must cost one retry, not the whole rendezvous). Each attempt's
/// connect timeout is clamped to the remaining deadline, so a late
/// overall deadline is honored instead of overshooting by a fixed
/// 500 ms.
pub fn connect_with_retry(addr: &str, deadline: Instant) -> anyhow::Result<TcpStream> {
    let mut last_err = String::from("never attempted");
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        // Floor of 10 ms so a nearly-expired deadline still makes one
        // real attempt instead of failing with a 0-timeout artifact.
        let attempt = remaining.min(Duration::from_millis(500)).max(Duration::from_millis(10));
        match addr.to_socket_addrs() {
            Ok(addrs) => {
                // Try every resolved address, like `TcpStream::connect`
                // does — a hostname may resolve to [::1, 127.0.0.1] with
                // only one of them actually listening.
                let mut any = false;
                for sa in addrs {
                    any = true;
                    match TcpStream::connect_timeout(&sa, attempt) {
                        Ok(s) => match s.set_nodelay(true) {
                            Ok(()) => return Ok(s),
                            Err(e) => last_err = format!("{sa}: set_nodelay: {e}"),
                        },
                        Err(e) => last_err = format!("{sa}: {e}"),
                    }
                }
                if !any {
                    last_err = format!("'{addr}' resolved to no address");
                }
            }
            Err(e) => last_err = format!("cannot resolve '{addr}': {e}"),
        }
        if Instant::now() >= deadline {
            anyhow::bail!("rendezvous with {addr} timed out: {last_err}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// One inbound connection whose handshake has not completed yet. The
/// stream stays **nonblocking** until its Hello frame is complete, so a
/// silent or slow connector can never stall classification of the
/// others — it just sits here until the rendezvous deadline.
struct PendingHandshake {
    stream: TcpStream,
    buf: Vec<u8>,
    /// Bytes needed for the current phase: 4 (length header), then
    /// 4 + body once the header has been parsed.
    target: usize,
}

/// A handshake frame is a Hello — a few bytes. Anything claiming a
/// large body on a fresh inbound connection is not a peer.
const MAX_HANDSHAKE_BODY: usize = 1024;

/// Advance one pending handshake as far as the socket allows without
/// blocking. `Ok(Some(msg))` = handshake frame complete; `Ok(None)` =
/// more bytes needed; `Err` = connection is dead or mis-framed (caller
/// drops it without failing the rendezvous).
fn advance_handshake(p: &mut PendingHandshake) -> anyhow::Result<Option<WireMsg>> {
    let mut tmp = [0u8; 64];
    loop {
        let want = (p.target - p.buf.len()).min(tmp.len());
        match p.stream.read(&mut tmp[..want]) {
            Ok(0) => anyhow::bail!("inbound connection closed before completing its handshake"),
            Ok(k) => {
                p.buf.extend_from_slice(&tmp[..k]);
                if p.target == 4 && p.buf.len() == 4 {
                    let len = wire::check_body_len(u32::from_le_bytes([
                        p.buf[0], p.buf[1], p.buf[2], p.buf[3],
                    ]))?;
                    anyhow::ensure!(
                        len <= MAX_HANDSHAKE_BODY,
                        "handshake frame of {len} bytes is not a Hello"
                    );
                    p.target = 4 + len;
                }
                if p.target > 4 && p.buf.len() == p.target {
                    return Ok(Some(wire::decode_body(&p.buf[4..])?));
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Ok(None)
            }
            Err(e) => return Err(anyhow::Error::from(e).context("handshake read")),
        }
    }
}

/// The rendezvous accept loop shared by the flat and hierarchical mesh:
/// drain the listener without blocking, advance every pending handshake
/// concurrently, and hand each completed Hello (with its now-blocking
/// stream) to `classify`, which slots it and returns the number of
/// inbound links filled so far. A connection that dies or mis-frames
/// mid-handshake is dropped without failing the rendezvous; a rogue
/// connector that never completes its Hello occupies one pending slot
/// until the deadline.
fn drain_rendezvous(
    rank: usize,
    n: usize,
    listener: &TcpListener,
    deadline: Instant,
    expected: usize,
    mut classify: impl FnMut(WireMsg, TcpStream) -> anyhow::Result<usize>,
) -> anyhow::Result<()> {
    use anyhow::Context;
    let mut pending: Vec<PendingHandshake> = Vec::new();
    listener
        .set_nonblocking(true)
        .context("nonblocking rendezvous accept")?;
    let mut got = 0usize;
    while got < expected {
        // Drain the accept queue without blocking.
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nodelay(true)?;
                    stream
                        .set_nonblocking(true)
                        .context("nonblocking handshake read")?;
                    pending.push(PendingHandshake { stream, buf: Vec::new(), target: 4 });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(anyhow::Error::from(e).context("rendezvous accept")),
            }
        }
        // Advance every pending handshake; none can block the others.
        let mut i = 0;
        while i < pending.len() {
            match advance_handshake(&mut pending[i]) {
                Ok(None) => i += 1,
                Ok(Some(hello)) => {
                    let p = pending.swap_remove(i);
                    p.stream.set_nonblocking(false)?;
                    got = classify(hello, p.stream)?;
                }
                Err(_) => {
                    // dead or mis-framed mid-handshake: not a peer
                    pending.swap_remove(i);
                }
            }
        }
        if got < expected {
            anyhow::ensure!(
                Instant::now() < deadline,
                "rank {rank}: rendezvous timed out with {got}/{expected} inbound \
                 connections — are all {n} nodes running with the same --peers list?"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    Ok(())
}

/// Form this rank's ring + star endpoints against a static peer list
/// (`peers[r]` is rank r's bind address; the coordinator/star root is
/// rank 0). `listener` must already be bound to `peers[rank]` — binding
/// first and connecting second is what makes the rendezvous
/// deadlock-free regardless of process start order. The listener is
/// borrowed, not consumed: fault recovery re-runs the rendezvous on the
/// same bound socket (`--reconnect`), so restarted peers can find the
/// survivors at their original addresses.
///
/// Every outbound connection introduces itself with a `Hello` frame
/// (carrying this build's wire codec version), and inbound connections
/// are classified by it, so accept order does not matter. A peer whose
/// codec version is too old for `wire_cfg` is rejected with an error
/// naming both versions. All waits are bounded by `timeout`.
///
/// Handshakes are read **incrementally and concurrently**: a connector
/// that never sends its Hello (rogue scanner, half-dead peer) occupies
/// one pending slot until the deadline instead of head-of-line blocking
/// every other inbound handshake for a full read timeout. A connection
/// that dies or mis-frames mid-handshake is dropped without failing the
/// rendezvous. A *duplicate* Hello for an already-classified slot
/// replaces the old stream (newest wins): during fault recovery a peer
/// may have connected, died, and reconnected, and the stale stream is
/// the dead one.
pub fn form_mesh(
    rank: usize,
    peers: &[String],
    listener: &TcpListener,
    timeout: Duration,
    wire_cfg: WireCodecConfig,
    stats: &CodecStats,
) -> anyhow::Result<(SocketRingNode, SocketStarNode)> {
    form_mesh_with(rank, peers, listener, timeout, wire_cfg, stats, None)
}

/// [`form_mesh`] with an optional heartbeat interval: when set, every
/// mesh endpoint carries the liveness machinery (senders ping and
/// monitor pongs, receivers answer pings and track silence), so a dead
/// peer is detected within 2× the interval instead of only at the next
/// blocking read.
pub fn form_mesh_with(
    rank: usize,
    peers: &[String],
    listener: &TcpListener,
    timeout: Duration,
    wire_cfg: WireCodecConfig,
    stats: &CodecStats,
    heartbeat: Option<Duration>,
) -> anyhow::Result<(SocketRingNode, SocketStarNode)> {
    use anyhow::Context;
    let n = peers.len();
    assert!(rank < n);
    if n == 1 {
        return Ok((
            SocketRingNode::new(0, 1, None, None),
            SocketStarNode::new(0, 1, None, Some(Vec::new())),
        ));
    }
    let deadline = Instant::now() + timeout;
    let mk_codec = || FrameCodec::new(wire_cfg, stats.clone());
    let mk_rx = |s: TcpStream| -> anyhow::Result<FramedReceiver> {
        match heartbeat {
            Some(hb) => FramedReceiver::with_heartbeat(s, timeout, mk_codec(), hb),
            None => FramedReceiver::new(s, timeout, mk_codec()),
        }
    };
    let mk_tx = |s: TcpStream| -> anyhow::Result<FramedSender> {
        match heartbeat {
            Some(hb) => FramedSender::with_heartbeat(s, timeout, mk_codec(), hb),
            None => FramedSender::new(s, timeout, mk_codec()),
        }
    };

    // Outbound: ring-right always; star uplink from every worker to rank 0.
    let right = (rank + 1) % n;
    let mut ring_tx_stream = connect_with_retry(&peers[right], deadline)
        .with_context(|| format!("rank {rank}: connect ring-right to rank {right}"))?;
    wire::write_msg(
        &mut ring_tx_stream,
        &WireMsg::Hello {
            rank: rank as u32,
            purpose: Purpose::Ring,
            codec: wire::WIRE_CODEC_VERSION,
        },
    )?;
    let mut star_tx_stream = if rank > 0 {
        let mut s = connect_with_retry(&peers[0], deadline)
            .with_context(|| format!("rank {rank}: connect star uplink to rank 0"))?;
        wire::write_msg(
            &mut s,
            &WireMsg::Hello {
                rank: rank as u32,
                purpose: Purpose::Star,
                codec: wire::WIRE_CODEC_VERSION,
            },
        )?;
        Some(s)
    } else {
        None
    };

    // Inbound: one ring stream from the left neighbor, plus (root only)
    // one star stream per worker 1..n. Streams park in `pending` until
    // their Hello is complete, then classify into a slot.
    let left = (rank + n - 1) % n;
    let mut ring_rx: Option<FramedReceiver> = None;
    let mut star_rx: Vec<Option<FramedReceiver>> = (1..n).map(|_| None).collect();
    let expected = 1 + if rank == 0 { n - 1 } else { 0 };
    drain_rendezvous(rank, n, listener, deadline, expected, |hello, stream| {
        match hello {
            WireMsg::Hello {
                rank: from,
                purpose: Purpose::Ring,
                codec: peer_codec,
            } => {
                anyhow::ensure!(
                    from as usize == left,
                    "rank {rank}: ring hello from rank {from}, expected left \
                     neighbor {left} — check that every node got the same \
                     --peers list"
                );
                check_peer_codec(rank, from as usize, peer_codec, wire_cfg, heartbeat)?;
                // newest wins: a duplicate means the peer
                // reconnected and the old stream is stale
                ring_rx = Some(mk_rx(stream)?);
            }
            WireMsg::Hello {
                rank: from,
                purpose: Purpose::Star,
                codec: peer_codec,
            } => {
                let from = from as usize;
                anyhow::ensure!(
                    rank == 0,
                    "rank {rank}: unexpected star uplink from rank {from} \
                     (only rank 0 roots the star)"
                );
                anyhow::ensure!(
                    (1..n).contains(&from),
                    "rank 0: star hello from invalid rank {from}"
                );
                check_peer_codec(rank, from, peer_codec, wire_cfg, heartbeat)?;
                star_rx[from - 1] = Some(mk_rx(stream)?);
            }
            WireMsg::Hello {
                rank: from,
                purpose: Purpose::Uplink,
                ..
            } => {
                // A hier-mesh peer dialed into a flat mesh: a config
                // split this loud is unrecoverable — fail with the fix.
                anyhow::bail!(
                    "rank {rank}: unexpected hierarchical uplink hello from rank \
                     {from} — this node runs the flat ring; check that every node \
                     got the same --group-size"
                );
            }
            // A first frame that is not a Hello is not a
            // peer (rogue connector, stale stream): drop it
            // without failing the rendezvous.
            _ => {}
        }
        Ok(ring_rx.iter().count() + star_rx.iter().filter(|r| r.is_some()).count())
    })?;

    let ring = SocketRingNode::new(
        rank,
        n,
        Some(mk_tx(ring_tx_stream)?),
        Some(ring_rx.expect("ring inbound link present")),
    );
    let star = if rank == 0 {
        let rxs: Vec<FramedReceiver> = star_rx
            .into_iter()
            .map(|r| r.expect("star inbound links present"))
            .collect();
        SocketStarNode::new(0, n, None, Some(rxs))
    } else {
        SocketStarNode::new(
            rank,
            n,
            Some(mk_tx(star_tx_stream.take().expect("worker star uplink"))?),
            None,
        )
    };
    Ok((ring, star))
}

/// [`form_mesh`] for the two-level ring-of-rings: every rank joins its
/// group's intra ring (ranks `grp·m .. grp·m+m`, member index `rank %
/// m`), group leaders (`rank % m == 0`) additionally join the level-1
/// uplink ring, and the gather star stays rooted at rank 0 exactly like
/// the flat mesh. Uplink connections introduce themselves with
/// `Purpose::Uplink`, and every hier-mesh peer must speak wire codec v4
/// (the level-tagged frames) — a config split between flat and
/// hierarchical nodes fails the rendezvous with the fix named.
pub fn form_hier_mesh_with(
    rank: usize,
    peers: &[String],
    group_size: usize,
    listener: &TcpListener,
    timeout: Duration,
    wire_cfg: WireCodecConfig,
    stats: &CodecStats,
    heartbeat: Option<Duration>,
) -> anyhow::Result<(SocketHierRingNode, SocketStarNode)> {
    use anyhow::Context;
    let n = peers.len();
    assert!(rank < n);
    crate::comm::parallel::validate_group_size(n, group_size)?;
    anyhow::ensure!(
        group_size >= 2,
        "form_hier_mesh: group size {group_size} selects the flat ring — call \
         `form_mesh` instead"
    );
    let m = group_size;
    let ngroups = n / m;
    let (grp, member) = (rank / m, rank % m);
    let deadline = Instant::now() + timeout;
    let mk_codec = || FrameCodec::new(wire_cfg, stats.clone());
    let mk_rx = |s: TcpStream| -> anyhow::Result<FramedReceiver> {
        match heartbeat {
            Some(hb) => FramedReceiver::with_heartbeat(s, timeout, mk_codec(), hb),
            None => FramedReceiver::new(s, timeout, mk_codec()),
        }
    };
    let mk_tx = |s: TcpStream| -> anyhow::Result<FramedSender> {
        match heartbeat {
            Some(hb) => FramedSender::with_heartbeat(s, timeout, mk_codec(), hb),
            None => FramedSender::new(s, timeout, mk_codec()),
        }
    };
    let say_hello = |addr: &str, purpose: Purpose, what: &str| -> anyhow::Result<TcpStream> {
        let mut s = connect_with_retry(addr, deadline)
            .with_context(|| format!("rank {rank}: connect {what}"))?;
        wire::write_msg(
            &mut s,
            &WireMsg::Hello {
                rank: rank as u32,
                purpose,
                codec: wire::WIRE_CODEC_VERSION,
            },
        )?;
        Ok(s)
    };

    // Outbound: intra ring-right always; leaders also dial the next
    // group's leader on the uplink; every rank > 0 dials the star root.
    let intra_right = grp * m + (member + 1) % m;
    let intra_tx_stream = say_hello(
        &peers[intra_right],
        Purpose::Ring,
        &format!("intra ring-right to rank {intra_right}"),
    )?;
    let mut up_tx_stream = if member == 0 {
        let up_right = ((grp + 1) % ngroups) * m;
        Some(say_hello(
            &peers[up_right],
            Purpose::Uplink,
            &format!("uplink ring-right to leader rank {up_right}"),
        )?)
    } else {
        None
    };
    let mut star_tx_stream = if rank > 0 {
        Some(say_hello(&peers[0], Purpose::Star, "star uplink to rank 0")?)
    } else {
        None
    };

    // Inbound: intra-left always, uplink-left on leaders, the full star
    // fan-in on rank 0.
    let intra_left = grp * m + (member + m - 1) % m;
    let up_left = ((grp + ngroups - 1) % ngroups) * m;
    let mut intra_rx: Option<FramedReceiver> = None;
    let mut up_rx: Option<FramedReceiver> = None;
    let mut star_rx: Vec<Option<FramedReceiver>> = (1..n).map(|_| None).collect();
    let expected = 1
        + usize::from(member == 0)
        + if rank == 0 { n - 1 } else { 0 };
    drain_rendezvous(rank, n, listener, deadline, expected, |hello, stream| {
        match hello {
            WireMsg::Hello {
                rank: from,
                purpose: Purpose::Ring,
                codec: peer_codec,
            } => {
                anyhow::ensure!(
                    from as usize == intra_left,
                    "rank {rank}: intra-ring hello from rank {from}, expected left \
                     group member {intra_left} — check that every node got the same \
                     --peers list and --group-size"
                );
                check_peer_codec(rank, from as usize, peer_codec, wire_cfg, heartbeat)?;
                check_hier_peer_codec(rank, from as usize, peer_codec)?;
                intra_rx = Some(mk_rx(stream)?);
            }
            WireMsg::Hello {
                rank: from,
                purpose: Purpose::Uplink,
                codec: peer_codec,
            } => {
                anyhow::ensure!(
                    member == 0,
                    "rank {rank}: unexpected uplink hello from rank {from} — only \
                     group leaders (rank % {m} == 0) ride the leader ring"
                );
                anyhow::ensure!(
                    from as usize == up_left,
                    "rank {rank}: uplink hello from rank {from}, expected the left \
                     leader {up_left} — check that every node got the same --peers \
                     list and --group-size"
                );
                check_peer_codec(rank, from as usize, peer_codec, wire_cfg, heartbeat)?;
                check_hier_peer_codec(rank, from as usize, peer_codec)?;
                up_rx = Some(mk_rx(stream)?);
            }
            WireMsg::Hello {
                rank: from,
                purpose: Purpose::Star,
                codec: peer_codec,
            } => {
                let from = from as usize;
                anyhow::ensure!(
                    rank == 0,
                    "rank {rank}: unexpected star uplink from rank {from} \
                     (only rank 0 roots the star)"
                );
                anyhow::ensure!(
                    (1..n).contains(&from),
                    "rank 0: star hello from invalid rank {from}"
                );
                check_peer_codec(rank, from, peer_codec, wire_cfg, heartbeat)?;
                check_hier_peer_codec(rank, from, peer_codec)?;
                star_rx[from - 1] = Some(mk_rx(stream)?);
            }
            // A first frame that is not a Hello is not a peer (rogue
            // connector, stale stream): drop it without failing the
            // rendezvous.
            _ => {}
        }
        Ok(intra_rx.iter().count()
            + up_rx.iter().count()
            + star_rx.iter().filter(|r| r.is_some()).count())
    })?;

    let intra = SocketRingNode::new(
        member,
        m,
        Some(mk_tx(intra_tx_stream)?),
        Some(intra_rx.expect("intra inbound link present")),
    );
    let up = if member == 0 {
        Some(
            SocketRingNode::new(
                grp,
                ngroups,
                Some(mk_tx(up_tx_stream.take().expect("leader uplink stream"))?),
                Some(up_rx.expect("uplink inbound link present")),
            )
            .at_level(1),
        )
    } else {
        None
    };
    let ring = SocketHierRingNode {
        id: rank,
        n,
        group_size: m,
        intra,
        up,
    };
    let star = if rank == 0 {
        let rxs: Vec<FramedReceiver> = star_rx
            .into_iter()
            .map(|r| r.expect("star inbound links present"))
            .collect();
        SocketStarNode::new(0, n, None, Some(rxs))
    } else {
        SocketStarNode::new(
            rank,
            n,
            Some(mk_tx(star_tx_stream.take().expect("worker star uplink"))?),
            None,
        )
    };
    Ok((ring, star))
}

/// The hier-mesh addendum to [`check_peer_codec`]: level-tagged dense
/// frames (`DenseChunkLvl`) entered the wire at codec v4, so every
/// member of a hierarchical mesh must speak it regardless of the
/// compression configuration.
fn check_hier_peer_codec(rank: usize, from: usize, peer_codec: u8) -> anyhow::Result<()> {
    anyhow::ensure!(
        peer_codec >= 4,
        "rank {rank}: peer rank {from} speaks wire codec v{peer_codec} but the \
         hierarchical mesh's level-tagged frames need v4 — upgrade the peer or \
         run flat with --group-size 0",
    );
    Ok(())
}

/// Reject a handshake from a peer whose wire codec is too old for this
/// node's configuration. Plain framing (`--wire-compression off`)
/// interoperates with any peer; packed/compressed frames need a peer
/// that understands them (v2+), and the heartbeat's `Ping`/`Pong`
/// control frames need v3+.
fn check_peer_codec(
    rank: usize,
    from: usize,
    peer_codec: u8,
    wire_cfg: WireCodecConfig,
    heartbeat: Option<Duration>,
) -> anyhow::Result<()> {
    let needed = wire_cfg.required_peer_codec();
    anyhow::ensure!(
        peer_codec >= needed,
        "rank {rank}: peer rank {from} speaks wire codec v{peer_codec} but this \
         node's compression config ({}) needs v{needed} — upgrade the peer or \
         run with --wire-compression off",
        wire_cfg.label(),
    );
    if heartbeat.is_some() {
        anyhow::ensure!(
            peer_codec >= 3,
            "rank {rank}: peer rank {from} speaks wire codec v{peer_codec} but the \
             heartbeat control frames need v3 — upgrade the peer or run with \
             --heartbeat-ms 0",
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::parallel;
    use crate::util::rng::Rng;

    const T: Duration = Duration::from_secs(10);

    #[test]
    fn timeout_parse_accepts_positive_seconds_and_defaults_when_unset() {
        assert_eq!(parse_timeout_secs(None).unwrap(), Duration::from_secs(30));
        assert_eq!(
            parse_timeout_secs(Some("5")).unwrap(),
            Duration::from_secs(5)
        );
        assert_eq!(
            parse_timeout_secs(Some(" 120 ")).unwrap(),
            Duration::from_secs(120)
        );
    }

    #[test]
    fn timeout_parse_rejects_zero_and_garbage_loudly() {
        for bad in ["0", "-3", "2.5", "ten", ""] {
            let err = parse_timeout_secs(Some(bad)).unwrap_err();
            assert!(
                err.to_string().contains("SCALECOM_SOCKET_TIMEOUT_SECS"),
                "'{bad}' -> {err}"
            );
        }
    }

    /// Run `f(node, w)` on one thread per socket ring node.
    fn on_ring<TOut: Send>(
        n: usize,
        f: impl Fn(&mut SocketRingNode, usize) -> TOut + Sync,
    ) -> Vec<TOut> {
        on_ring_with(n, WireCodecConfig::off(), &CodecStats::new(), f)
    }

    /// [`on_ring`] with an explicit codec configuration and stats sink.
    fn on_ring_with<TOut: Send>(
        n: usize,
        cfg: WireCodecConfig,
        stats: &CodecStats,
        f: impl Fn(&mut SocketRingNode, usize) -> TOut + Sync,
    ) -> Vec<TOut> {
        let nodes = local_ring(n, T, cfg, stats).expect("loopback ring");
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    let f = &f;
                    s.spawn(move || {
                        let id = node.id;
                        f(&mut node, id)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker")).collect()
        })
    }

    #[test]
    fn socket_ring_is_bit_identical_to_channel_ring() {
        for n in [1usize, 2, 3, 5, 8] {
            for len in [0usize, 1, n, 3 * n + 1, 100] {
                let mut rng = Rng::new((n * 7919 + len) as u64);
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut v = vec![0.0f32; len];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    })
                    .collect();
                // channel reference
                let chan_nodes = parallel::ring(n);
                let inputs_ref = &inputs;
                let expect: Vec<Vec<f32>> = std::thread::scope(|s| {
                    let handles: Vec<_> = chan_nodes
                        .into_iter()
                        .map(|node| {
                            s.spawn(move || {
                                let mut buf = inputs_ref[node.id].clone();
                                node.allreduce_avg(&mut buf);
                                buf
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                let got = on_ring(n, |node, w| {
                    let mut buf = inputs_ref[w].clone();
                    node.allreduce_avg(&mut buf).expect("socket allreduce");
                    buf
                });
                // identical schedule + bit-exact wire → bit-identical
                assert_eq!(got, expect, "n={n} len={len}");
            }
        }
    }

    #[test]
    fn socket_star_gathers_in_worker_order() {
        let n = 5;
        let nodes =
            local_star(n, T, WireCodecConfig::off(), &CodecStats::new()).expect("loopback star");
        let gathered = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    s.spawn(move || {
                        let sg = SparseGrad::new(8, vec![node.id as u32], vec![node.id as f32]);
                        node.gather(sg).expect("gather")
                    })
                })
                .collect();
            handles
                .into_iter()
                .filter_map(|h| h.join().expect("worker"))
                .next()
                .expect("root result")
        });
        assert_eq!(gathered.len(), n);
        for (w, sg) in gathered.iter().enumerate() {
            assert_eq!(sg.indices, vec![w as u32], "worker order");
        }
    }

    #[test]
    fn broadcast_indices_reaches_every_node() {
        let n = 6;
        for leader in [0usize, 2, n - 1] {
            let idx: Vec<u32> = vec![4, 8, 15, 16, 23, 42];
            let idx_ref = &idx;
            let got = on_ring(n, |node, w| {
                let own = (w == leader).then_some(idx_ref.as_slice());
                node.broadcast_indices(leader, own).expect("broadcast")
            });
            for (w, g) in got.iter().enumerate() {
                assert_eq!(g, idx_ref, "leader={leader} worker={w}");
            }
        }
    }

    #[test]
    fn back_to_back_bucket_collectives_stay_ordered_and_exact() {
        // Two per-bucket collectives launched back-to-back on the same
        // ring (the bucketed exchange's wire pattern): both must reduce
        // exactly, in order, with their tags intact.
        let n = 4;
        let got = on_ring(n, |node, w| {
            let mut b5 = vec![(w + 1) as f32; 8];
            let mut b6 = vec![(w + 1) as f32 * 10.0; 8];
            node.allreduce_avg_bucket(5, &mut b5).expect("bucket 5");
            node.allreduce_avg_bucket(6, &mut b6).expect("bucket 6");
            (b5, b6)
        });
        for (b5, b6) in &got {
            assert!(b5.iter().all(|&v| (v - 2.5).abs() < 1e-6), "{b5:?}");
            assert!(b6.iter().all(|&v| (v - 25.0).abs() < 1e-6), "{b6:?}");
        }
    }

    #[test]
    fn bucket_tag_mismatch_is_detected_not_mixed() {
        // Node 0 reduces bucket 1 while node 1 reduces bucket 2: the
        // first cross frame must fail the collective with a tag error
        // instead of silently reducing one bucket into the other.
        let mut nodes =
            local_ring(2, Duration::from_secs(5), WireCodecConfig::off(), &CodecStats::new())
                .expect("loopback ring");
        let n1 = nodes.remove(1);
        let n0 = nodes.remove(0);
        let errs = std::thread::scope(|s| {
            let h0 = s.spawn(move || {
                let mut n0 = n0;
                n0.allreduce_avg_bucket(1, &mut vec![1.0f32; 8]).unwrap_err()
            });
            let h1 = s.spawn(move || {
                let mut n1 = n1;
                n1.allreduce_avg_bucket(2, &mut vec![1.0f32; 8]).unwrap_err()
            });
            [h0.join().expect("node 0"), h1.join().expect("node 1")]
        });
        for e in &errs {
            let msg = format!("{e:#}");
            assert!(msg.contains("bucket tag mismatch"), "{msg}");
        }
    }

    #[test]
    fn star_bucket_tag_mismatch_is_detected() {
        let nodes =
            local_star(2, Duration::from_secs(5), WireCodecConfig::off(), &CodecStats::new())
                .expect("loopback star");
        let mut it = nodes.into_iter();
        let root = it.next().expect("root");
        let worker = it.next().expect("worker");
        let err = std::thread::scope(|s| {
            s.spawn(move || {
                let mut w = worker;
                // worker contributes under bucket 9...
                w.gather_bucket(9, SparseGrad::new(4, vec![1], vec![1.0]))
                    .expect("worker send");
            });
            let mut r = root;
            // ...while the root gathers bucket 3
            s.spawn(move || {
                r.gather_bucket(3, SparseGrad::new(4, vec![0], vec![1.0]))
                    .unwrap_err()
            })
            .join()
            .expect("root")
        });
        assert!(format!("{err:#}").contains("bucket tag mismatch"), "{err:#}");
    }

    #[test]
    fn job_tag_mismatch_is_detected_not_mixed() {
        // Node 0 reduces job 4 while node 1 reduces job 8 (same bucket):
        // the first cross frame must fail the collective with a job tag
        // error instead of silently reducing one tenant into the other —
        // the bucket-tag contract, one level up.
        let mut nodes =
            local_ring(2, Duration::from_secs(5), WireCodecConfig::off(), &CodecStats::new())
                .expect("loopback ring");
        let n1 = nodes.remove(1);
        let n0 = nodes.remove(0);
        let errs = std::thread::scope(|s| {
            let h0 = s.spawn(move || {
                let mut n0 = n0;
                n0.allreduce_avg_tagged(4, 1, &mut vec![1.0f32; 8]).unwrap_err()
            });
            let h1 = s.spawn(move || {
                let mut n1 = n1;
                n1.allreduce_avg_tagged(8, 1, &mut vec![1.0f32; 8]).unwrap_err()
            });
            [h0.join().expect("node 0"), h1.join().expect("node 1")]
        });
        for e in &errs {
            let msg = format!("{e:#}");
            assert!(msg.contains("job tag mismatch"), "{msg}");
        }
    }

    #[test]
    fn job_frames_never_mix_with_legacy_frames() {
        // A job-0 (legacy-framed) node paired with a job-tagged node:
        // both must fail with a mis-framed-stream error, never decode
        // each other's chunks as their own.
        let mut nodes =
            local_ring(2, Duration::from_secs(5), WireCodecConfig::off(), &CodecStats::new())
                .expect("loopback ring");
        let n1 = nodes.remove(1);
        let n0 = nodes.remove(0);
        let errs = std::thread::scope(|s| {
            let h0 = s.spawn(move || {
                let mut n0 = n0;
                n0.allreduce_avg_bucket(1, &mut vec![1.0f32; 8]).unwrap_err()
            });
            let h1 = s.spawn(move || {
                let mut n1 = n1;
                n1.allreduce_avg_tagged(6, 1, &mut vec![1.0f32; 8]).unwrap_err()
            });
            [h0.join().expect("node 0"), h1.join().expect("node 1")]
        });
        for e in &errs {
            let msg = format!("{e:#}");
            assert!(msg.contains("peer out of sync"), "{msg}");
        }
    }

    #[test]
    fn star_job_tag_mismatch_is_detected() {
        let nodes =
            local_star(2, Duration::from_secs(5), WireCodecConfig::off(), &CodecStats::new())
                .expect("loopback star");
        let mut it = nodes.into_iter();
        let root = it.next().expect("root");
        let worker = it.next().expect("worker");
        let err = std::thread::scope(|s| {
            s.spawn(move || {
                let mut w = worker;
                // worker contributes under job 2...
                w.gather_tagged(2, 3, SparseGrad::new(4, vec![1], vec![1.0]))
                    .expect("worker send");
            });
            let mut r = root;
            // ...while the root gathers job 5
            s.spawn(move || {
                r.gather_tagged(5, 3, SparseGrad::new(4, vec![0], vec![1.0]))
                    .unwrap_err()
            })
            .join()
            .expect("root")
        });
        assert!(format!("{err:#}").contains("job tag mismatch"), "{err:#}");
    }

    #[test]
    fn tagged_collectives_match_legacy_bit_for_bit() {
        // The job tag changes framing only, never arithmetic: the same
        // inputs reduced under job 0 and under a tenant job id must be
        // bit-identical.
        let n = 3;
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|w| (0..17).map(|i| ((w * 17 + i) as f32 * 0.3).sin()).collect())
            .collect();
        let inputs_ref = &inputs;
        let legacy = on_ring(n, |node, w| {
            let mut buf = inputs_ref[w].clone();
            node.allreduce_avg_bucket(2, &mut buf).expect("legacy");
            buf
        });
        let tagged = on_ring(n, |node, w| {
            let mut buf = inputs_ref[w].clone();
            node.allreduce_avg_tagged(11, 2, &mut buf).expect("tagged");
            buf
        });
        assert_eq!(legacy, tagged);
    }

    #[test]
    fn dead_peer_errors_instead_of_hanging() {
        // Node 1 drops its endpoints without participating: node 0's recv
        // must fail (EOF from the dropped writer) within the timeout.
        let mut nodes =
            local_ring(2, Duration::from_secs(2), WireCodecConfig::off(), &CodecStats::new())
                .expect("loopback ring");
        let n1 = nodes.remove(1);
        let mut n0 = nodes.remove(0);
        drop(n1);
        let start = Instant::now();
        let err = n0.allreduce_avg(&mut vec![1.0f32; 8]).unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(5), "bounded failure");
        let msg = format!("{err:#}");
        assert!(msg.contains("recv from left neighbor"), "{msg}");
    }

    #[test]
    fn multiprocess_mesh_forms_on_threads() {
        // The rendezvous path (static peer list + Hello classification),
        // exercised in one process with one thread per rank.
        let n = 4;
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap())
            .collect();
        let peers: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let peers_ref = &peers;
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = listeners
                .into_iter()
                .enumerate()
                .map(|(rank, listener)| {
                    s.spawn(move || {
                        let (mut ring, mut star) = form_mesh(
                            rank,
                            peers_ref,
                            &listener,
                            T,
                            WireCodecConfig::off(),
                            &CodecStats::new(),
                        )
                        .expect("mesh");
                        let mut buf = vec![(rank + 1) as f32; 12];
                        ring.allreduce_avg(&mut buf).expect("ring over mesh");
                        let sg =
                            SparseGrad::new(4, vec![rank as u32], vec![1.0]);
                        let gathered = star.gather(sg).expect("star over mesh");
                        if rank == 0 {
                            let all = gathered.expect("root sees all");
                            assert_eq!(all.len(), n);
                            for (w, s) in all.iter().enumerate() {
                                assert_eq!(s.indices, vec![w as u32]);
                            }
                        }
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        // avg of 1,2,3,4 = 2.5 on every rank
        for r in &results {
            assert!(r.iter().all(|&v| (v - 2.5).abs() < 1e-6), "{r:?}");
        }
    }

    /// Run `f(node, w)` on one thread per hier socket ring node.
    fn on_hier_ring<TOut: Send>(
        n: usize,
        g: usize,
        f: impl Fn(&mut SocketHierRingNode, usize) -> TOut + Sync,
    ) -> Vec<TOut> {
        let nodes =
            local_hier_ring(n, g, T, WireCodecConfig::off(), &CodecStats::new())
                .expect("loopback hier ring");
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    let f = &f;
                    s.spawn(move || {
                        let id = node.id;
                        f(&mut node, id)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker")).collect()
        })
    }

    #[test]
    fn socket_hier_ring_is_bit_identical_to_channel_hier_ring() {
        for (n, g) in [(4usize, 2usize), (8, 2), (8, 4)] {
            for len in [0usize, 1, 3, g - 1, n, 4 * n + 3] {
                let mut rng = Rng::new((n * 31 + g * 7 + len) as u64);
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut v = vec![0.0f32; len];
                        rng.fill_normal(&mut v, 1.0);
                        v
                    })
                    .collect();
                let inputs_ref = &inputs;
                // channel reference: the same three-phase schedule
                let chan_nodes = parallel::hier_ring(n, g).expect("channel hier");
                let expect: Vec<Vec<f32>> = std::thread::scope(|s| {
                    let handles: Vec<_> = chan_nodes
                        .into_iter()
                        .map(|node| {
                            s.spawn(move || {
                                let mut buf = inputs_ref[node.id].clone();
                                node.allreduce_avg(&mut buf);
                                buf
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                let got = on_hier_ring(n, g, |node, w| {
                    let mut buf = inputs_ref[w].clone();
                    node.allreduce_avg(&mut buf).expect("socket hier allreduce");
                    buf
                });
                // identical schedule + bit-exact wire → bit-identical
                assert_eq!(got, expect, "n={n} g={g} len={len}");
            }
        }
    }

    #[test]
    fn local_hier_ring_rejects_bad_tilings() {
        let (cfg, stats) = (WireCodecConfig::off(), CodecStats::new());
        for (n, g) in [(12usize, 8usize), (4, 4), (8, 1)] {
            let err = local_hier_ring(n, g, T, cfg, &stats).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("group size") || msg.contains("flat ring"),
                "(n={n}, g={g}) -> {msg}"
            );
        }
    }

    #[test]
    fn hier_broadcast_indices_reaches_every_node_across_levels() {
        let (n, g) = (8usize, 2usize);
        // leaders in the first group, mid-mesh, and the last member of
        // the last group — every phase combination gets exercised
        for leader in [0usize, 3, n - 1] {
            let idx: Vec<u32> = vec![4, 8, 15, 16, 23, 42];
            let idx_ref = &idx;
            let got = on_hier_ring(n, g, |node, w| {
                let own = (w == leader).then_some(idx_ref.as_slice());
                node.broadcast_indices(leader, own).expect("hier broadcast")
            });
            for (w, got_idx) in got.iter().enumerate() {
                assert_eq!(got_idx, idx_ref, "leader={leader} worker={w}");
            }
        }
    }

    #[test]
    fn hier_resume_min_reduce_agrees_on_the_fleet_minimum() {
        let (n, g) = (8usize, 4usize);
        // the fleet minimum lives on a non-leader member of group 1
        let own: Vec<u64> = (0..n as u64).map(|r| 100 + r * 10).collect();
        let mut own_vals = own.clone();
        own_vals[6] = 3;
        let own_ref = &own_vals;
        let got = on_hier_ring(n, g, |node, w| {
            node.resume_min_reduce(own_ref[w]).expect("hier resume reduce")
        });
        assert!(got.iter().all(|&m| m == 3), "{got:?}");
    }

    #[test]
    fn multiprocess_hier_mesh_forms_on_threads() {
        // The hierarchical rendezvous path (intra + uplink + star hello
        // classification), exercised in one process with one thread per
        // rank: 2 groups × 2 workers.
        let (n, g) = (4usize, 2usize);
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap())
            .collect();
        let peers: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let peers_ref = &peers;
        let results: Vec<(Vec<f32>, Vec<u32>, u64)> = std::thread::scope(|s| {
            let handles: Vec<_> = listeners
                .into_iter()
                .enumerate()
                .map(|(rank, listener)| {
                    s.spawn(move || {
                        let (mut ring, mut star) = form_hier_mesh_with(
                            rank,
                            peers_ref,
                            g,
                            &listener,
                            T,
                            WireCodecConfig::off(),
                            &CodecStats::new(),
                            None,
                        )
                        .expect("hier mesh");
                        let mut buf = vec![(rank + 1) as f32; 12];
                        ring.allreduce_avg(&mut buf).expect("hier ring over mesh");
                        let idx = ring
                            .broadcast_indices(2, (rank == 2).then_some(&[7u32, 9][..]))
                            .expect("hier broadcast over mesh");
                        let resume = ring
                            .resume_min_reduce(100 + rank as u64)
                            .expect("hier resume over mesh");
                        let sg = SparseGrad::new(4, vec![rank as u32], vec![1.0]);
                        let gathered = star.gather(sg).expect("star over mesh");
                        if rank == 0 {
                            let all = gathered.expect("root sees all");
                            assert_eq!(all.len(), n);
                        }
                        (buf, idx, resume)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        for (buf, idx, resume) in &results {
            // avg of 1,2,3,4 = 2.5 on every rank
            assert!(buf.iter().all(|&v| (v - 2.5).abs() < 1e-6), "{buf:?}");
            assert_eq!(idx, &vec![7u32, 9]);
            assert_eq!(*resume, 100);
        }
    }

    #[test]
    fn flat_mesh_rejects_a_hierarchical_peer_loudly() {
        // A hier-mesh node (Purpose::Uplink hello) dials a flat-ring
        // rank 0: the rendezvous must fail naming --group-size instead
        // of hanging or silently dropping the peer.
        let l0 = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let l1 = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let peers = vec![
            l0.local_addr().unwrap().to_string(),
            l1.local_addr().unwrap().to_string(),
        ];
        let addr0 = peers[0].clone();
        let fake = std::thread::spawn(move || {
            // absorb rank 0's ring-right connect so its handshake lands
            let (held, _) = l1.accept().expect("accept rank 0");
            let mut s = TcpStream::connect(addr0.as_str()).expect("dial rank 0");
            wire::write_msg(
                &mut s,
                &WireMsg::Hello {
                    rank: 1,
                    purpose: Purpose::Uplink,
                    codec: wire::WIRE_CODEC_VERSION,
                },
            )
            .expect("uplink hello");
            std::thread::sleep(Duration::from_millis(500));
            drop(held);
            drop(s);
        });
        let err = form_mesh(
            0,
            &peers,
            &l0,
            Duration::from_secs(5),
            WireCodecConfig::off(),
            &CodecStats::new(),
        )
        .expect_err("hier peer must be rejected by the flat mesh");
        fake.join().expect("fake peer");
        let msg = format!("{err:#}");
        assert!(msg.contains("--group-size"), "{msg}");
    }

    #[test]
    fn bounded_send_queue_trips_on_a_stalled_receiver() {
        // A peer that never reads: the writer thread blocks once the OS
        // socket buffers fill, the bounded queue fills up behind it, and
        // the next send must fail with a clean queue-full fault instead
        // of growing memory without limit.
        let (w, r) = loopback_pair().expect("loopback pair");
        let sender = FramedSender::with_queue(
            w,
            Duration::from_secs(1), // write timeout bounds the Drop join
            FrameCodec::new(WireCodecConfig::off(), CodecStats::new()),
            2,                           // tiny queue so the bound trips fast
            Duration::from_millis(300), // queue-full wait, well under the write timeout
        )
        .expect("sender");
        let start = Instant::now();
        let big = WireMsg::DenseChunk {
            bucket: 0,
            vals: vec![1.0f32; 2 << 20], // 8 MiB per frame beats any OS buffer
        };
        let mut fault = None;
        for _ in 0..16 {
            if let Err(e) = sender.send(big.clone()) {
                fault = Some(format!("{e:#}"));
                break;
            }
        }
        let fault = fault.expect("a stalled receiver must trip the queue bound");
        assert!(fault.contains("send queue full"), "{fault}");
        assert!(start.elapsed() < Duration::from_secs(10), "bounded failure");
        drop(sender);
        drop(r);
    }

    #[test]
    fn compressed_ring_is_bit_identical_to_plain_framing() {
        use crate::comm::codec::WireCompression;
        let n = 4;
        for len in [0usize, 1, 17, 1000, 5000] {
            let mut rng = Rng::new(len as u64 + 42);
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0.0f32; len];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            let inputs_ref = &inputs;
            let expect = on_ring(n, |node, w| {
                let mut buf = inputs_ref[w].clone();
                node.allreduce_avg(&mut buf).expect("plain allreduce");
                buf
            });
            let stats = CodecStats::new();
            let got = on_ring_with(
                n,
                WireCodecConfig::with_mode(WireCompression::Full),
                &stats,
                |node, w| {
                    let mut buf = inputs_ref[w].clone();
                    node.allreduce_avg(&mut buf).expect("compressed allreduce");
                    buf
                },
            );
            // same schedule, codec touches only the byte envelope →
            // bit-identical reductions
            assert_eq!(got, expect, "len={len}");
            if len >= 1000 {
                assert!(!stats.snapshot().is_empty(), "codec saw the frames");
            }
        }
    }

    #[test]
    fn compressed_star_gather_is_exact_and_packs_sparse_frames() {
        use crate::comm::codec::WireCompression;
        let n = 4;
        let stats = CodecStats::new();
        let cfg = WireCodecConfig::with_mode(WireCompression::Delta);
        let nodes = local_star(n, T, cfg, &stats).expect("loopback star");
        let gathered = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    s.spawn(move || {
                        let id = node.id as u32;
                        // strictly increasing indices: the packable case
                        let indices: Vec<u32> = (0..200u32).map(|i| i * 7 + id).collect();
                        let values: Vec<f32> = (0..200).map(|i| i as f32 + 0.5).collect();
                        let sg = SparseGrad::new(2048, indices, values);
                        node.gather(sg).expect("gather")
                    })
                })
                .collect();
            handles
                .into_iter()
                .filter_map(|h| h.join().expect("worker"))
                .next()
                .expect("root result")
        });
        assert_eq!(gathered.len(), n);
        for (w, sg) in gathered.iter().enumerate() {
            let expect_idx: Vec<u32> = (0..200u32).map(|i| i * 7 + w as u32).collect();
            let expect_vals: Vec<f32> = (0..200).map(|i| i as f32 + 0.5).collect();
            assert_eq!(sg.indices, expect_idx, "worker {w} indices bit-exact");
            assert_eq!(sg.values, expect_vals, "worker {w} values bit-exact");
        }
        let snap = stats.snapshot();
        assert!(snap.packed_frames > 0, "sparse uplinks should pack: {snap:?}");
    }

    #[test]
    fn legacy_peer_without_codec_version_is_rejected() {
        use crate::comm::codec::WireCompression;
        // A v1 peer sends the old 6-byte Hello body (no codec version).
        // With packing enabled, rank 0 must reject the handshake with an
        // error naming both versions and the off switch.
        let l0 = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let l1 = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let peers = vec![
            l0.local_addr().unwrap().to_string(),
            l1.local_addr().unwrap().to_string(),
        ];
        let addr0 = peers[0].clone();
        let fake = std::thread::spawn(move || {
            // absorb rank 0's ring-right connect so its handshake lands
            let (held, _) = l1.accept().expect("accept rank 0");
            // dial rank 0 and speak the legacy v1 handshake
            let mut s = TcpStream::connect(addr0.as_str()).expect("dial rank 0");
            let mut frame = Vec::new();
            frame.extend_from_slice(&6u32.to_le_bytes()); // body length
            frame.push(3u8); // TAG_HELLO
            frame.extend_from_slice(&1u32.to_le_bytes()); // rank 1
            frame.push(0u8); // purpose: ring — and no codec byte
            s.write_all(&frame).expect("legacy hello");
            s.flush().expect("flush");
            // keep both streams open until rank 0 classifies the hello
            std::thread::sleep(Duration::from_millis(500));
            drop(held);
            drop(s);
        });
        let cfg = WireCodecConfig::with_mode(WireCompression::Delta);
        let err = form_mesh(0, &peers, &l0, Duration::from_secs(5), cfg, &CodecStats::new())
            .expect_err("legacy peer must be rejected");
        fake.join().expect("fake peer");
        let msg = format!("{err:#}");
        assert!(msg.contains("wire codec v1"), "{msg}");
        assert!(msg.contains("--wire-compression off"), "{msg}");
    }

    #[test]
    fn rogue_silent_connector_does_not_starve_honest_peers() {
        // A connection that never sends its Hello lands on rank 0's
        // listener *before* the honest peer. The rendezvous must still
        // form promptly: the rogue parks as a pending handshake instead
        // of head-of-line blocking the accept loop for a read timeout.
        let l0 = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let l1 = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let peers = vec![
            l0.local_addr().unwrap().to_string(),
            l1.local_addr().unwrap().to_string(),
        ];
        let rogue = TcpStream::connect(peers[0].as_str()).expect("rogue dials rank 0");
        // give the rogue's connection time to land in the accept queue first
        std::thread::sleep(Duration::from_millis(100));
        let timeout = Duration::from_secs(10);
        let start = Instant::now();
        let peers_ref = &peers;
        let bufs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = [&l0, &l1]
                .into_iter()
                .enumerate()
                .map(|(rank, listener)| {
                    s.spawn(move || {
                        let (mut ring, _star) = form_mesh(
                            rank,
                            peers_ref,
                            listener,
                            timeout,
                            WireCodecConfig::off(),
                            &CodecStats::new(),
                        )
                        .expect("mesh despite the rogue");
                        let mut buf = vec![(rank + 1) as f32; 8];
                        ring.allreduce_avg(&mut buf).expect("allreduce");
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        // well under the timeout: the rogue cost no blocking read
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "rendezvous stalled behind the silent connector: {:?}",
            start.elapsed()
        );
        for b in &bufs {
            assert!(b.iter().all(|&v| (v - 1.5).abs() < 1e-6), "{b:?}");
        }
        drop(rogue);
    }

    #[test]
    fn mesh_reforms_on_the_same_listeners() {
        // The reconnect path re-runs the rendezvous on the same bound
        // listeners after dropping the old mesh — twice through
        // form_mesh must work on one set of sockets.
        let n = 3;
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap())
            .collect();
        let peers: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let peers_ref = &peers;
        std::thread::scope(|s| {
            let handles: Vec<_> = listeners
                .iter()
                .enumerate()
                .map(|(rank, listener)| {
                    s.spawn(move || {
                        for round in 0..2 {
                            let (mut ring, _star) = form_mesh(
                                rank,
                                peers_ref,
                                listener,
                                T,
                                WireCodecConfig::off(),
                                &CodecStats::new(),
                            )
                            .unwrap_or_else(|e| panic!("round {round}: {e:#}"));
                            let mut buf = vec![(rank + 1) as f32; 8];
                            ring.allreduce_avg(&mut buf).expect("allreduce");
                            assert!(buf.iter().all(|&v| (v - 2.0).abs() < 1e-6));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("rank");
            }
        });
    }

    #[test]
    fn bounded_send_queue_blocks_then_succeeds_when_receiver_drains() {
        // Backpressure without fault: the receiver drains slowly, so
        // sends park on the condvar and complete once room opens —
        // no queue-full fault, no busy-spin.
        let (w, r) = loopback_pair().expect("loopback pair");
        let sender = FramedSender::with_queue(
            w,
            Duration::from_secs(10),
            FrameCodec::new(WireCodecConfig::off(), CodecStats::new()),
            2,
            Duration::from_secs(8), // queue wait far above the drain stall
        )
        .expect("sender");
        let frames = 8usize;
        let drain = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300)); // let the queue fill
            let mut rx = FramedReceiver::new(
                r,
                Duration::from_secs(10),
                FrameCodec::new(WireCodecConfig::off(), CodecStats::new()),
            )
            .expect("receiver");
            for _ in 0..frames {
                rx.recv().expect("drain");
            }
        });
        let big = WireMsg::DenseChunk { bucket: 0, vals: vec![0.5f32; 1 << 20] }; // 4 MiB
        for i in 0..frames {
            sender.send(big.clone()).unwrap_or_else(|e| panic!("frame {i}: {e:#}"));
        }
        assert!(sender.fault().is_none(), "{:?}", sender.fault());
        drop(sender);
        drain.join().expect("drain thread");
    }

    #[test]
    fn heartbeat_sender_detects_a_dead_peer_within_the_bound() {
        // The peer holds the connection open but never answers pings
        // (wedged process): the liveness monitor must latch a heartbeat
        // fault within ~2x the interval, even though nothing is being
        // sent or received on the data path.
        let (w, r) = loopback_pair().expect("loopback pair");
        let interval = Duration::from_millis(300);
        let sender = FramedSender::with_heartbeat(
            w,
            Duration::from_secs(5),
            FrameCodec::new(WireCodecConfig::off(), CodecStats::new()),
            interval,
        )
        .expect("sender");
        let start = Instant::now();
        let fault = loop {
            if let Some(f) = sender.fault() {
                break f;
            }
            assert!(
                start.elapsed() < 4 * interval,
                "heartbeat fault not latched within the detection bound"
            );
            std::thread::sleep(Duration::from_millis(10));
        };
        let elapsed = start.elapsed();
        assert!(fault.contains("heartbeat"), "{fault}");
        // grace window is 2x the interval; allow a scheduling tick of slack
        assert!(
            elapsed <= 2 * interval + Duration::from_millis(450),
            "detected after {elapsed:?}, bound is ~2x {interval:?}"
        );
        assert!(elapsed >= interval, "must not fault instantly: {elapsed:?}");
        // the latched fault also fails sends fast
        let err = sender.send(WireMsg::Indices(vec![1])).unwrap_err();
        assert!(format!("{err:#}").contains("heartbeat"), "{err:#}");
        drop(sender);
        drop(r);
    }

    #[test]
    fn heartbeat_receiver_detects_a_silent_peer_within_the_bound() {
        // The peer never sends anything — not even pings. The threaded
        // receiver must declare it dead within ~2x the interval instead
        // of waiting out the full read timeout.
        let (w, r) = loopback_pair().expect("loopback pair");
        let interval = Duration::from_millis(300);
        let mut rx = FramedReceiver::with_heartbeat(
            r,
            Duration::from_secs(30), // read timeout far above the bound
            FrameCodec::new(WireCodecConfig::off(), CodecStats::new()),
            interval,
        )
        .expect("receiver");
        let start = Instant::now();
        let err = rx.recv().expect_err("silent peer must fault");
        let elapsed = start.elapsed();
        let msg = format!("{err:#}");
        assert!(msg.contains("heartbeat"), "{msg}");
        assert!(
            elapsed <= 2 * interval + Duration::from_millis(450),
            "detected after {elapsed:?}, bound is ~2x {interval:?}"
        );
        drop(w);
    }

    #[test]
    fn heartbeat_link_stays_healthy_and_filters_pings() {
        // Full ping/pong plumbing: sender pings, receiver answers on the
        // reverse direction, data frames pass through untouched, and
        // neither side faults across several idle intervals. Each pong
        // must also land an RTT sample in the process-global accumulator
        // (the /metrics gauge and `CommStats.rtt` read it).
        let rtt_before = rtt_snapshot().count;
        let (w, r) = loopback_pair().expect("loopback pair");
        let interval = Duration::from_millis(100);
        let sender = FramedSender::with_heartbeat(
            w,
            Duration::from_secs(5),
            FrameCodec::new(WireCodecConfig::off(), CodecStats::new()),
            interval,
        )
        .expect("sender");
        let mut rx = FramedReceiver::with_heartbeat(
            r,
            Duration::from_secs(5),
            FrameCodec::new(WireCodecConfig::off(), CodecStats::new()),
            interval,
        )
        .expect("receiver");
        for i in 0..4u32 {
            sender.send(WireMsg::Indices(vec![i])).expect("send");
            match rx.recv().expect("recv") {
                WireMsg::Indices(v) => assert_eq!(v, vec![i]),
                other => panic!("ping leaked into the data stream: {other:?}"),
            }
            // idle gap well past the interval: pings must keep both
            // liveness monitors satisfied
            std::thread::sleep(Duration::from_millis(250));
        }
        assert!(sender.fault().is_none(), "{:?}", sender.fault());
        // ~10 pings answered over the four idle gaps; the accumulator is
        // shared process-wide, so assert growth, not an absolute count.
        let snap = rtt_snapshot();
        assert!(
            snap.count > rtt_before,
            "no RTT sample recorded: {} before, {} after",
            rtt_before,
            snap.count
        );
        assert!(snap.min_ns > 0, "a loopback round-trip cannot take 0 ns");
        assert!(
            snap.min_ns <= snap.mean_ns && snap.mean_ns <= snap.max_ns,
            "min {} / mean {} / max {} out of order",
            snap.min_ns,
            snap.mean_ns,
            snap.max_ns
        );
    }

    #[test]
    fn mesh_with_heartbeat_forms_and_runs_collectives() {
        let n = 3;
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap())
            .collect();
        let peers: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let peers_ref = &peers;
        let hb = Some(Duration::from_millis(100));
        std::thread::scope(|s| {
            let handles: Vec<_> = listeners
                .iter()
                .enumerate()
                .map(|(rank, listener)| {
                    s.spawn(move || {
                        let (mut ring, mut star) = form_mesh_with(
                            rank,
                            peers_ref,
                            listener,
                            T,
                            WireCodecConfig::off(),
                            &CodecStats::new(),
                            hb,
                        )
                        .expect("heartbeat mesh");
                        let mut buf = vec![(rank + 1) as f32; 16];
                        ring.allreduce_avg(&mut buf).expect("allreduce 1");
                        // idle past several heartbeat intervals: the
                        // liveness plumbing must not false-positive
                        std::thread::sleep(Duration::from_millis(350));
                        ring.allreduce_avg(&mut buf).expect("allreduce 2");
                        let sg = SparseGrad::new(4, vec![rank as u32], vec![1.0]);
                        let gathered = star.gather(sg).expect("gather");
                        if rank == 0 {
                            assert_eq!(gathered.expect("root").len(), n);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("rank");
            }
        });
    }

    #[test]
    fn resume_min_reduce_agrees_on_the_fleet_minimum() {
        // Three nodes claim different resume points; after the ring
        // min-reduce every node must hold the fleet minimum (node 1's 3),
        // and a second exchange with equal inputs stays stable.
        let stats = CodecStats::new();
        let rings = local_ring(3, T, WireCodecConfig::off(), &stats).unwrap();
        let own = [7u64, 3, 5];
        let got: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = rings
                .into_iter()
                .enumerate()
                .map(|(i, mut ring)| {
                    s.spawn(move || {
                        let m = ring.resume_min_reduce(own[i]).expect("min reduce");
                        let again = ring.resume_min_reduce(m).expect("stable");
                        assert_eq!(again, m);
                        m
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        assert_eq!(got, vec![3, 3, 3]);
        // single node: the reduce is its own value, no links needed
        let mut solo = SocketRingNode::new(0, 1, None, None);
        assert_eq!(solo.resume_min_reduce(9).unwrap(), 9);
    }
}
