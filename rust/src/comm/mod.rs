//! Simulated communication fabric with exact byte accounting and an
//! analytic time model.
//!
//! The paper's scalability claims are about *communication volume* as a
//! function of worker count (Fig 1a/b, Fig 6, A8/A9). The fabric executes
//! every collective **functionally** (the trainer gets bit-exact averaged
//! gradients) while recording, per operation:
//!   - bytes each worker uploads / downloads,
//!   - bytes crossing the bottleneck link (the parameter-server port for
//!     PS topology; a worker's ring port for ring topology),
//!   - modeled wall time = latency·hops + bottleneck_bytes / bandwidth.
//!
//! Three collectives correspond to the three schemes the paper evaluates:
//!   - `dense_allreduce_avg` — uncompressed baseline,
//!   - `sparse_allreduce_shared` — ScaleCom: identical index sets reduce,
//!   - `sparse_gather_avg` — local top-k: per-worker sets must gather,
//!     and the reduced union grows O(n) (gradient build-up).

pub mod bucket;
pub mod codec;
pub mod cost;
pub mod fabric;
pub mod parallel;
pub mod socket;
pub mod wire;

pub use bucket::{Bucket, BucketPlan};
pub use codec::{CodecSnapshot, CodecStats, WireCodecConfig, WireCompression};
pub use cost::{CommCost, CommStats, RttSnapshot};
pub use fabric::{Fabric, FabricConfig, FaultSpec, GatherStats, Topology};
pub use parallel::Backend;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SparseGrad;

    fn mk_fabric(n: usize, topo: Topology) -> Fabric {
        Fabric::new(FabricConfig {
            workers: n,
            topology: topo,
            bandwidth_gbps: 32.0,
            latency_us: 1.0,
            fault: FaultSpec::None,
        })
    }

    #[test]
    fn scalecom_bytes_constant_in_n_but_gather_grows() {
        // The core scaling claim (Fig 1a): per-worker download for the
        // gather path grows with n; ScaleCom's stays constant.
        let dim = 10_000;
        let k = 100;
        let mut per_worker_down_gather = Vec::new();
        let mut per_worker_down_scalecom = Vec::new();
        for n in [2usize, 4, 8, 16] {
            // Disjoint index sets → worst-case build-up.
            let sparses: Vec<SparseGrad> = (0..n)
                .map(|w| {
                    let ix: Vec<u32> = (0..k as u32).map(|i| (w * k) as u32 + i).collect();
                    SparseGrad::new(dim, ix.clone(), vec![1.0; k])
                })
                .collect();
            let mut f = mk_fabric(n, Topology::ParameterServer);
            let _ = f.sparse_gather_avg(&sparses);
            per_worker_down_gather.push(f.stats().last_cost().bytes_down_per_worker);

            let shared_ix: Vec<u32> = (0..k as u32).collect();
            let shared: Vec<SparseGrad> = (0..n)
                .map(|_| SparseGrad::new(dim, shared_ix.clone(), vec![1.0; k]))
                .collect();
            let mut f2 = mk_fabric(n, Topology::ParameterServer);
            let _ = f2.sparse_allreduce_shared(&shared, 0);
            per_worker_down_scalecom.push(f2.stats().last_cost().bytes_down_per_worker);
        }
        // gather download grows ~linearly
        assert!(per_worker_down_gather[3] > per_worker_down_gather[0] * 6);
        // scalecom download constant
        assert_eq!(per_worker_down_scalecom[0], per_worker_down_scalecom[3]);
    }
}
